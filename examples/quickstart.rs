//! Quickstart: load the AOT runtime, train a tiny model for a few steps,
//! factor its keys, and show the KV cache saving — the whole API in ~60
//! lines. Run with: cargo run --release --example quickstart
use thinkeys::coordinator::roofline::KvGeometry;
use thinkeys::datagen::corpus::{Corpus, CorpusModel};
use thinkeys::model::surgery;
use thinkeys::runtime::Runtime;
use thinkeys::train::{eval, Schedule, Trainer, TrainState};

fn main() -> anyhow::Result<()> {
    // 1. The runtime loads artifacts/ (built once by `make artifacts`).
    let rt = Runtime::new()?;
    let full_cfg = rt.manifest().config("tinylm_ds64")?.clone();
    let thin_cfg = rt.manifest().config("tinylm_ds16")?.clone();

    // 2. Train the full-attention model briefly on the synthetic corpus.
    let model = CorpusModel::new(7, full_cfg.vocab);
    let corpus = Corpus::generate(&model, 60_000, 1);
    let trainer = Trainer::new(&rt, "tinylm_ds64", false)?;
    let mut st = TrainState::new(&full_cfg, 0);
    let batches = corpus.batches(&corpus.train, full_cfg.train_batch,
                                 full_cfg.train_seq, 0);
    let sched = Schedule::warmup_cosine(3e-3, 5, 60);
    let out = trainer.run(&mut st, 60, &sched,
                          |i| batches[i % batches.len()].clone())?;
    println!("trained 60 steps: loss {:.2} -> {:.2} ({:.0} tok/s)",
             out.losses[0], out.final_loss(), out.tokens_per_sec());
    let ppl_full = eval::eval_ppl(&rt, &full_cfg, &st.params,
        &corpus.batches(&corpus.val, 8, 64, 0)[..4])?;

    // 3. Factored keys: one SVD per head, queries absorb the factor.
    let thin = surgery::factor_to_thin(&st.params, &full_cfg, &thin_cfg)?;
    let ppl_thin = eval::eval_ppl(&rt, &thin_cfg, &thin,
        &corpus.batches(&corpus.val, 8, 64, 0)[..4])?;
    println!("val PPL: full {ppl_full:.2} -> factored(d/4) {ppl_thin:.2} \
              (zero retraining)");

    // 4. The saving this buys at deployment scale (paper Table 10):
    let std_kv = KvGeometry::mha(4096).cache_bytes(128_000, 32, 2.0) / 1e9;
    let thin_kv =
        KvGeometry::thin(4096, 1024).cache_bytes(128_000, 32, 2.0) / 1e9;
    println!("at 7B/128K: {std_kv:.1} GB -> {thin_kv:.1} GB KV per user \
              ({:.1}% saved)", 100.0 * (1.0 - thin_kv / std_kv));
    Ok(())
}
