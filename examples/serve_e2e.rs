//! End-to-end serving driver (the headline validation): pretrain a small
//! model, deploy it twice — full keys and factored keys — serve the same
//! Poisson trace through the full stack (router -> scheduler -> paged
//! split-pool KV cache -> batched PJRT decode), and report throughput,
//! latency, and the measured K-cache saving.
//! Run with: cargo run --release --example serve_e2e
use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::coordinator::router::Router;
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::scheduler::Scheduler;
use thinkeys::datagen::arrival::{poisson_trace, TraceConfig};
use thinkeys::experiments::common;
use thinkeys::model::surgery;
use thinkeys::runtime::{ParamStore, Runtime};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new()?;
    let full_cfg = rt.manifest().config("servefull")?.clone();
    let thin_cfg = rt.manifest().config("servethin")?.clone();

    // pretrain (cached under artifacts/ckpt after the first run)
    let corpus = common::corpus_for(&rt, "servefull", common::LARGE_CORPUS);
    let pre = common::pretrain_lm(&rt, "servefull", &corpus, "serve",
                                  240, 137)?;
    let ppl = common::val_ppl(&rt, "servefull", &pre.params, &corpus)?;
    println!("base model: servefull val PPL {ppl:.2} (cached: {})",
             pre.cached);
    let thin_params =
        surgery::factor_to_thin(&pre.params, &full_cfg, &thin_cfg)?;
    let ppl_thin = common::val_ppl(&rt, "servethin", &thin_params, &corpus)?;
    println!("factored (d/4, zero retraining): val PPL {ppl_thin:.2}");

    let trace = poisson_trace(&TraceConfig {
        rate_per_s: 6.0, n_requests: 24, prompt_mean: 48, prompt_max: 120,
        gen_mean: 16, gen_max: 32,
    }, 0);

    for (label, cfg, params) in [
        ("FULL KEYS", &full_cfg, pre.params.clone()),
        ("FACTORED KEYS (d/4)", &thin_cfg, thin_params),
    ] {
        let eng = Engine::new(&rt, &cfg.name, params, false,
                              Sampler::TopK { temperature: 0.8, top_k: 40 },
                              7)?;
        let kv = KvCacheManager::new(KvCacheConfig {
            n_layers: cfg.n_layers,
            k_dims: cfg.k_cache_dims,
            v_dims: cfg.v_cache_dims,
            block_tokens: 16,
            bytes_per_el_k: 2.0,
            bytes_per_el_v: 2.0,
            budget_bytes: 4e6,
        });
        println!("\n=== {label} ===  (token capacity {})",
                 kv.cfg.token_capacity());
        let sched = Scheduler::new(eng, kv, 16);
        let mut router = Router::new(sched);
        let report = router.run_trace(&trace, 3)?;
        println!("{}", report.report());
        println!("{}", router.sched.engine.metrics.report());
        let stats = router.sched.kv.stats();
        println!("K pool capacity {:.2} MB vs V pool {:.2} MB (K is {:.0}x \
                  thinner per token)",
                 stats.k_bytes_capacity / 1e6, stats.v_bytes_capacity / 1e6,
                 cfg.v_cache_dims as f64 / cfg.k_cache_dims as f64);
    }
    Ok(())
}
