//! From-scratch training comparison (paper Experiments 7/7b shape): train
//! the LLaMA-style model with full attention and with thin keys (d/4),
//! log the validation-PPL trajectory and wall-clock — thin keys should
//! track (or beat) full attention while training faster.
//! Run with: cargo run --release --example train_thin_vs_full
use thinkeys::experiments::exp67_llama::trajectory;
use thinkeys::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new()?;
    let steps = 120;
    let full = trajectory(&rt, "llama_ds64", steps, steps / 6, 137)?;
    let thin = trajectory(&rt, "llama_ds16", steps, steps / 6, 137)?;
    println!("\nstep   full-PPL   thin-PPL");
    for (i, &(step, ppl)) in full.checkpoints.iter().enumerate() {
        println!("{step:>5}  {ppl:>8.2}  {:>8.2}", thin.checkpoints[i].1);
    }
    println!("\nparams: full {:.2}M vs thin {:.2}M ({:.0}% fewer)",
             full.params as f64 / 1e6, thin.params as f64 / 1e6,
             100.0 * (1.0 - thin.params as f64 / full.params as f64));
    println!("wall-clock: full {:.1}s vs thin {:.1}s ({:+.1}%)",
             full.seconds, thin.seconds,
             100.0 * (thin.seconds / full.seconds - 1.0));
    Ok(())
}
