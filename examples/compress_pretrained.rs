//! Post-training compression walkthrough (paper Experiment 5 shape):
//! pretrain once, then show (a) the K-vs-Q compressibility asymmetry under
//! truncated SVD and (b) QK-only fine-tuning recovering the loss at an
//! aggressive rank. Run with: cargo run --release --example compress_pretrained
use thinkeys::experiments::common::{self, Opts};
use thinkeys::experiments::exp5_svd;
use thinkeys::model::surgery::{self, AblationMode};
use thinkeys::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new()?;
    let opts = Opts { scale: 0.5, seeds: vec![137] };
    let (params, corpus) = exp5_svd::base_model(&rt, &opts)?;
    let cfg = rt.manifest().config("tinylm_ds64")?.clone();
    let base = common::val_ppl(&rt, "tinylm_ds64", &params, &corpus)?;
    println!("pretrained tinylm: val PPL {base:.2} (d_qk_head = {})",
             cfg.d_qk_head);

    println!("\nrank/head   K-only        Q-only        (dPPL)");
    for r in [2usize, 4, 6] {
        let k = surgery::low_rank_ablation(&params, &cfg, r,
                                           AblationMode::KOnly)?;
        let q = surgery::low_rank_ablation(&params, &cfg, r,
                                           AblationMode::QOnly)?;
        let kp = common::val_ppl(&rt, "tinylm_ds64", &k, &corpus)?;
        let qp = common::val_ppl(&rt, "tinylm_ds64", &q, &corpus)?;
        println!("{r:>9}   {kp:>6.2} ({:+5.1}%)  {qp:>6.2} ({:+5.1}%)",
                 100.0 * (kp - base) / base, 100.0 * (qp - base) / base);
    }

    // aggressive factoring + recovery
    let thin_cfg = rt.manifest().config("tinylm_ds16")?.clone();
    let thin = surgery::factor_to_thin(&params, &cfg, &thin_cfg)?;
    let before = common::val_ppl(&rt, "tinylm_ds16", &thin, &corpus)?;
    let batches = corpus.batches(&corpus.train, cfg.train_batch,
                                 cfg.train_seq, 99);
    let tuned = common::qk_finetune(&rt, "tinylm_ds16", thin, 80,
                                    |i| batches[i % batches.len()].clone())?;
    let after = common::val_ppl(&rt, "tinylm_ds16", &tuned, &corpus)?;
    println!("\nfactored to d/4 (75% K cache saved): PPL {before:.2} before \
              FT -> {after:.2} after 80 QK-FT steps (base {base:.2})");
    Ok(())
}
