"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes / head-group sizes / lengths; plus directed edge
cases (single head, d_qk_head=1, full-length, length=1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # The testbed container may lack hypothesis (and nothing may be pip
    # installed there). The sweeps are skipped; the directed tests below
    # still run, so the module must keep collecting.
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(f):
            return pytest.mark.skip(reason="hypothesis not installed")(f)
        return deco

    def settings(**_k):
        def deco(f):
            return f
        return deco

    class _StStub:
        @staticmethod
        def composite(_f):
            # the composite strategy is only ever *called* by hypothesis;
            # under the stub it just needs to be invocable without `draw`
            def strategy(*_a, **_k):
                return None
            return strategy

        @staticmethod
        def sampled_from(xs):
            return xs

        @staticmethod
        def integers(lo, hi):
            return (lo, hi)

    st = _StStub()

from compile.kernels import ref
from compile.kernels.asym_attention import (pallas_attention_prefill,
                                            pallas_attention_decode,
                                            pallas_attention_decode_q8)

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@st.composite
def prefill_geometry(draw):
    b = draw(st.sampled_from([1, 2]))
    hkv = draw(st.sampled_from([1, 2]))
    group = draw(st.sampled_from([1, 2, 4]))
    s = draw(st.sampled_from([8, 16, 64]))
    dqk = draw(st.sampled_from([1, 2, 4, 8, 32]))
    dv = draw(st.sampled_from([4, 16, 32]))
    return b, hkv, group, s, dqk, dv


@given(prefill_geometry(), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_prefill_matches_ref(geom, seed):
    b, hkv, group, s, dqk, dv = geom
    h = hkv * group
    q = rand(seed, (b, h, s, dqk))
    k = rand(seed + 1, (b, hkv, s, dqk))
    v = rand(seed + 2, (b, hkv, s, dv))
    lengths = jnp.asarray(
        np.random.RandomState(seed % 2 ** 31).randint(1, s + 1, size=(b,)),
        jnp.int32)
    want = ref.attention_prefill(q, k, v, lengths)
    got = pallas_attention_prefill(q, k, v, lengths, block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@given(prefill_geometry(), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_decode_matches_ref(geom, seed):
    b, hkv, group, n, dqk, dv = geom
    h = hkv * group
    q = rand(seed, (b, h, dqk))
    kc = rand(seed + 1, (b, hkv, n, dqk))
    vc = rand(seed + 2, (b, hkv, n, dv))
    pos = jnp.asarray(
        np.random.RandomState((seed + 7) % 2 ** 31).randint(0, n, size=(b,)),
        jnp.int32)
    want = ref.attention_decode(q, kc, vc, pos)
    got = pallas_attention_decode(q, kc, vc, pos, block_k=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_prefill_no_lengths():
    q = rand(0, (2, 4, 32, 8))
    k = rand(1, (2, 2, 32, 8))
    v = rand(2, (2, 2, 32, 16))
    want = ref.attention_prefill(q, k, v, None)
    got = pallas_attention_prefill(q, k, v, None, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_prefill_causality():
    """Perturbing token j must not change outputs at positions < j."""
    q = rand(0, (1, 2, 16, 4))
    k = rand(1, (1, 2, 16, 4))
    v = rand(2, (1, 2, 16, 8))
    out = pallas_attention_prefill(q, k, v, block_q=8, block_k=8)
    k2 = k.at[:, :, 10].add(3.0)
    v2 = v.at[:, :, 10].add(3.0)
    out2 = pallas_attention_prefill(q, k2, v2, block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(out[:, :, :10]),
                               np.asarray(out2[:, :, :10]), atol=1e-6)
    assert np.abs(np.asarray(out[:, :, 10:]) -
                  np.asarray(out2[:, :, 10:])).max() > 1e-4


def test_decode_ignores_positions_beyond_pos():
    q = rand(0, (1, 2, 4))
    kc = rand(1, (1, 2, 16, 4))
    vc = rand(2, (1, 2, 16, 8))
    pos = jnp.array([5], jnp.int32)
    out = pallas_attention_decode(q, kc, vc, pos, block_k=8)
    kc2 = kc.at[:, :, 9:].set(99.0)
    vc2 = vc.at[:, :, 9:].set(-99.0)
    out2 = pallas_attention_decode(q, kc2, vc2, pos, block_k=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_decode_pos_zero():
    """pos=0: the output must equal v at index 0 (softmax over one entry)."""
    q = rand(0, (1, 2, 4))
    kc = rand(1, (1, 2, 8, 4))
    vc = rand(2, (1, 2, 8, 8))
    pos = jnp.array([0], jnp.int32)
    out = pallas_attention_decode(q, kc, vc, pos, block_k=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vc[:, :, 0]),
                               rtol=1e-5, atol=1e-5)


@given(prefill_geometry(), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_decode_attention_mass_matches_ref(geom, seed):
    """The per-row attention mass (ISSUE 10): the pallas score-plane
    reconstruction must match the ref softmax head-mean, each lane's mass
    must sum to 1 over the valid rows, and rows past pos must be exactly
    0 (the eviction policies rely on masked rows scoring zero)."""
    b, hkv, group, n, dqk, dv = geom
    h = hkv * group
    q = rand(seed, (b, h, dqk))
    kc = rand(seed + 1, (b, hkv, n, dqk))
    vc = rand(seed + 2, (b, hkv, n, dv))
    pos = jnp.asarray(
        np.random.RandomState((seed + 7) % 2 ** 31).randint(0, n, size=(b,)),
        jnp.int32)
    o_ref, m_ref = ref.attention_decode(q, kc, vc, pos, return_mass=True)
    o_pl, m_pl = pallas_attention_decode(q, kc, vc, pos, block_k=8,
                                         return_mass=True)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(m_pl), np.asarray(m_ref),
                               rtol=2e-5, atol=2e-5)
    mass = np.asarray(m_ref)
    np.testing.assert_allclose(mass.sum(axis=-1), 1.0, rtol=1e-5)
    for lane in range(b):
        assert np.all(mass[lane, int(pos[lane]) + 1:] == 0.0)


def test_decode_q8_attention_mass_matches_ref():
    """q8 twin of the mass oracle: fused-dequant mass (pallas vs ref)."""
    b, hkv, group, n, dqk, dv = 2, 2, 2, 16, 4, 8
    q = rand(0, (b, hkv * group, dqk))
    kq, ks, vq, vs = _quantized_cache(1, b, hkv, n, dqk, dv)
    pos = jnp.array([5, 12], jnp.int32)
    o_ref, m_ref = ref.attention_decode_q8(q, kq, ks, vq, vs, pos,
                                           return_mass=True)
    o_pl, m_pl = pallas_attention_decode_q8(q, kq, ks, vq, vs, pos,
                                            block_k=8, return_mass=True)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(m_pl), np.asarray(m_ref),
                               rtol=2e-5, atol=2e-5)
    mass = np.asarray(m_ref)
    np.testing.assert_allclose(mass.sum(axis=-1), 1.0, rtol=1e-5)
    assert np.all(mass[0, 6:] == 0.0) and np.all(mass[1, 13:] == 0.0)


# ---------------------------------------------------------------------------
# Per-row int8 quantization (ISSUE 4): round-trip properties + the
# dequant-fused attention oracle. The rust twin
# (substrate::tensor::quantize_rows_q8) mirrors these exact semantics.
# ---------------------------------------------------------------------------

def _quant_roundtrip_check(x):
    """Shared assertions: scale correctness + elementwise error bound."""
    q, s = ref.quantize_rows(x)
    xq = np.asarray(q)
    sc = np.asarray(s)
    xn = np.asarray(x)
    assert xq.dtype == np.int8 and sc.dtype == np.float32
    # per-row scale correctness: max|row|/127 (floored at eps)
    want = np.maximum(np.abs(xn).max(-1) / 127.0,
                      ref.Q8_SCALE_EPS).astype(np.float32)
    np.testing.assert_allclose(sc, want, rtol=1e-6)
    # worst-case reconstruction error <= scale/2 per element (tiny float
    # slack: the division x/s happens in f32)
    err = np.abs(xq.astype(np.float32) * sc[..., None] - xn)
    assert (err <= sc[..., None] * 0.5 + 1e-7).all(), err.max()
    return xq, sc


@given(st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_quantize_rows_roundtrip_sweep(d, seed):
    x = rand(seed, (3, 5, d))
    _quant_roundtrip_check(x)


def test_quantize_rows_roundtrip_directed():
    for d in (1, 2, 16, 80):
        _quant_roundtrip_check(rand(d, (2, 7, d)))


def test_quantize_zero_row():
    """An all-zero row must quantize to exactly zero codes and dequantize
    to exactly zero (the eps scale floor, not a NaN/inf)."""
    x = jnp.zeros((2, 4, 16))
    q, s = ref.quantize_rows(x)
    assert np.abs(np.asarray(q)).max() == 0
    assert np.abs(np.asarray(ref.dequantize_rows(q, s))).max() == 0.0
    assert np.isfinite(np.asarray(s)).all()


def test_quantize_outlier_row():
    """One huge element sets the scale: the outlier reproduces exactly
    (code 127) and every element still satisfies the scale/2 bound."""
    x = np.array(rand(0, (1, 8)))
    x[0, 3] = 1e4
    q, s = _quant_roundtrip_check(jnp.asarray(x))
    assert q[0, 3] == 127
    # small elements collapse toward zero but stay within half a quantum
    assert np.abs(q[0, :3]).max() <= 1


def test_quantize_mixed_zero_and_live_rows():
    """Zero rows and live rows coexist: independent per-row scales."""
    x = np.array(rand(1, (4, 8)))
    x[2] = 0.0
    q, s = _quant_roundtrip_check(jnp.asarray(x))
    assert np.abs(q[2]).max() == 0
    assert np.abs(q[[0, 1, 3]]).max() > 0


def _quantized_cache(seed, b, hkv, n, dqk, dv):
    """Build an int8 cache + per-ROW (B, N) scales shared across kv heads,
    exactly the serving arena layout: quantize the flat (B, N, hkv*d) rows,
    then reshape to heads."""
    kf = rand(seed, (b, n, hkv * dqk))
    vf = rand(seed + 1, (b, n, hkv * dv))
    kq, ks = ref.quantize_rows(kf)
    vq, vs = ref.quantize_rows(vf)
    kh = kq.reshape(b, n, hkv, dqk).transpose(0, 2, 1, 3)
    vh = vq.reshape(b, n, hkv, dv).transpose(0, 2, 1, 3)
    return kh, ks, vh, vs


def test_fused_q8_equals_dequant_then_attend():
    """THE fused-dequant oracle: attention_decode_q8 over (codes, scales)
    must equal attention_decode over the dequantized fp32 cache — the
    scale application inside the softmax loop is algebraically exact."""
    b, hkv, group, n, dqk, dv = 2, 2, 2, 16, 4, 8
    h = hkv * group
    q = rand(0, (b, h, dqk))
    kh, ks, vh, vs = _quantized_cache(7, b, hkv, n, dqk, dv)
    pos = jnp.array([15, 4], jnp.int32)
    fused = ref.attention_decode_q8(q, kh, ks, vh, vs, pos)
    kdeq = kh.astype(jnp.float32) * ks[:, None, :, None]
    vdeq = vh.astype(jnp.float32) * vs[:, None, :, None]
    want = ref.attention_decode(q, kdeq, vdeq, pos)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_q8_chunk_equals_dequant_then_attend():
    b, hkv, group, c, n, dqk, dv = 1, 2, 2, 4, 16, 4, 8
    h = hkv * group
    q = rand(3, (b, h, c, dqk))
    kh, ks, vh, vs = _quantized_cache(9, b, hkv, n, dqk, dv)
    qpos = jnp.array([[5, 6, 7, 8]], jnp.int32)
    fused = ref.attention_prefill_chunk_q8(q, kh, ks, vh, vs, qpos)
    kdeq = kh.astype(jnp.float32) * ks[:, None, :, None]
    vdeq = vh.astype(jnp.float32) * vs[:, None, :, None]
    want = ref.attention_prefill_chunk(q, kdeq, vdeq, qpos)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@given(prefill_geometry(), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_pallas_decode_q8_matches_ref(geom, seed):
    b, hkv, group, n, dqk, dv = geom
    h = hkv * group
    q = rand(seed, (b, h, dqk))
    kh, ks, vh, vs = _quantized_cache(seed + 11, b, hkv, n, dqk, dv)
    pos = jnp.asarray(
        np.random.RandomState((seed + 3) % 2 ** 31).randint(0, n, size=(b,)),
        jnp.int32)
    want = ref.attention_decode_q8(q, kh, ks, vh, vs, pos)
    got = pallas_attention_decode_q8(q, kh, ks, vh, vs, pos, block_k=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_pallas_decode_q8_matches_ref_directed():
    """Directed twin of the sweep (runs even without hypothesis): the
    Pallas q8 kernel streaming int8 tiles must match the jnp oracle."""
    for (b, hkv, group, n, dqk, dv) in [(1, 1, 1, 8, 2, 4),
                                        (2, 2, 4, 64, 8, 32),
                                        (2, 1, 2, 16, 1, 16)]:
        h = hkv * group
        q = rand(n + dqk, (b, h, dqk))
        kh, ks, vh, vs = _quantized_cache(n + dv, b, hkv, n, dqk, dv)
        pos = jnp.asarray(np.arange(b) % n, jnp.int32)
        want = ref.attention_decode_q8(q, kh, ks, vh, vs, pos)
        got = pallas_attention_decode_q8(q, kh, ks, vh, vs, pos, block_k=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Grouped thin keys (ISSUE 5): GQA with group size g must reproduce an MHA
# reference whose KV cache duplicates each kv head g times — the group
# broadcast lives in the BlockSpec index map (kv head = q head // group)
# and in ref.repeat_kv, both pure indexing, never arithmetic. fp32 paths
# must match BIT-FOR-BIT; q8 paths must match bit-for-bit too (grouping
# commutes with the fused dequant) and stay inside the fused-oracle bound
# already pinned above.
# ---------------------------------------------------------------------------

GROUPED_GEOMS = [(1, 2, 4, 32, 2, 8),   # servegqathin-shaped: thin dqk
                 (2, 1, 2, 16, 8, 4),
                 (2, 2, 2, 64, 4, 16),
                 (1, 2, 4, 8, 8, 8)]    # servegqa-shaped: full dqk


@pytest.mark.parametrize("geom", GROUPED_GEOMS)
def test_grouped_decode_bit_matches_duplicated_mha(geom):
    b, hkv, group, n, dqk, dv = geom
    h = hkv * group
    q = rand(0, (b, h, dqk))
    kc = rand(1, (b, hkv, n, dqk))
    vc = rand(2, (b, hkv, n, dv))
    pos = jnp.asarray((np.arange(b) * 7 + 3) % n, jnp.int32)
    grouped = pallas_attention_decode(q, kc, vc, pos, block_k=8)
    mha = pallas_attention_decode(q, ref.repeat_kv(kc, group),
                                  ref.repeat_kv(vc, group), pos, block_k=8)
    assert np.array_equal(np.asarray(grouped), np.asarray(mha)), \
        "pallas group broadcast diverged from duplicated-kv MHA"
    ref_grouped = ref.attention_decode(q, kc, vc, pos)
    ref_mha = ref.attention_decode(q, ref.repeat_kv(kc, group),
                                   ref.repeat_kv(vc, group), pos)
    assert np.array_equal(np.asarray(ref_grouped), np.asarray(ref_mha)), \
        "ref group broadcast diverged from duplicated-kv MHA"


@pytest.mark.parametrize("geom", GROUPED_GEOMS)
def test_grouped_q8_decode_bit_matches_duplicated_mha(geom):
    """q8 grouped parity: the per-ROW scales are shared across kv heads
    (the arena layout), so duplicating the int8 kv heads while keeping the
    same (B, N) scale planes must reproduce the grouped output exactly —
    in the Pallas kernel and the jnp oracle alike."""
    b, hkv, group, n, dqk, dv = geom
    h = hkv * group
    q = rand(5, (b, h, dqk))
    kh, ks, vh, vs = _quantized_cache(21, b, hkv, n, dqk, dv)
    pos = jnp.asarray((np.arange(b) * 5 + 1) % n, jnp.int32)
    grouped = pallas_attention_decode_q8(q, kh, ks, vh, vs, pos, block_k=8)
    mha = pallas_attention_decode_q8(
        q, ref.repeat_kv(kh, group), ks, ref.repeat_kv(vh, group), vs,
        pos, block_k=8)
    assert np.array_equal(np.asarray(grouped), np.asarray(mha))
    ref_grouped = ref.attention_decode_q8(q, kh, ks, vh, vs, pos)
    ref_mha = ref.attention_decode_q8(
        q, ref.repeat_kv(kh, group), ks, ref.repeat_kv(vh, group), vs, pos)
    assert np.array_equal(np.asarray(ref_grouped), np.asarray(ref_mha))


def test_grouped_prefill_chunk_bit_matches_duplicated_mha():
    """The chunk-window kernel's group broadcast (fp32 and q8): a C-query
    window against a grouped arena == the same window against the
    duplicated-kv MHA arena, bit for bit."""
    b, hkv, group, c, n, dqk, dv = 1, 2, 4, 8, 32, 2, 8
    h = hkv * group
    q = rand(3, (b, h, c, dqk))
    kc = rand(4, (b, hkv, n, dqk))
    vc = rand(5, (b, hkv, n, dv))
    qpos = jnp.arange(6, 6 + c, dtype=jnp.int32)[None]
    grouped = ref.attention_prefill_chunk(q, kc, vc, qpos)
    mha = ref.attention_prefill_chunk(q, ref.repeat_kv(kc, group),
                                      ref.repeat_kv(vc, group), qpos)
    assert np.array_equal(np.asarray(grouped), np.asarray(mha))
    kh, ks, vh, vs = _quantized_cache(9, b, hkv, n, dqk, dv)
    grouped8 = ref.attention_prefill_chunk_q8(q, kh, ks, vh, vs, qpos)
    mha8 = ref.attention_prefill_chunk_q8(
        q, ref.repeat_kv(kh, group), ks, ref.repeat_kv(vh, group), vs, qpos)
    assert np.array_equal(np.asarray(grouped8), np.asarray(mha8))


def test_grouped_prefill_bit_matches_duplicated_mha():
    """The flash prefill kernel's index-map broadcast, same contract."""
    b, hkv, group, s, dqk, dv = 2, 2, 4, 32, 2, 8
    h = hkv * group
    q = rand(0, (b, h, s, dqk))
    k = rand(1, (b, hkv, s, dqk))
    v = rand(2, (b, hkv, s, dv))
    lengths = jnp.array([s, s // 2], jnp.int32)
    grouped = pallas_attention_prefill(q, k, v, lengths, block_q=8,
                                       block_k=8)
    mha = pallas_attention_prefill(q, ref.repeat_kv(k, group),
                                   ref.repeat_kv(v, group), lengths,
                                   block_q=8, block_k=8)
    assert np.array_equal(np.asarray(grouped), np.asarray(mha))


def test_thin_equals_full_when_keys_padded():
    """Zero-padding the qk dim must not change attention output — the
    asymmetric kernel's output depends on q·k only (selection is scalar)."""
    b, h, s, dqk, dv = 1, 2, 16, 4, 8
    q = rand(0, (b, h, s, dqk))
    k = rand(1, (b, h, s, dqk))
    v = rand(2, (b, h, s, dv))
    out_thin = ref.attention_prefill(q, k, v)
    pad = jnp.zeros((b, h, s, 12))
    qp = jnp.concatenate([q * jnp.sqrt(16 / 4), pad], -1)  # undo rescale
    kp = jnp.concatenate([k, pad], -1)
    out_pad = ref.attention_prefill(qp, kp, v)
    np.testing.assert_allclose(np.asarray(out_thin), np.asarray(out_pad),
                               rtol=1e-5, atol=1e-5)
