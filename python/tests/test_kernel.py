"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes / head-group sizes / lengths; plus directed edge
cases (single head, d_qk_head=1, full-length, length=1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.asym_attention import (pallas_attention_prefill,
                                            pallas_attention_decode)

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@st.composite
def prefill_geometry(draw):
    b = draw(st.sampled_from([1, 2]))
    hkv = draw(st.sampled_from([1, 2]))
    group = draw(st.sampled_from([1, 2, 4]))
    s = draw(st.sampled_from([8, 16, 64]))
    dqk = draw(st.sampled_from([1, 2, 4, 8, 32]))
    dv = draw(st.sampled_from([4, 16, 32]))
    return b, hkv, group, s, dqk, dv


@given(prefill_geometry(), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_prefill_matches_ref(geom, seed):
    b, hkv, group, s, dqk, dv = geom
    h = hkv * group
    q = rand(seed, (b, h, s, dqk))
    k = rand(seed + 1, (b, hkv, s, dqk))
    v = rand(seed + 2, (b, hkv, s, dv))
    lengths = jnp.asarray(
        np.random.RandomState(seed % 2 ** 31).randint(1, s + 1, size=(b,)),
        jnp.int32)
    want = ref.attention_prefill(q, k, v, lengths)
    got = pallas_attention_prefill(q, k, v, lengths, block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@given(prefill_geometry(), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_decode_matches_ref(geom, seed):
    b, hkv, group, n, dqk, dv = geom
    h = hkv * group
    q = rand(seed, (b, h, dqk))
    kc = rand(seed + 1, (b, hkv, n, dqk))
    vc = rand(seed + 2, (b, hkv, n, dv))
    pos = jnp.asarray(
        np.random.RandomState((seed + 7) % 2 ** 31).randint(0, n, size=(b,)),
        jnp.int32)
    want = ref.attention_decode(q, kc, vc, pos)
    got = pallas_attention_decode(q, kc, vc, pos, block_k=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_prefill_no_lengths():
    q = rand(0, (2, 4, 32, 8))
    k = rand(1, (2, 2, 32, 8))
    v = rand(2, (2, 2, 32, 16))
    want = ref.attention_prefill(q, k, v, None)
    got = pallas_attention_prefill(q, k, v, None, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_prefill_causality():
    """Perturbing token j must not change outputs at positions < j."""
    q = rand(0, (1, 2, 16, 4))
    k = rand(1, (1, 2, 16, 4))
    v = rand(2, (1, 2, 16, 8))
    out = pallas_attention_prefill(q, k, v, block_q=8, block_k=8)
    k2 = k.at[:, :, 10].add(3.0)
    v2 = v.at[:, :, 10].add(3.0)
    out2 = pallas_attention_prefill(q, k2, v2, block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(out[:, :, :10]),
                               np.asarray(out2[:, :, :10]), atol=1e-6)
    assert np.abs(np.asarray(out[:, :, 10:]) -
                  np.asarray(out2[:, :, 10:])).max() > 1e-4


def test_decode_ignores_positions_beyond_pos():
    q = rand(0, (1, 2, 4))
    kc = rand(1, (1, 2, 16, 4))
    vc = rand(2, (1, 2, 16, 8))
    pos = jnp.array([5], jnp.int32)
    out = pallas_attention_decode(q, kc, vc, pos, block_k=8)
    kc2 = kc.at[:, :, 9:].set(99.0)
    vc2 = vc.at[:, :, 9:].set(-99.0)
    out2 = pallas_attention_decode(q, kc2, vc2, pos, block_k=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_decode_pos_zero():
    """pos=0: the output must equal v at index 0 (softmax over one entry)."""
    q = rand(0, (1, 2, 4))
    kc = rand(1, (1, 2, 8, 4))
    vc = rand(2, (1, 2, 8, 8))
    pos = jnp.array([0], jnp.int32)
    out = pallas_attention_decode(q, kc, vc, pos, block_k=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vc[:, :, 0]),
                               rtol=1e-5, atol=1e-5)


def test_thin_equals_full_when_keys_padded():
    """Zero-padding the qk dim must not change attention output — the
    asymmetric kernel's output depends on q·k only (selection is scalar)."""
    b, h, s, dqk, dv = 1, 2, 16, 4, 8
    q = rand(0, (b, h, s, dqk))
    k = rand(1, (b, h, s, dqk))
    v = rand(2, (b, h, s, dv))
    out_thin = ref.attention_prefill(q, k, v)
    pad = jnp.zeros((b, h, s, 12))
    qp = jnp.concatenate([q * jnp.sqrt(16 / 4), pad], -1)  # undo rescale
    kp = jnp.concatenate([k, pad], -1)
    out_pad = ref.attention_prefill(qp, kp, v)
    np.testing.assert_allclose(np.asarray(out_thin), np.asarray(out_pad),
                               rtol=1e-5, atol=1e-5)
