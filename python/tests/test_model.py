"""L2 model invariants: shapes, causality, prefill/decode parity, training
step behaviour, QK-only fine-tuning masking, and the factored-keys
(SVD + absorption) score-equivalence that pins rust/src/model/surgery.rs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import REGISTRY
from compile import model as M

VARIANTS = ["tinylm_ds32", "tinylm_ds64", "llama_ds32", "llama_gqa2",
            "llama_mla56", "tinygqa_ds32", "servegqathin"]


def setup_cfg(name, seed=0):
    cfg = REGISTRY[name]
    p = M.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, p


@pytest.mark.parametrize("name", VARIANTS)
def test_forward_shape_and_causality(name):
    cfg, p = setup_cfg(name)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits = M.forward(cfg, p, toks)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    toks2 = toks.at[:, 10].set((toks[:, 10] + 1) % cfg.vocab)
    l2 = M.forward(cfg, p, toks2)
    np.testing.assert_allclose(np.asarray(logits[:, :10]),
                               np.asarray(l2[:, :10]), atol=1e-5)


@pytest.mark.parametrize("name", ["servefull", "servethin",
                                  "servegqathin", "llama_ds32"])
def test_prefill_decode_parity(name):
    """prefill(prompt) then decode(tok_t) must reproduce forward logits."""
    cfg, p = setup_cfg(name)
    plist = M.flatten(cfg, p)
    S, N, L = 16, cfg.max_seq, cfg.n_layers
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab)
    full = M.forward(cfg, p, toks)
    out = M.make_prefill(cfg, S)(*plist, toks, jnp.asarray(7, jnp.int32))
    lastlog, kc, vc = out
    np.testing.assert_allclose(np.asarray(lastlog[0]), np.asarray(full[0, 6]),
                               rtol=1e-4, atol=1e-4)
    ka = jnp.zeros((L, 1, N, kc.shape[-1])).at[:, 0, :S].set(kc)
    va = jnp.zeros((L, 1, N, vc.shape[-1])).at[:, 0, :S].set(vc)
    decode = M.make_decode(cfg, 1)
    for t in range(7, 12):
        lg, ka, va, kr, vr, _ = decode(*plist, ka, va, toks[:, t],
                                       jnp.array([t], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(full[0, t]),
                                   rtol=1e-4, atol=1e-4)
        # the delta outputs are exactly the rows written at position t
        np.testing.assert_allclose(np.asarray(kr), np.asarray(ka[:, :, t]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(vr), np.asarray(va[:, :, t]),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", ["servethin", "llama_ds32"])
def test_decode_tier_parity(name):
    """Decoding in a small arena tier must produce the same logits as the
    full max_seq arena (the tier only truncates never-written rows)."""
    cfg, p = setup_cfg(name)
    plist = M.flatten(cfg, p)
    S, L, tier = 12, cfg.n_layers, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, S + 6), 0, cfg.vocab)
    out = M.make_prefill(cfg, S)(*plist, toks[:, :S], jnp.asarray(S, jnp.int32))
    _, kc, vc = out
    run = {}
    for n in (tier, cfg.max_seq):
        ka = jnp.zeros((L, 1, n, kc.shape[-1])).at[:, 0, :S].set(kc)
        va = jnp.zeros((L, 1, n, vc.shape[-1])).at[:, 0, :S].set(vc)
        decode = M.make_decode(cfg, 1, n=n)
        logs = []
        for t in range(S, S + 6):
            lg, ka, va, _, _, _ = decode(*plist, ka, va, toks[:, t],
                                         jnp.array([t], jnp.int32))
            logs.append(np.asarray(lg))
        run[n] = logs
    for a, b in zip(run[tier], run[cfg.max_seq]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["servefull", "servethin",
                                  "servegqathin"])
@pytest.mark.parametrize("plen", [8, 37, 128])
def test_chunked_prefill_bit_identical_to_single_shot(name, plen):
    """The chunked-prefill contract (ISSUE 3): running ceil(p/C) chunks of
    make_prefill_chunk — carrying the arenas across calls and accumulating
    only the per-chunk delta rows host-side, exactly as the rust engine
    does — must reproduce the single-shot prefill BIT-FOR-BIT: last
    logits, final arenas, and the delta-row mirror. Covers a prompt
    shorter than the chunk (8), one not divisible by any chunk (37), and
    the full bucket (128)."""
    from compile.configs import PREFILL_CHUNKS, PREFILL_SEQ
    cfg, p = setup_cfg(name)
    plist = M.flatten(cfg, p)
    S, L = PREFILL_SEQ, cfg.n_layers
    KD, VD = cfg.k_cache_dims(), cfg.v_cache_dims()
    toks = np.zeros((1, S), np.int32)
    toks[0, :plen] = np.random.RandomState(plen).randint(4, cfg.vocab, plen)
    log_a, kc_a, vc_a = map(np.asarray, jax.jit(M.make_prefill(cfg, S))(
        *plist, jnp.asarray(toks), jnp.asarray(plen, jnp.int32)))
    for C in PREFILL_CHUNKS:
        chunk = jax.jit(M.make_prefill_chunk(cfg, C, S))
        ka, va = jnp.zeros((L, S, KD)), jnp.zeros((L, S, VD))
        mirror_k = np.zeros((L, S, KD), np.float32)
        mirror_v = np.zeros((L, S, VD), np.float32)
        start, log_b = 0, None
        while start < plen:
            ctoks = np.zeros((1, C), np.int32)
            n_valid = min(C, plen - start)
            ctoks[0, :n_valid] = toks[0, start:start + n_valid]
            log_b, ka, va, kr, vr = chunk(
                *plist, ka, va, jnp.asarray(ctoks),
                jnp.asarray(start, jnp.int32), jnp.asarray(plen, jnp.int32))
            mirror_k[:, start:start + C] = np.asarray(kr)
            mirror_v[:, start:start + C] = np.asarray(vr)
            start += C
        assert np.array_equal(log_a, np.asarray(log_b)), (name, plen, C)
        assert np.array_equal(kc_a, np.asarray(ka)), (name, plen, C)
        assert np.array_equal(vc_a, np.asarray(va)), (name, plen, C)
        # the host mirror built from delta rows alone matches the arena
        assert np.array_equal(kc_a[:, :plen], mirror_k[:, :plen])
        assert np.array_equal(vc_a[:, :plen], mirror_v[:, :plen])


@pytest.mark.parametrize("name", ["servefull", "servethin",
                                  "servegqathin"])
def test_q8_decode_parity_bounded(name):
    """The q8 acceptance oracle (ISSUE 4): decoding over the quantized
    arena must track the fp32 engine's logits within a tight bound.
    Teacher-forced (both paths fed the fp32 argmax tokens) so contexts
    stay identical; measured worst-case with init params is ~1.5e-3 on a
    ~1.3 logit range — the 0.05 bound is ~30x headroom while still
    catching any real dequant/scatter bug."""
    from compile.kernels import ref
    cfg, p = setup_cfg(name)
    plist = M.flatten(cfg, p)
    L, N, B, S = cfg.n_layers, 64, 2, 16
    KD, VD = cfg.k_cache_dims(), cfg.v_cache_dims()
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S + 8), 0, cfg.vocab)
    _, kc, vc = M.make_prefill(cfg, S)(*plist, toks[:, :S],
                                       jnp.asarray(S, jnp.int32))
    ka = jnp.zeros((L, B, N, KD)).at[:, 0, :S].set(kc)
    va = jnp.zeros((L, B, N, VD)).at[:, 0, :S].set(vc)
    kq, ks = ref.quantize_rows(ka)   # host-side quantization of the park
    vq, vs = ref.quantize_rows(va)
    dec = jax.jit(M.make_decode(cfg, B, n=N))
    dec8 = jax.jit(M.make_decode_q8(cfg, B, n=N))
    t = jnp.stack([toks[0, S], toks[0, S]])
    pos = jnp.array([S, 0], jnp.int32)
    worst = 0.0
    for _ in range(6):
        lg, ka, va, _, _, _ = dec(*plist, ka, va, t, pos)
        lg8, kq, ks, vq, vs, kr, krs, vr, vrs, _ = dec8(
            *plist, kq, ks, vq, vs, t, pos)
        worst = max(worst, float(jnp.abs(lg - lg8).max()))
        # the delta outputs are exactly the quantized rows written at pos
        lanes = jnp.arange(B)
        assert np.array_equal(np.asarray(kr),
                              np.asarray(kq[:, lanes, pos])), "k delta rows"
        assert np.array_equal(np.asarray(krs),
                              np.asarray(ks[:, lanes, pos])), "k delta scales"
        assert np.array_equal(np.asarray(vr), np.asarray(vq[:, lanes, pos]))
        assert np.array_equal(np.asarray(vrs), np.asarray(vs[:, lanes, pos]))
        t = jnp.argmax(lg, -1).astype(jnp.int32)  # teacher-force fp32 path
        pos = pos + 1
    assert 0.0 < worst < 0.05, worst


@pytest.mark.parametrize("name", ["servethin"])
@pytest.mark.parametrize("plen", [8, 37, 128])
def test_q8_chunked_prefill_contract(name, plen):
    """q8 chunked prefill (ISSUE 4): the delta-row mirror equals the
    arena, the dequantized arena tracks the fp32 single-shot arena within
    the per-row quantization bound (plus the bounded drift from attending
    quantized earlier rows), padded rows stay exactly zero, and the
    resulting arena is IDENTICAL whatever chunk schedule produced it (row
    values depend only on the quantized prefix, not on chunk boundaries)."""
    from compile.configs import PREFILL_CHUNKS, PREFILL_SEQ
    from compile.kernels import ref
    cfg, p = setup_cfg(name)
    plist = M.flatten(cfg, p)
    S, L = PREFILL_SEQ, cfg.n_layers
    KD, VD = cfg.k_cache_dims(), cfg.v_cache_dims()
    toks = np.zeros((1, S), np.int32)
    toks[0, :plen] = np.random.RandomState(plen).randint(4, cfg.vocab, plen)
    log_a, kc_a, vc_a = map(np.asarray, jax.jit(M.make_prefill(cfg, S))(
        *plist, jnp.asarray(toks), jnp.asarray(plen, jnp.int32)))
    arenas = []
    for C in PREFILL_CHUNKS:
        chunk = jax.jit(M.make_prefill_chunk_q8(cfg, C, S))
        ka = jnp.zeros((L, S, KD), jnp.int8)
        kas = jnp.zeros((L, S))
        va = jnp.zeros((L, S, VD), jnp.int8)
        vas = jnp.zeros((L, S))
        mirror_k = np.zeros((L, S, KD), np.int8)
        mirror_ks = np.zeros((L, S), np.float32)
        start, log_b = 0, None
        while start < plen:
            ctoks = np.zeros((1, C), np.int32)
            nv = min(C, plen - start)
            ctoks[0, :nv] = toks[0, start:start + nv]
            log_b, ka, kas, va, vas, kr, krs, vr, vrs = chunk(
                *plist, ka, kas, va, vas, jnp.asarray(ctoks),
                jnp.asarray(start, jnp.int32), jnp.asarray(plen, jnp.int32))
            mirror_k[:, start:start + C] = np.asarray(kr)
            mirror_ks[:, start:start + C] = np.asarray(krs)
            start += C
        # delta-sync contract: the mirror rebuilt from delta rows alone
        # equals the arena
        assert np.array_equal(mirror_k[:, :plen], np.asarray(ka)[:, :plen])
        assert np.array_equal(mirror_ks[:, :plen], np.asarray(kas)[:, :plen])
        # padded rows have zero codes (so they dequantize to exactly 0);
        # rows covered by a chunk but >= length carry the eps scale floor,
        # rows never touched by any chunk keep their 0.0 init
        if plen < S:
            assert np.abs(np.asarray(ka)[:, plen:]).max() == 0
            assert np.asarray(kas)[:, plen:].max() <= ref.Q8_SCALE_EPS
        # dequantized arena tracks the fp32 single-shot arena
        deq_k = np.asarray(ref.dequantize_rows(ka, kas))
        deq_v = np.asarray(ref.dequantize_rows(va, vas))
        bound_k = np.asarray(kas)[..., None] * 0.5
        assert (np.abs(deq_k[:, :plen] - kc_a[:, :plen])
                <= bound_k[:, :plen] + 0.02).all()
        assert np.abs(deq_v[:, :plen] - vc_a[:, :plen]).max() < 0.1
        # last-chunk logits track the fp32 prefill logits
        assert np.abs(np.asarray(log_b) - log_a).max() < 0.05
        arenas.append((np.asarray(ka)[:, :plen], np.asarray(kas)[:, :plen],
                       np.asarray(va)[:, :plen], np.asarray(vas)[:, :plen]))
    # chunk-schedule independence: every C produced the same live rows
    # (beyond plen the eps-scale footprint differs by chunk coverage, but
    # codes there are 0 so the dequantized arena is identical everywhere)
    for other in arenas[1:]:
        for a, b in zip(arenas[0], other):
            assert np.array_equal(a, b), "q8 arena depends on chunking"


def test_prefill_zeroes_padded_cache_rows():
    cfg, p = setup_cfg("servefull")
    plist = M.flatten(cfg, p)
    S = 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab)
    _, kc, vc = M.make_prefill(cfg, S)(*plist, toks, jnp.asarray(5, jnp.int32))
    assert float(jnp.abs(kc[:, 5:]).max()) == 0.0
    assert float(jnp.abs(vc[:, 5:]).max()) == 0.0
    assert float(jnp.abs(kc[:, :5]).max()) > 0.0


def test_train_step_reduces_loss():
    cfg, p = setup_cfg("copyback_ds16")
    plist = M.flatten(cfg, p)
    zeros = [jnp.zeros_like(t) for t in plist]
    b, s = 8, 16
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    targets = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    # geometry differs from the exported artifact; the python fn is generic
    mask = jnp.ones((b, s))
    step = jax.jit(M.make_train_step(cfg))
    m, v = list(zeros), list(zeros)
    losses = []
    for i in range(30):
        out = step(*plist, *m, *v, toks, targets, mask,
                   jnp.asarray(1e-2), jnp.asarray(float(i + 1)))
        losses.append(float(out[0]))
        n = len(plist)
        plist = list(out[1:n + 1])
        m = list(out[n + 1:2 * n + 1])
        v = list(out[2 * n + 1:3 * n + 1])
    assert losses[-1] < losses[0] * 0.7, losses


def test_qkft_only_updates_qk():
    cfg, p = setup_cfg("tinylm_ds32")
    plist = M.flatten(cfg, p)
    zeros = [jnp.zeros_like(t) for t in plist]
    b, s = 2, 16
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    mask = jnp.ones((b, s))
    step = jax.jit(M.make_train_step(cfg, trainable="qk"))
    out = step(*plist, *zeros, *zeros, toks, toks, mask,
               jnp.asarray(1e-2), jnp.asarray(1.0))
    specs = M.param_specs(cfg)
    new = out[1:len(plist) + 1]
    for sp, old_t, new_t in zip(specs, plist, new):
        changed = float(jnp.abs(old_t - new_t).max()) > 0
        assert changed == sp.qk, (sp.name, changed)


def test_mask_excludes_positions_from_loss():
    cfg, p = setup_cfg("tinylm_ds32")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits = M.forward(cfg, p, toks)
    m1 = jnp.ones((2, 16))
    m2 = m1.at[:, 8:].set(0.0)
    s1, c1 = M.masked_nll(logits, toks, m1)
    s2, c2 = M.masked_nll(logits, toks, m2)
    assert float(c1) == 32.0 and float(c2) == 16.0
    assert float(s2) < float(s1)


# ---------------------------------------------------------------------------
# Factored keys: the SVD + absorption math that rust surgery implements.
# ---------------------------------------------------------------------------

def factor_head(wq, wk, r):
    """Per-head truncated-SVD factoring with query absorption and the
    softmax-scale correction (numpy twin of rust model::surgery)."""
    d_head = wk.shape[1]
    u, s, vt = np.linalg.svd(wk, full_matrices=False)
    a = u[:, :r] * s[:r]                     # thin key projection (d, r)
    wq_new = wq @ vt[:r].T                   # absorbed query (d, r)
    # the thin model divides scores by sqrt(r); the original by sqrt(d_head)
    wq_new = wq_new * np.sqrt(r / d_head)
    return wq_new.astype(np.float32), a.astype(np.float32)


def test_factored_keys_exact_at_full_rank():
    """At r = d_head the factorization is exact: thin-model attention output
    equals the full model's (scores preserved, scale corrected)."""
    full, p = setup_cfg("tinylm_ds64")
    thin = REGISTRY["tinylm_ds32"]
    rng = np.random.RandomState(3)
    d, h = full.d_model, full.n_heads
    dh = full.d_qk_head
    x = jnp.asarray(rng.randn(2, 16, d).astype(np.float32))
    wq = np.asarray(p["l0.attn.wq"]).reshape(d, h, dh)
    wk = np.asarray(p["l0.attn.wk"]).reshape(d, h, dh)
    wv = np.asarray(p["l0.attn.wv"])

    for r, cfg_r in ((dh, full), (thin.d_qk_head, thin)):
        wq_t = np.stack([factor_head(wq[:, i], wk[:, i], r)[0]
                         for i in range(h)], 1)
        wk_t = np.stack([factor_head(wq[:, i], wk[:, i], r)[1]
                         for i in range(h)], 1)
        q_full = M._heads(x @ p["l0.attn.wq"], h, dh)
        k_full = M._heads(x @ p["l0.attn.wk"], h, dh)
        v = M._heads(x @ jnp.asarray(wv), h, full.d_v_head)
        from compile.kernels import ref
        o_full = ref.attention_prefill(q_full, k_full, v)
        q_thin = M._heads(x @ jnp.asarray(wq_t.reshape(d, h * r)), h, r)
        k_thin = M._heads(x @ jnp.asarray(wk_t.reshape(d, h * r)), h, r)
        o_thin = ref.attention_prefill(q_thin, k_thin, v)
        err = float(jnp.abs(o_full - o_thin).max())
        if r == dh:
            assert err < 1e-4, err          # exact at full rank
        else:
            assert err < 0.5, err           # approximation, bounded


def test_factored_keys_error_monotone_in_rank():
    """Eckart–Young: attention-output error decreases as rank grows."""
    full, p = setup_cfg("tinylm_ds64", seed=4)
    rng = np.random.RandomState(5)
    d, h, dh = full.d_model, full.n_heads, full.d_qk_head
    x = jnp.asarray(rng.randn(1, 32, d).astype(np.float32))
    wq = np.asarray(p["l1.attn.wq"]).reshape(d, h, dh)
    wk = np.asarray(p["l1.attn.wk"]).reshape(d, h, dh)
    v = M._heads(x @ p["l1.attn.wv"], h, full.d_v_head)
    from compile.kernels import ref
    q_full = M._heads(x @ p["l1.attn.wq"], h, dh)
    k_full = M._heads(x @ p["l1.attn.wk"], h, dh)
    o_full = ref.attention_prefill(q_full, k_full, v)
    errs = []
    for r in (1, 2, 4, 8):
        wq_t = np.stack([factor_head(wq[:, i], wk[:, i], r)[0]
                         for i in range(h)], 1)
        wk_t = np.stack([factor_head(wq[:, i], wk[:, i], r)[1]
                         for i in range(h)], 1)
        q = M._heads(x @ jnp.asarray(wq_t.reshape(d, h * r)), h, r)
        k = M._heads(x @ jnp.asarray(wk_t.reshape(d, h * r)), h, r)
        o = ref.attention_prefill(q, k, v)
        errs.append(float(jnp.abs(o - o_full).max()))
    assert errs[-1] < errs[0], errs
    assert errs[-1] < 1e-4, errs  # full rank -> exact


def test_mla_cache_budget():
    cfg = REGISTRY["llama_mla56"]
    assert cfg.kv_budget() == 56 + 8
    cfg2 = REGISTRY["llama_gqa2"]
    assert cfg2.kv_budget() == 2 * (16 + 16)


def test_param_specs_sizes():
    """Thin configs must have strictly fewer parameters; report the delta."""
    def n_params(name):
        return sum(int(np.prod(s.shape)) for s in
                   M.param_specs(REGISTRY[name]))
    full, thin = n_params("llama_ds64"), n_params("llama_ds32")
    assert thin < full
    # QK at d/4 (ds16 of d_model 64) should save ~75% of QK params
    def qk_params(name):
        return sum(int(np.prod(s.shape)) for s in
                   M.param_specs(REGISTRY[name]) if s.qk)
    assert abs(1 - qk_params("llama_ds16") / qk_params("llama_ds64") - 0.75) < 0.01
