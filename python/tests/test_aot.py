"""Export-path checks: the artifact plan is well-formed and the manifest
written by aot.py is consistent with the configs/model param specs the rust
runtime will rely on."""

import json
import os

import numpy as np
import pytest

from compile.aot import artifact_plan, build_entry
from compile.configs import REGISTRY, config_dict, train_geometry
from compile import model as M

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_plan_names_unique():
    plan = artifact_plan()
    names = [n for n, _, _, _ in plan]
    assert len(names) == len(set(names))
    assert len(plan) > 80


@pytest.mark.parametrize("kind,cfgname,geom", [
    ("train", "copyback_ds4", {"b": 16, "s": 32}),
    ("qkft", "tinylm_ds32", {"b": 8, "s": 64}),
    ("evalloss", "tinylm_ds64", {"b": 8, "s": 64}),
    ("logits", "kvret_ds8", {"b": 32, "s": 24}),
    ("prefill", "servethin", {"s": 128}),
    ("decode", "servethin", {"b": 4}),
])
def test_build_entry_specs(kind, cfgname, geom):
    cfg = REGISTRY[cfgname]
    fn, specs, in_names, out_names = build_entry(kind, cfg, geom)
    assert len(specs) == len(in_names)
    nparams = len(M.param_specs(cfg))
    if kind in ("train", "qkft"):
        assert len(specs) == 3 * nparams + 5
    # parameter arg shapes must match the specs order exactly
    for s, p in zip(specs[:nparams], M.param_specs(cfg)):
        assert tuple(s.shape) == tuple(p.shape)


def test_manifest_consistent_with_registry():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not exported (run `make artifacts`)")
    with open(path) as f:
        man = json.load(f)
    assert man["version"] == 1
    for name_, cd in man["configs"].items():
        cfg = REGISTRY[name_]
        want = config_dict(cfg)
        for k, v in want.items():
            assert cd[k] == v, (name_, k)
        specs = M.param_specs(cfg)
        assert len(cd["params"]) == len(specs)
        for got, sp in zip(cd["params"], specs):
            assert got["name"] == sp.name
            assert tuple(got["shape"]) == tuple(sp.shape)
    for art in man["artifacts"]:
        assert os.path.exists(os.path.join(ART_DIR, art["file"])), art["file"]
        assert art["config"] in man["configs"]


def test_manifest_decode_cache_shapes():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not exported")
    with open(path) as f:
        man = json.load(f)
    for art in man["artifacts"]:
        if art["kind"] != "decode":
            continue
        cfg = REGISTRY[art["config"]]
        by_name = {i[0]: i for i in art["inputs"]}
        assert by_name["k_cache"][2] == [
            cfg.n_layers, art["geom"]["b"], cfg.max_seq, cfg.k_cache_dims()]
        assert by_name["v_cache"][2] == [
            cfg.n_layers, art["geom"]["b"], cfg.max_seq, cfg.v_cache_dims()]


def test_hlo_text_is_parseable_header():
    """Every exported artifact must be HLO text (starts with HloModule)."""
    if not os.path.exists(os.path.join(ART_DIR, "manifest.json")):
        pytest.skip("artifacts not exported")
    count = 0
    for fn in os.listdir(ART_DIR):
        if fn.endswith(".hlo.txt"):
            with open(os.path.join(ART_DIR, fn)) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), fn
            count += 1
    assert count > 80
