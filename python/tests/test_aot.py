"""Export-path checks: the artifact plan is well-formed and the manifest
written by aot.py is consistent with the configs/model param specs the rust
runtime will rely on."""

import json
import os

import numpy as np
import pytest

from compile.aot import artifact_plan, build_entry
from compile.configs import (DECODE_BATCHES, KV_QUANTS, PREFILL_CHUNKS,
                             PREFILL_SEQ, REGISTRY, SERVE_CONFIGS,
                             config_dict, decode_tiers, train_geometry)
from compile import model as M

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_plan_names_unique():
    plan = artifact_plan()
    names = [n for n, _, _, _ in plan]
    assert len(names) == len(set(names))
    assert len(plan) > 80


@pytest.mark.parametrize("kind,cfgname,geom", [
    ("train", "copyback_ds4", {"b": 16, "s": 32}),
    ("qkft", "tinylm_ds32", {"b": 8, "s": 64}),
    ("evalloss", "tinylm_ds64", {"b": 8, "s": 64}),
    ("logits", "kvret_ds8", {"b": 32, "s": 24}),
    ("prefill", "servethin", {"s": 128}),
    ("decode", "servethin", {"b": 4, "n": 64}),
])
def test_build_entry_specs(kind, cfgname, geom):
    cfg = REGISTRY[cfgname]
    fn, specs, in_names, out_names = build_entry(kind, cfg, geom)
    assert len(specs) == len(in_names)
    nparams = len(M.param_specs(cfg))
    if kind in ("train", "qkft"):
        assert len(specs) == 3 * nparams + 5
    # parameter arg shapes must match the specs order exactly
    for s, p in zip(specs[:nparams], M.param_specs(cfg)):
        assert tuple(s.shape) == tuple(p.shape)


def test_decode_tiers_shape():
    assert decode_tiers(256) == [32, 64, 128, 256]
    assert decode_tiers(32) == [32]
    assert decode_tiers(48) == [32, 48]  # max_seq always included


def test_plan_covers_full_bucket_tier_grid():
    """Every serving config exports decode_{cfg}_b{B}_n{N} for the full
    (batch bucket x context tier) grid, plus the b=8 pallas column."""
    plan = artifact_plan()
    names = {n for n, _, _, _ in plan}
    for cfg_name in SERVE_CONFIGS:
        cfg = REGISTRY[cfg_name]
        for b in DECODE_BATCHES:
            for n in decode_tiers(cfg.max_seq):
                assert f"decode_{cfg_name}_b{b}_n{n}" in names
        for n in decode_tiers(cfg.max_seq):
            assert f"decode_{cfg_name}_b8_n{n}_pallas" in names


def test_plan_covers_q8_grid():
    """Every serving config exports `_q8` variants of the full decode
    (bucket x tier) grid, the b=8 pallas column, and every prefill chunk
    (ISSUE 4). The monolithic prefill is fp32-only by design."""
    plan = artifact_plan()
    names = {n for n, _, _, _ in plan}
    assert "q8" in KV_QUANTS
    for cfg_name in SERVE_CONFIGS:
        cfg = REGISTRY[cfg_name]
        for b in DECODE_BATCHES:
            for n in decode_tiers(cfg.max_seq):
                assert f"decode_{cfg_name}_b{b}_n{n}_q8" in names
        for n in decode_tiers(cfg.max_seq):
            assert f"decode_{cfg_name}_b8_n{n}_q8_pallas" in names
        for c in PREFILL_CHUNKS:
            assert f"prefill_{cfg_name}_c{c}_q8" in names
        assert f"prefill_{cfg_name}_s{PREFILL_SEQ}_q8" not in names


def test_q8_decode_entry_specs():
    """q8 decode entries carry int8 arenas + per-row fp32 scale planes and
    return the quantized delta rows plus their scales."""
    cfg = REGISTRY["servethin"]
    fn, specs, in_names, out_names = build_entry(
        "decode", cfg, {"b": 2, "n": 32, "quant": "q8"})
    assert out_names == ["logits", "k_cache", "k_scale", "v_cache",
                         "v_scale", "k_rows", "k_row_scale", "v_rows",
                         "v_row_scale", "attn_mass"]
    by_name = dict(zip(in_names, specs))
    assert tuple(by_name["k_cache"].shape) == (
        cfg.n_layers, 2, 32, cfg.k_cache_dims())
    assert str(by_name["k_cache"].dtype) == "int8"
    assert tuple(by_name["k_scale"].shape) == (cfg.n_layers, 2, 32)
    assert str(by_name["k_scale"].dtype) == "float32"
    assert str(by_name["v_cache"].dtype) == "int8"
    assert tuple(by_name["v_scale"].shape) == (cfg.n_layers, 2, 32)


def test_q8_prefill_chunk_entry_specs():
    cfg = REGISTRY["servethin"]
    fn, specs, in_names, out_names = build_entry(
        "prefill", cfg, {"c": 32, "quant": "q8"})
    assert out_names == ["last_logits", "k_cache", "k_scale", "v_cache",
                         "v_scale", "k_rows", "k_row_scale", "v_rows",
                         "v_row_scale"]
    by_name = dict(zip(in_names, specs))
    assert tuple(by_name["k_cache"].shape) == (
        cfg.n_layers, PREFILL_SEQ, cfg.k_cache_dims())
    assert str(by_name["k_cache"].dtype) == "int8"
    assert tuple(by_name["k_scale"].shape) == (cfg.n_layers, PREFILL_SEQ)
    assert tuple(by_name["tokens"].shape) == (1, 32)


def test_manifest_kv_quant_recorded():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not exported")
    with open(path) as f:
        man = json.load(f)
    assert "kv_quant" in man, \
        "stale pre-quantization manifest — re-run `make artifacts`"
    for cfg_name in SERVE_CONFIGS:
        assert man["kv_quant"][cfg_name] == list(KV_QUANTS)
        cfg = REGISTRY[cfg_name]
        for n in decode_tiers(cfg.max_seq):
            assert any(a["name"] == f"decode_{cfg_name}_b8_n{n}_q8"
                       for a in man["artifacts"])


def test_plan_covers_prefill_chunk_axis():
    """Every serving config exports prefill_{cfg}_c{C} for each chunk size,
    alongside the monolithic prefill_{cfg}_s{S}."""
    plan = artifact_plan()
    names = {n for n, _, _, _ in plan}
    for cfg_name in SERVE_CONFIGS:
        assert f"prefill_{cfg_name}_s{PREFILL_SEQ}" in names
        for c in PREFILL_CHUNKS:
            assert f"prefill_{cfg_name}_c{c}" in names


def test_prefill_chunk_entry_specs():
    """Chunk entries take the S-length arenas + (1,C) tokens + start/length
    scalars and return the delta rows the engine mirrors host-side."""
    cfg = REGISTRY["servethin"]
    _, specs, in_names, out_names = build_entry("prefill", cfg, {"c": 32})
    assert out_names == ["last_logits", "k_cache", "v_cache",
                         "k_rows", "v_rows"]
    by_name = dict(zip(in_names, specs))
    assert tuple(by_name["k_cache"].shape) == (
        cfg.n_layers, PREFILL_SEQ, cfg.k_cache_dims())
    assert tuple(by_name["v_cache"].shape) == (
        cfg.n_layers, PREFILL_SEQ, cfg.v_cache_dims())
    assert tuple(by_name["tokens"].shape) == (1, 32)
    assert tuple(by_name["start"].shape) == ()
    assert tuple(by_name["length"].shape) == ()


def test_manifest_prefill_chunks_recorded():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not exported")
    with open(path) as f:
        man = json.load(f)
    for cfg_name in SERVE_CONFIGS:
        assert man["prefill_chunks"][cfg_name] == list(PREFILL_CHUNKS)
        for c in PREFILL_CHUNKS:
            assert any(a["name"] == f"prefill_{cfg_name}_c{c}"
                       for a in man["artifacts"])


def test_decode_entry_returns_delta_rows():
    cfg = REGISTRY["servethin"]
    _, specs, in_names, out_names = build_entry(
        "decode", cfg, {"b": 2, "n": 32})
    assert out_names == ["logits", "k_cache", "v_cache", "k_rows", "v_rows",
                         "attn_mass"]
    by_name = dict(zip(in_names, specs))
    assert tuple(by_name["k_cache"].shape) == (
        cfg.n_layers, 2, 32, cfg.k_cache_dims())


def test_manifest_consistent_with_registry():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not exported (run `make artifacts`)")
    with open(path) as f:
        man = json.load(f)
    assert man["version"] == 1
    for name_, cd in man["configs"].items():
        cfg = REGISTRY[name_]
        want = config_dict(cfg)
        for k, v in want.items():
            assert cd[k] == v, (name_, k)
        specs = M.param_specs(cfg)
        assert len(cd["params"]) == len(specs)
        for got, sp in zip(cd["params"], specs):
            assert got["name"] == sp.name
            assert tuple(got["shape"]) == tuple(sp.shape)
    for art in man["artifacts"]:
        assert os.path.exists(os.path.join(ART_DIR, art["file"])), art["file"]
        assert art["config"] in man["configs"]


def test_manifest_decode_cache_shapes():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not exported")
    with open(path) as f:
        man = json.load(f)
    for art in man["artifacts"]:
        if art["kind"] != "decode":
            continue
        cfg = REGISTRY[art["config"]]
        tiers = man["decode_tiers"][art["config"]]
        assert tiers == decode_tiers(cfg.max_seq)
        n = art["geom"]["n"]
        assert n in tiers
        by_name = {i[0]: i for i in art["inputs"]}
        assert by_name["k_cache"][2] == [
            cfg.n_layers, art["geom"]["b"], n, cfg.k_cache_dims()]
        assert by_name["v_cache"][2] == [
            cfg.n_layers, art["geom"]["b"], n, cfg.v_cache_dims()]
        if art["geom"].get("quant") == "q8":
            assert by_name["k_cache"][1] == "int8"
            assert by_name["k_scale"][2] == [
                cfg.n_layers, art["geom"]["b"], n]
            assert by_name["k_scale"][1] == "float32"
            assert art["outputs"][-5:] == [
                "k_rows", "k_row_scale", "v_rows", "v_row_scale",
                "attn_mass"]
        else:
            assert by_name["k_cache"][1] == "float32"
            assert art["outputs"][-3:] == ["k_rows", "v_rows", "attn_mass"]


def test_gqa_serving_configs_grouped_geometry():
    """The GQA serving pair (ISSUE 5) caches KV-HEAD widths, not
    query-head widths: k_cache_dims = n_kv_heads * d_qk_head, so the
    composed grid shrinks K 16x (group 4x × rank 4x) before quantization
    even applies, while V shrinks by the group alone."""
    full = REGISTRY["servefull"]
    gqa = REGISTRY["servegqa"]
    thin = REGISTRY["servegqathin"]
    for cfg in (gqa, thin):
        assert cfg.attn == "gqa"
        assert cfg.n_heads == 8 and cfg.n_kv_heads == 2
        assert cfg.group == 4
        assert cfg.k_cache_dims() == cfg.n_kv_heads * cfg.d_qk_head
        assert cfg.max_seq == full.max_seq  # same tier table
    assert gqa.k_cache_dims() * 4 == full.k_cache_dims()
    assert thin.k_cache_dims() * 16 == full.k_cache_dims()
    assert thin.v_cache_dims() * 4 == full.v_cache_dims()
    assert thin.v_cache_dims() == gqa.v_cache_dims()


def test_gqa_decode_entry_specs_sized_by_kv_heads():
    """Exported gqa decode arenas carry the grouped widths end to end —
    the manifest shape the rust engine sizes its RowArenas by."""
    cfg = REGISTRY["servegqathin"]
    _, specs, in_names, _ = build_entry("decode", cfg, {"b": 4, "n": 32})
    by_name = dict(zip(in_names, specs))
    assert tuple(by_name["k_cache"].shape) == (cfg.n_layers, 4, 32, 4)
    assert tuple(by_name["v_cache"].shape) == (cfg.n_layers, 4, 32, 16)
    _, specs8, in_names8, _ = build_entry(
        "decode", cfg, {"b": 4, "n": 32, "quant": "q8"})
    by8 = dict(zip(in_names8, specs8))
    assert str(by8["k_cache"].dtype) == "int8"
    assert tuple(by8["k_cache"].shape) == (cfg.n_layers, 4, 32, 4)
    assert tuple(by8["k_scale"].shape) == (cfg.n_layers, 4, 32)


def test_hlo_text_is_parseable_header():
    """Every exported artifact must be HLO text (starts with HloModule)."""
    if not os.path.exists(os.path.join(ART_DIR, "manifest.json")):
        pytest.skip("artifacts not exported")
    count = 0
    for fn in os.listdir(ART_DIR):
        if fn.endswith(".hlo.txt"):
            with open(os.path.join(ART_DIR, fn)) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), fn
            count += 1
    assert count > 80


# --- export-time manifest validation (ISSUE 6) ---------------------------

import copy

from compile.aot import SCHEMA_VERSION, build_manifest, validate_manifest


@pytest.fixture(scope="module")
def fresh_manifest():
    """A real manifest built from the full artifact plan — spec construction
    only, no HLO lowering, so this is fast enough to run per test module."""
    artifacts = []
    for name, kind, cfg, geom in artifact_plan():
        _, specs, in_names, out_names = build_entry(kind, cfg, geom)
        artifacts.append({
            "name": name, "file": f"{name}.hlo.txt", "kind": kind,
            "config": cfg.name, "geom": dict(geom), "hash": "",
            "inputs": [[n_, str(s.dtype), list(s.shape)]
                       for n_, s in zip(in_names, specs)],
            "n_params": len(M.param_specs(cfg)),
            "outputs": out_names,
        })
    return build_manifest(artifacts)


def test_fresh_manifest_is_stamped_and_validates(fresh_manifest):
    assert fresh_manifest["schema_version"] == SCHEMA_VERSION == 2
    validate_manifest(fresh_manifest)  # must not raise


def test_validate_rejects_missing_schema_version(fresh_manifest):
    man = copy.deepcopy(fresh_manifest)
    del man["schema_version"]
    with pytest.raises(ValueError, match="schema-version"):
        validate_manifest(man)


def test_validate_rejects_missing_tier_artifact(fresh_manifest):
    man = copy.deepcopy(fresh_manifest)
    victim = "decode_servethin_b2_n64_q8"
    man["artifacts"] = [a for a in man["artifacts"] if a["name"] != victim]
    with pytest.raises(ValueError, match="grid-missing"):
        validate_manifest(man)


def test_validate_rejects_mismatched_k_cache_dims(fresh_manifest):
    man = copy.deepcopy(fresh_manifest)
    man["configs"]["servethin"]["k_cache_dims"] += 1
    with pytest.raises(ValueError, match="config-algebra"):
        validate_manifest(man)


def test_validate_rejects_q8_without_scale_plane(fresh_manifest):
    man = copy.deepcopy(fresh_manifest)
    for a in man["artifacts"]:
        if a["name"] == "decode_servethin_b1_n32_q8":
            a["inputs"] = [i for i in a["inputs"] if i[0] != "k_scale"]
            break
    else:
        pytest.fail("q8 decode artifact missing from the plan")
    with pytest.raises(ValueError, match="k_scale"):
        validate_manifest(man)


def test_validate_rejects_non_pow2_tier(fresh_manifest):
    man = copy.deepcopy(fresh_manifest)
    tiers = man["decode_tiers"]["servethin"]
    man["decode_tiers"]["servethin"] = [48] + tiers[1:]
    with pytest.raises(ValueError, match="tier-ladder"):
        validate_manifest(man)


def test_validate_rejects_non_dividing_chunk(fresh_manifest):
    man = copy.deepcopy(fresh_manifest)
    man["prefill_chunks"]["servethin"] = [24]
    with pytest.raises(ValueError, match="chunk-ladder"):
        validate_manifest(man)


def test_exported_manifest_validates():
    """The manifest on disk (if present and stamped) passes the same
    validation `thinkeys check` applies — guards the CI artifact cache."""
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not exported")
    with open(path) as f:
        man = json.load(f)
    if man.get("schema_version", 1) < SCHEMA_VERSION:
        pytest.skip("pre-schema-stamp manifest — re-run `make artifacts`")
    validate_manifest(man)
