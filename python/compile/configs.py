"""Experiment configuration registry (single source of truth, mirrored by
rust/src/model/config.rs via artifacts/manifest.json).

Every architecture evaluated in the paper maps to a config here, scaled to a
1-core CPU testbed (see DESIGN.md §2 for the substitution table):

- ``tinylm_*``   — vanilla transformer (learned positions, LayerNorm, GELU),
                   the GPT-2 stand-in for Experiments 3/4/5 and Table 1/2.
- ``copyback_*`` — Experiment 1 positional-selection task models.
- ``kvret_*``    — Experiment 2 content-selection task models.
- ``llama_*``    — LLaMA-style (RMSNorm, SwiGLU, RoPE, no bias) for
                   Experiments 6/7/7b and Table 16/17, incl. GQA/MLA variants.
- ``tinygqa_*``  — GQA (8q/2kv) vanilla model, the Mistral-7B stand-in for
                   Experiment 8 (learned positions keep factored-key SVD
                   semantics exact; see DESIGN.md on the RoPE caveat).
- ``serve*``     — serving artifacts (prefill/decode) for the engine.
"""

from dataclasses import dataclass, field, asdict
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch: str            # "vanilla" | "llama"
    attn: str            # "mha" | "gqa" | "mla"
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int         # query heads
    n_kv_heads: int      # kv heads (== n_heads for MHA)
    d_select: int        # TOTAL query/key dims across query heads
    d_ff: int
    max_seq: int         # longest sequence any artifact of this config sees
    # MLA-only:
    d_c: int = 0         # latent dim (cached)
    d_r: int = 0         # decoupled RoPE key dim (cached, shared across heads)

    @property
    def d_qk_head(self) -> int:
        return self.d_select // self.n_heads

    @property
    def d_v_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def group(self) -> int:
        """Query heads per KV head (GQA group size)."""
        return self.n_heads // self.n_kv_heads

    def validate(self) -> None:
        assert self.d_select % self.n_heads == 0, self.name
        assert self.d_model % self.n_heads == 0, self.name
        assert self.n_heads % self.n_kv_heads == 0, self.name
        if self.arch == "llama" and self.attn != "mla":
            assert self.d_qk_head % 2 == 0, f"{self.name}: RoPE needs even d_qk_head"
        if self.attn == "mla":
            assert self.d_c > 0 and self.d_r > 0 and self.d_r % 2 == 0, self.name

    # --- cache geometry (per token, per layer, in ELEMENTS) ---
    def k_cache_dims(self) -> int:
        if self.attn == "mla":
            return self.d_c + self.d_r  # joint latent + rope key
        return self.n_kv_heads * self.d_qk_head

    def v_cache_dims(self) -> int:
        if self.attn == "mla":
            return 0  # values reconstructed from the latent
        return self.n_kv_heads * self.d_v_head

    def kv_budget(self) -> int:
        """Per-token per-layer cache elements (the paper's 'KV budget')."""
        return self.k_cache_dims() + self.v_cache_dims()


def _v(name, vocab, d_model, n_layers, n_heads, d_select, d_ff, max_seq,
       n_kv_heads=None):
    return ModelConfig(
        name=name, arch="vanilla",
        attn="mha" if (n_kv_heads is None or n_kv_heads == n_heads) else "gqa",
        vocab=vocab, d_model=d_model, n_layers=n_layers, n_heads=n_heads,
        n_kv_heads=n_kv_heads if n_kv_heads is not None else n_heads,
        d_select=d_select, d_ff=d_ff, max_seq=max_seq)


def _l(name, vocab, d_model, n_layers, n_heads, d_select, d_ff, max_seq,
       n_kv_heads=None, attn="mha", d_c=0, d_r=0):
    return ModelConfig(
        name=name, arch="llama", attn=attn, vocab=vocab, d_model=d_model,
        n_layers=n_layers, n_heads=n_heads,
        n_kv_heads=n_kv_heads if n_kv_heads is not None else n_heads,
        d_select=d_select, d_ff=d_ff, max_seq=max_seq, d_c=d_c, d_r=d_r)


def build_registry() -> dict:
    cfgs = []

    # Experiment 1 — copy-back (positional selection), Table 12.
    for ds in (4, 8, 16, 32, 64):
        cfgs.append(_v(f"copyback_ds{ds}", 32, 64, 2, 4, ds, 256, 64))

    # Experiment 2 — key-value retrieval (content selection), Table 13.
    for ds in (4, 8, 16, 32, 64):
        cfgs.append(_v(f"kvret_ds{ds}", 48, 64, 4, 4, ds, 256, 24))

    # Experiments 3/4/5 — tinylm, the GPT-2 stand-in, Tables 1/2/14/15.
    # d_model 64 (the paper's own Exp 1-4 scale): the xla_extension 0.5.1
    # CPU compiler is ~5x slower than modern jaxlib, so LM sweeps are sized
    # for ~0.1-0.2 s/step on the 1-core testbed (DESIGN.md §2).
    for ds in (8, 16, 32, 64):
        cfgs.append(_v(f"tinylm_ds{ds}", 512, 64, 3, 8, ds, 256, 128))

    # Experiments 6/7/7b — LLaMA-style, Tables 3/4/5/16 + Figs 1/2.
    for ds in (8, 16, 32, 64):
        cfgs.append(_l(f"llama_ds{ds}", 512, 64, 3, 4, ds, 192, 128))

    # Table 17 — GQA and MLA baselines trained from scratch (LLaMA arch).
    # MHA KV budget = 128 el/token/layer; gqa2 = 64 (50%), gqa1 = 32 (75%);
    # mla56 = 64 (50%), mla36 = 44 (66%).
    cfgs.append(_l("llama_gqa2", 512, 64, 3, 4, 64, 192, 128, n_kv_heads=2))
    cfgs.append(_l("llama_gqa1", 512, 64, 3, 4, 64, 192, 128, n_kv_heads=1))
    cfgs.append(_l("llama_mla56", 512, 64, 3, 4, 64, 192, 128,
                   attn="mla", d_c=56, d_r=8))
    cfgs.append(_l("llama_mla36", 512, 64, 3, 4, 64, 192, 128,
                   attn="mla", d_c=36, d_r=8))

    # Experiment 8 — tinygqa, the Mistral-7B stand-in (GQA 8q/2kv, learned
    # positions so truncated-SVD key factoring is score-exact), Tables 7/8/9/19.
    # d_qk_head 8; factored ranks {4,2,1} per kv head = d_K/{2,4,8}.
    for ds in (8, 16, 32, 64):
        cfgs.append(_v(f"tinygqa_ds{ds}", 512, 64, 3, 8, ds, 256, 128,
                       n_kv_heads=2))

    # Serving configs: full model and its factored (/4) deployment.
    # max_seq here is the decode cache arena length N. Same family as
    # tinylm so the serve_e2e example serves a genuinely trained model.
    cfgs.append(_v("servefull", 512, 64, 3, 8, 64, 256, 256))
    cfgs.append(_v("servethin", 512, 64, 3, 8, 16, 256, 256))
    # GQA serving axis (ISSUE 5): the same family at 8 query / 2 kv heads
    # (the Mistral-style 4x group of tinygqa), full-key and thin-key
    # variants. Head grouping divides BOTH cache widths by the group;
    # thin keys then divide only K — the paper's §6 composition axis the
    # engine serves at runtime instead of quoting from roofline.rs:
    #   servefull     KD 64  VD 64   (baseline)
    #   servegqa      KD 16  VD 16   (4x group sharing)
    #   servegqathin  KD  4  VD 16   (group x rank: K 16x below baseline;
    #                                 x q8 element width = 64x payload)
    cfgs.append(_v("servegqa", 512, 64, 3, 8, 64, 256, 256, n_kv_heads=2))
    cfgs.append(_v("servegqathin", 512, 64, 3, 8, 16, 256, 256,
                   n_kv_heads=2))

    reg = {}
    for c in cfgs:
        c.validate()
        assert c.name not in reg, c.name
        reg[c.name] = c
    return reg


REGISTRY = build_registry()

# Training batch/seq per config family (also recorded in the manifest).
def train_geometry(cfg: ModelConfig):
    """(batch, seq) used by train/qkft/evalloss/logits artifacts."""
    fam = cfg.name.split("_")[0]
    if fam == "copyback":
        return 16, 32
    if fam == "kvret":
        return 32, 24
    # LM families: sized for the 1-core CPU testbed (see DESIGN.md §2) —
    # 512 tokens/step keeps a train step ~0.1s so full sweeps stay tractable.
    return 8, 64


DECODE_BATCHES = (1, 2, 4, 8, 16, 32)
PREFILL_SEQ = 128  # prompt bucket for serving prefill (B=1)

# The serving artifact families (ISSUE 5): every config here exports the
# full prefill + chunked-prefill + decode (bucket x tier x kv_quant) grid
# and is a valid `thinkeys serve --config` value. MHA full/thin plus the
# GQA (8q/2kv) full/thin pair — the grouped axis that composes with thin
# keys and q8 for the paper's 16x key-cache claim, measured end to end.
SERVE_CONFIGS = ("servefull", "servethin", "servegqa", "servegqathin")

# Chunked-prefill axis: besides the monolithic prefill_{cfg}_s{S} artifact,
# serving configs export resumable chunk artifacts prefill_{cfg}_c{C} that
# process C prompt positions against the S-length arena (ISSUE 3). The
# scheduler interleaves one chunk per round with decode steps so a long
# document never stalls interactive decode for a whole prompt; chunk sizes
# trade per-chunk overhead (C small -> more XLA dispatches per prompt)
# against decode stall (C large -> longer pause at each chunk boundary).
PREFILL_CHUNKS = (16, 32, 64)

# KV-cache quantization axis (ISSUE 4): besides the fp32 grid, serving
# configs export `_q8` variants of every decode artifact and every
# prefill-chunk artifact. q8 arenas are int8 with one fp32 scale per
# (layer, lane, position) cache row; rows are quantized on write inside
# the artifact and attention is dequant-fused (never materializes an fp32
# arena). Decode is bandwidth-bound (Eq. 10), so the 4x payload shrink
# composes multiplicatively with the r/d thin-key factor — the paper's
# "up to 16x combined key cache compression" claim made executable.
KV_QUANTS = ("fp32", "q8")

# Smallest decode cache-arena tier. Decode artifacts are specialized on a
# second axis besides the batch bucket: the arena length N, in powers of
# two from here up to the config's max_seq. The engine picks the smallest
# tier covering the longest live sequence, so arena memory, upload bytes,
# and per-step attention work scale with live lengths instead of the model
# max context (ISSUE 2 / Eq. 10: decode is bandwidth-bound on bytes/step).
DECODE_TIER_MIN = 32


def decode_tiers(max_seq):
    """Arena-length tiers for a serving config: powers of two from
    DECODE_TIER_MIN up to (and always including) max_seq."""
    tiers = []
    n = DECODE_TIER_MIN
    while n < max_seq:
        tiers.append(n)
        n *= 2
    tiers.append(max_seq)
    return tiers


def config_dict(cfg: ModelConfig) -> dict:
    d = asdict(cfg)
    d["d_qk_head"] = cfg.d_qk_head
    d["d_v_head"] = cfg.d_v_head
    d["k_cache_dims"] = cfg.k_cache_dims()
    d["v_cache_dims"] = cfg.v_cache_dims()
    d["kv_budget"] = cfg.kv_budget()
    return d
