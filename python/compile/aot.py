"""AOT export: lower every entry point to HLO *text* + write manifest.json.

This is the only python that ever runs (`make artifacts`); the rust binary
is self-contained afterwards. Interchange is HLO text, NOT serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Incremental: an artifact is re-lowered only if its content hash (config +
kind + geometry + source digest) changed since the last export.

Usage: cd python && python -m compile.aot [--out-dir ../artifacts] [--only PREFIX]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import (KV_QUANTS, REGISTRY, DECODE_BATCHES, PREFILL_CHUNKS,
                      PREFILL_SEQ, SERVE_CONFIGS, config_dict, decode_tiers,
                      train_geometry)
from . import model as M
from .kernels.asym_attention import vmem_report

F32 = jnp.float32
I32 = jnp.int32
I8 = jnp.int8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _param_arg_specs(cfg):
    return [_spec(s.shape) for s in M.param_specs(cfg)]


def _source_digest():
    h = hashlib.sha1()
    base = os.path.dirname(__file__)
    for rel in ("configs.py", "model.py", "kernels/ref.py",
                "kernels/asym_attention.py"):
        with open(os.path.join(base, rel), "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def artifact_plan():
    """Yield (artifact_name, kind, cfg, geometry dict)."""
    plan = []

    def add(kind, cfg, **geom):
        tag = "_".join(f"{k}{v}" for k, v in sorted(geom.items())
                       if k not in ("impl", "quant"))
        impl = geom.get("impl", "ref")
        quant = geom.get("quant", "fp32")
        suffix = f"_{tag}" if tag else ""
        if quant != "fp32":
            suffix += f"_{quant}"
        if impl != "ref":
            suffix += f"_{impl}"
        plan.append((f"{kind}_{cfg.name}{suffix}", kind, cfg, geom))

    trainables = (
        [f"copyback_ds{d}" for d in (4, 8, 16, 32, 64)] +
        [f"kvret_ds{d}" for d in (4, 8, 16, 32, 64)] +
        [f"tinylm_ds{d}" for d in (8, 16, 32, 64)] +
        [f"llama_ds{d}" for d in (8, 16, 32, 64)] +
        ["llama_gqa2", "llama_gqa1", "llama_mla56", "llama_mla36",
         "tinygqa_ds64", "servefull"])
    for name in trainables:
        cfg = REGISTRY[name]
        b, s = train_geometry(cfg)
        add("train", cfg, b=b, s=s)

    # QK-only fine-tuning (Exp 5/8, Tables 2/7/19). ds64 = identically
    # fine-tuned uncompressed control.
    for fam in ("tinylm", "tinygqa"):
        for d in (64, 32, 16, 8):
            cfg = REGISTRY[f"{fam}_ds{d}"]
            b, s = train_geometry(cfg)
            add("qkft", cfg, b=b, s=s)

    # Eval loss (PPL) for every config whose PPL we report.
    for name in ([f"tinylm_ds{d}" for d in (8, 16, 32, 64)] +
                 [f"llama_ds{d}" for d in (8, 16, 32, 64)] +
                 ["llama_gqa2", "llama_gqa1", "llama_mla56", "llama_mla36"] +
                 [f"tinygqa_ds{d}" for d in (8, 16, 32, 64)]):
        cfg = REGISTRY[name]
        b, s = train_geometry(cfg)
        add("evalloss", cfg, b=b, s=s)

    # Full logits (accuracy tasks + downstream probes + sampling eval).
    for name in ([f"copyback_ds{d}" for d in (4, 8, 16, 32, 64)] +
                 [f"kvret_ds{d}" for d in (4, 8, 16, 32, 64)] +
                 [f"tinylm_ds{d}" for d in (8, 16, 32, 64)] +
                 [f"tinygqa_ds{d}" for d in (8, 16, 32, 64)] +
                 [f"llama_ds{d}" for d in (8, 16, 32, 64)] +
                 list(SERVE_CONFIGS)):
        cfg = REGISTRY[name]
        b, s = train_geometry(cfg)
        add("logits", cfg, b=b, s=s)

    # Serving artifacts. Decode is specialized on (batch bucket, context
    # tier): the engine selects the smallest arena tier covering the
    # longest live sequence, so short-context serving never pays
    # max_seq-sized arenas (ISSUE 2). The GQA pair (ISSUE 5) exports the
    # identical grid at grouped cache widths — the kernels broadcast the
    # 2 kv heads across the 8 query heads in the index map, so the arenas
    # (and every byte the engine moves) shrink by the group factor.
    for name in SERVE_CONFIGS:
        cfg = REGISTRY[name]
        add("prefill", cfg, s=PREFILL_SEQ)
        # Resumable chunked-prefill artifacts (ref impl only; the chunk
        # attention is a C x S window the Pallas prefill kernel does not
        # cover): prefill_{cfg}_c{C}, recorded as manifest prefill_chunks.
        # The q8 column quantizes rows on write so the engine can chunk a
        # document straight into an int8 arena (manifest kv_quant).
        for c in PREFILL_CHUNKS:
            add("prefill", cfg, c=c)
            add("prefill", cfg, c=c, quant="q8")
        # Decode grid: (batch bucket x context tier x kv quant). The
        # monolithic prefill stays fp32-only: prefill is compute-bound
        # (§12), so quantization there buys nothing — the engine
        # quantizes parked rows host-side when serving in q8 mode.
        for b in DECODE_BATCHES:
            for n in decode_tiers(cfg.max_seq):
                for q in KV_QUANTS:
                    add("decode", cfg, b=b, n=n, quant=q)
        # Pallas-kernel path (Layer 1 lowered into the same HLO), both
        # quant columns at the b=8 bucket.
        add("prefill", cfg, s=PREFILL_SEQ, impl="pallas")
        for n in decode_tiers(cfg.max_seq):
            for q in KV_QUANTS:
                add("decode", cfg, b=8, n=n, quant=q, impl="pallas")
    return plan


def build_entry(kind, cfg, geom):
    """Returns (fn, arg_specs, input_names, output_names)."""
    nparams = len(M.param_specs(cfg))
    pnames = [s.name for s in M.param_specs(cfg)]
    impl = geom.get("impl", "ref")
    if kind in ("train", "qkft"):
        b, s = geom["b"], geom["s"]
        fn = M.make_train_step(cfg, "qk" if kind == "qkft" else "all",
                               impl=impl)
        specs = (_param_arg_specs(cfg) * 3 +
                 [_spec((b, s), I32), _spec((b, s), I32), _spec((b, s)),
                  _spec(()), _spec(())])
        names = (pnames + [f"m.{n}" for n in pnames] +
                 [f"v.{n}" for n in pnames] +
                 ["tokens", "targets", "mask", "lr", "step"])
        outs = (["loss"] + pnames + [f"m.{n}" for n in pnames] +
                [f"v.{n}" for n in pnames])
        return fn, specs, names, outs
    if kind == "evalloss":
        b, s = geom["b"], geom["s"]
        fn = M.make_evalloss(cfg, impl=impl)
        specs = _param_arg_specs(cfg) + [
            _spec((b, s), I32), _spec((b, s), I32), _spec((b, s))]
        return fn, specs, pnames + ["tokens", "targets", "mask"], \
            ["sum_nll", "sum_mask"]
    if kind == "logits":
        b, s = geom["b"], geom["s"]
        fn = M.make_logits(cfg, impl=impl)
        specs = _param_arg_specs(cfg) + [_spec((b, s), I32)]
        return fn, specs, pnames + ["tokens"], ["logits"]
    if kind == "prefill" and "c" in geom:
        c, s = geom["c"], PREFILL_SEQ
        kd = cfg.k_cache_dims()
        vd = cfg.v_cache_dims()
        if geom.get("quant", "fp32") == "q8":
            fn = M.make_prefill_chunk_q8(cfg, c, s, impl=impl)
            specs = _param_arg_specs(cfg) + [
                _spec((cfg.n_layers, s, kd), I8), _spec((cfg.n_layers, s)),
                _spec((cfg.n_layers, s, vd), I8), _spec((cfg.n_layers, s)),
                _spec((1, c), I32), _spec((), I32), _spec((), I32)]
            return fn, specs, \
                pnames + ["k_cache", "k_scale", "v_cache", "v_scale",
                          "tokens", "start", "length"], \
                ["last_logits", "k_cache", "k_scale", "v_cache", "v_scale",
                 "k_rows", "k_row_scale", "v_rows", "v_row_scale"]
        fn = M.make_prefill_chunk(cfg, c, s, impl=impl)
        specs = _param_arg_specs(cfg) + [
            _spec((cfg.n_layers, s, kd)), _spec((cfg.n_layers, s, vd)),
            _spec((1, c), I32), _spec((), I32), _spec((), I32)]
        return fn, specs, \
            pnames + ["k_cache", "v_cache", "tokens", "start", "length"], \
            ["last_logits", "k_cache", "v_cache", "k_rows", "v_rows"]
    if kind == "prefill":
        s = geom["s"]
        fn = M.make_prefill(cfg, s, impl=impl)
        specs = _param_arg_specs(cfg) + [_spec((1, s), I32), _spec((), I32)]
        return fn, specs, pnames + ["tokens", "length"], \
            ["last_logits", "k_cache", "v_cache"]
    if kind == "decode":
        b = geom["b"]
        kd = cfg.k_cache_dims()
        vd = cfg.v_cache_dims()
        n = geom.get("n", cfg.max_seq)
        if geom.get("quant", "fp32") == "q8":
            fn = M.make_decode_q8(cfg, b, n=n, impl=impl)
            specs = _param_arg_specs(cfg) + [
                _spec((cfg.n_layers, b, n, kd), I8),
                _spec((cfg.n_layers, b, n)),
                _spec((cfg.n_layers, b, n, vd), I8),
                _spec((cfg.n_layers, b, n)),
                _spec((b,), I32), _spec((b,), I32)]
            return fn, specs, \
                pnames + ["k_cache", "k_scale", "v_cache", "v_scale",
                          "tokens", "pos"], \
                ["logits", "k_cache", "k_scale", "v_cache", "v_scale",
                 "k_rows", "k_row_scale", "v_rows", "v_row_scale"]
        fn = M.make_decode(cfg, b, n=n, impl=impl)
        specs = _param_arg_specs(cfg) + [
            _spec((cfg.n_layers, b, n, kd)), _spec((cfg.n_layers, b, n, vd)),
            _spec((b,), I32), _spec((b,), I32)]
        return fn, specs, pnames + ["k_cache", "v_cache", "tokens", "pos"], \
            ["logits", "k_cache", "v_cache", "k_rows", "v_rows"]
    raise ValueError(kind)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None,
                    help="only export artifacts whose name starts with this")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest_path = os.path.join(out_dir, "manifest.json")
    prev = {}
    if os.path.exists(manifest_path) and not args.force:
        with open(manifest_path) as f:
            prev = {a["name"]: a for a in json.load(f).get("artifacts", [])}

    digest = _source_digest()
    plan = artifact_plan()
    artifacts = []
    n_built = n_skipped = 0
    for name, kind, cfg, geom in plan:
        fname = f"{name}.hlo.txt"
        fpath = os.path.join(out_dir, fname)
        h = hashlib.sha1(json.dumps(
            [digest, config_dict(cfg), kind, sorted(geom.items())],
            sort_keys=True, default=str).encode()).hexdigest()
        entry_meta = {
            "name": name, "file": fname, "kind": kind, "config": cfg.name,
            "geom": {k: v for k, v in geom.items()}, "hash": h,
        }
        fn, specs, in_names, out_names = build_entry(kind, cfg, geom)
        entry_meta["inputs"] = [
            [n_, str(s.dtype), list(s.shape)] for n_, s in zip(in_names, specs)]
        entry_meta["n_params"] = len(M.param_specs(cfg))
        entry_meta["outputs"] = out_names
        artifacts.append(entry_meta)
        if (not args.force and args.only is None and os.path.exists(fpath)
                and prev.get(name, {}).get("hash") == h):
            n_skipped += 1
            continue
        if args.only is not None and not name.startswith(args.only):
            if os.path.exists(fpath):
                n_skipped += 1
                continue
        print(f"[aot] lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(fpath, "w") as f:
            f.write(text)
        n_built += 1

    configs_out = {}
    for name_ in sorted({a["config"] for a in artifacts}):
        cfg = REGISTRY[name_]
        cd = config_dict(cfg)
        cd["params"] = [
            {"name": s.name, "shape": list(s.shape), "init": s.init,
             "std": s.std, "wd": s.wd, "qk": s.qk}
            for s in M.param_specs(cfg)]
        b, s = train_geometry(cfg)
        cd["train_batch"], cd["train_seq"] = b, s
        configs_out[name_] = cd

    manifest = {
        "version": 1,
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS,
                 "weight_decay": M.WEIGHT_DECAY},
        "decode_batches": list(DECODE_BATCHES),
        "decode_tiers": {
            name: decode_tiers(REGISTRY[name].max_seq)
            for name in sorted({a["config"] for a in artifacts
                                if a["kind"] == "decode"})},
        "prefill_seq": PREFILL_SEQ,
        "prefill_chunks": {
            name: list(PREFILL_CHUNKS)
            for name in sorted({a["config"] for a in artifacts
                                if a["kind"] == "prefill"
                                and "c" in a["geom"]})},
        # KV-cache quantization axis: serving config -> exported quant
        # modes. Manifests without this key are pre-quantization — the
        # rust Manifest::kv_quants_for falls back to ["fp32"] and the
        # engine refuses --kv-quant q8 rather than inventing names.
        "kv_quant": {
            name: list(KV_QUANTS)
            for name in sorted({a["config"] for a in artifacts
                                if a["kind"] == "decode"
                                and a["geom"].get("quant") == "q8"})},
        "configs": configs_out,
        "artifacts": artifacts,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)

    # L1 kernel report: VMEM/MXU estimates for the serving geometries.
    reports = []
    for name_ in SERVE_CONFIGS:
        cfg = REGISTRY[name_]
        reports.append(vmem_report(
            name_, 1, cfg.n_heads, cfg.n_kv_heads, PREFILL_SEQ,
            cfg.d_qk_head, cfg.d_v_head))
    with open(os.path.join(out_dir, "kernel_report.json"), "w") as f:
        json.dump(reports, f, indent=1)

    print(f"[aot] done: {n_built} built, {n_skipped} cached, "
          f"{len(artifacts)} total -> {out_dir}")


if __name__ == "__main__":
    main()
