"""AOT export: lower every entry point to HLO *text* + write manifest.json.

This is the only python that ever runs (`make artifacts`); the rust binary
is self-contained afterwards. Interchange is HLO text, NOT serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Incremental: an artifact is re-lowered only if its content hash (config +
kind + geometry + source digest) changed since the last export.

Usage: cd python && python -m compile.aot [--out-dir ../artifacts] [--only PREFIX]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import (KV_QUANTS, REGISTRY, DECODE_BATCHES, PREFILL_CHUNKS,
                      PREFILL_SEQ, SERVE_CONFIGS, config_dict, decode_tiers,
                      train_geometry)
from . import model as M
from .kernels.asym_attention import vmem_report

F32 = jnp.float32
I32 = jnp.int32
I8 = jnp.int8

# Export-contract revision stamped into manifest.json. Bump whenever the
# artifact naming scheme or the geometry contract changes; the rust side
# (`thinkeys check`, analysis::grid) refuses to audit older manifests and
# this module refuses to *write* one that violates its own contract
# (validate_manifest below).
SCHEMA_VERSION = 2


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _param_arg_specs(cfg):
    return [_spec(s.shape) for s in M.param_specs(cfg)]


def _source_digest():
    h = hashlib.sha1()
    base = os.path.dirname(__file__)
    for rel in ("configs.py", "model.py", "kernels/ref.py",
                "kernels/asym_attention.py"):
        with open(os.path.join(base, rel), "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def artifact_plan():
    """Yield (artifact_name, kind, cfg, geometry dict)."""
    plan = []

    def add(kind, cfg, **geom):
        tag = "_".join(f"{k}{v}" for k, v in sorted(geom.items())
                       if k not in ("impl", "quant"))
        impl = geom.get("impl", "ref")
        quant = geom.get("quant", "fp32")
        suffix = f"_{tag}" if tag else ""
        if quant != "fp32":
            suffix += f"_{quant}"
        if impl != "ref":
            suffix += f"_{impl}"
        plan.append((f"{kind}_{cfg.name}{suffix}", kind, cfg, geom))

    trainables = (
        [f"copyback_ds{d}" for d in (4, 8, 16, 32, 64)] +
        [f"kvret_ds{d}" for d in (4, 8, 16, 32, 64)] +
        [f"tinylm_ds{d}" for d in (8, 16, 32, 64)] +
        [f"llama_ds{d}" for d in (8, 16, 32, 64)] +
        ["llama_gqa2", "llama_gqa1", "llama_mla56", "llama_mla36",
         "tinygqa_ds64", "servefull"])
    for name in trainables:
        cfg = REGISTRY[name]
        b, s = train_geometry(cfg)
        add("train", cfg, b=b, s=s)

    # QK-only fine-tuning (Exp 5/8, Tables 2/7/19). ds64 = identically
    # fine-tuned uncompressed control.
    for fam in ("tinylm", "tinygqa"):
        for d in (64, 32, 16, 8):
            cfg = REGISTRY[f"{fam}_ds{d}"]
            b, s = train_geometry(cfg)
            add("qkft", cfg, b=b, s=s)

    # Eval loss (PPL) for every config whose PPL we report.
    for name in ([f"tinylm_ds{d}" for d in (8, 16, 32, 64)] +
                 [f"llama_ds{d}" for d in (8, 16, 32, 64)] +
                 ["llama_gqa2", "llama_gqa1", "llama_mla56", "llama_mla36"] +
                 [f"tinygqa_ds{d}" for d in (8, 16, 32, 64)]):
        cfg = REGISTRY[name]
        b, s = train_geometry(cfg)
        add("evalloss", cfg, b=b, s=s)

    # Full logits (accuracy tasks + downstream probes + sampling eval).
    for name in ([f"copyback_ds{d}" for d in (4, 8, 16, 32, 64)] +
                 [f"kvret_ds{d}" for d in (4, 8, 16, 32, 64)] +
                 [f"tinylm_ds{d}" for d in (8, 16, 32, 64)] +
                 [f"tinygqa_ds{d}" for d in (8, 16, 32, 64)] +
                 [f"llama_ds{d}" for d in (8, 16, 32, 64)] +
                 list(SERVE_CONFIGS)):
        cfg = REGISTRY[name]
        b, s = train_geometry(cfg)
        add("logits", cfg, b=b, s=s)

    # Serving artifacts. Decode is specialized on (batch bucket, context
    # tier): the engine selects the smallest arena tier covering the
    # longest live sequence, so short-context serving never pays
    # max_seq-sized arenas (ISSUE 2). The GQA pair (ISSUE 5) exports the
    # identical grid at grouped cache widths — the kernels broadcast the
    # 2 kv heads across the 8 query heads in the index map, so the arenas
    # (and every byte the engine moves) shrink by the group factor.
    for name in SERVE_CONFIGS:
        cfg = REGISTRY[name]
        add("prefill", cfg, s=PREFILL_SEQ)
        # Resumable chunked-prefill artifacts (ref impl only; the chunk
        # attention is a C x S window the Pallas prefill kernel does not
        # cover): prefill_{cfg}_c{C}, recorded as manifest prefill_chunks.
        # The q8 column quantizes rows on write so the engine can chunk a
        # document straight into an int8 arena (manifest kv_quant).
        for c in PREFILL_CHUNKS:
            add("prefill", cfg, c=c)
            add("prefill", cfg, c=c, quant="q8")
        # Decode grid: (batch bucket x context tier x kv quant). The
        # monolithic prefill stays fp32-only: prefill is compute-bound
        # (§12), so quantization there buys nothing — the engine
        # quantizes parked rows host-side when serving in q8 mode.
        for b in DECODE_BATCHES:
            for n in decode_tiers(cfg.max_seq):
                for q in KV_QUANTS:
                    add("decode", cfg, b=b, n=n, quant=q)
        # Pallas-kernel path (Layer 1 lowered into the same HLO), both
        # quant columns at the b=8 bucket.
        add("prefill", cfg, s=PREFILL_SEQ, impl="pallas")
        for n in decode_tiers(cfg.max_seq):
            for q in KV_QUANTS:
                add("decode", cfg, b=8, n=n, quant=q, impl="pallas")
    return plan


def build_entry(kind, cfg, geom):
    """Returns (fn, arg_specs, input_names, output_names)."""
    nparams = len(M.param_specs(cfg))
    pnames = [s.name for s in M.param_specs(cfg)]
    impl = geom.get("impl", "ref")
    if kind in ("train", "qkft"):
        b, s = geom["b"], geom["s"]
        fn = M.make_train_step(cfg, "qk" if kind == "qkft" else "all",
                               impl=impl)
        specs = (_param_arg_specs(cfg) * 3 +
                 [_spec((b, s), I32), _spec((b, s), I32), _spec((b, s)),
                  _spec(()), _spec(())])
        names = (pnames + [f"m.{n}" for n in pnames] +
                 [f"v.{n}" for n in pnames] +
                 ["tokens", "targets", "mask", "lr", "step"])
        outs = (["loss"] + pnames + [f"m.{n}" for n in pnames] +
                [f"v.{n}" for n in pnames])
        return fn, specs, names, outs
    if kind == "evalloss":
        b, s = geom["b"], geom["s"]
        fn = M.make_evalloss(cfg, impl=impl)
        specs = _param_arg_specs(cfg) + [
            _spec((b, s), I32), _spec((b, s), I32), _spec((b, s))]
        return fn, specs, pnames + ["tokens", "targets", "mask"], \
            ["sum_nll", "sum_mask"]
    if kind == "logits":
        b, s = geom["b"], geom["s"]
        fn = M.make_logits(cfg, impl=impl)
        specs = _param_arg_specs(cfg) + [_spec((b, s), I32)]
        return fn, specs, pnames + ["tokens"], ["logits"]
    if kind == "prefill" and "c" in geom:
        c, s = geom["c"], PREFILL_SEQ
        kd = cfg.k_cache_dims()
        vd = cfg.v_cache_dims()
        if geom.get("quant", "fp32") == "q8":
            fn = M.make_prefill_chunk_q8(cfg, c, s, impl=impl)
            specs = _param_arg_specs(cfg) + [
                _spec((cfg.n_layers, s, kd), I8), _spec((cfg.n_layers, s)),
                _spec((cfg.n_layers, s, vd), I8), _spec((cfg.n_layers, s)),
                _spec((1, c), I32), _spec((), I32), _spec((), I32)]
            return fn, specs, \
                pnames + ["k_cache", "k_scale", "v_cache", "v_scale",
                          "tokens", "start", "length"], \
                ["last_logits", "k_cache", "k_scale", "v_cache", "v_scale",
                 "k_rows", "k_row_scale", "v_rows", "v_row_scale"]
        fn = M.make_prefill_chunk(cfg, c, s, impl=impl)
        specs = _param_arg_specs(cfg) + [
            _spec((cfg.n_layers, s, kd)), _spec((cfg.n_layers, s, vd)),
            _spec((1, c), I32), _spec((), I32), _spec((), I32)]
        return fn, specs, \
            pnames + ["k_cache", "v_cache", "tokens", "start", "length"], \
            ["last_logits", "k_cache", "v_cache", "k_rows", "v_rows"]
    if kind == "prefill":
        s = geom["s"]
        fn = M.make_prefill(cfg, s, impl=impl)
        specs = _param_arg_specs(cfg) + [_spec((1, s), I32), _spec((), I32)]
        return fn, specs, pnames + ["tokens", "length"], \
            ["last_logits", "k_cache", "v_cache"]
    if kind == "decode":
        b = geom["b"]
        kd = cfg.k_cache_dims()
        vd = cfg.v_cache_dims()
        n = geom.get("n", cfg.max_seq)
        if geom.get("quant", "fp32") == "q8":
            fn = M.make_decode_q8(cfg, b, n=n, impl=impl)
            specs = _param_arg_specs(cfg) + [
                _spec((cfg.n_layers, b, n, kd), I8),
                _spec((cfg.n_layers, b, n)),
                _spec((cfg.n_layers, b, n, vd), I8),
                _spec((cfg.n_layers, b, n)),
                _spec((b,), I32), _spec((b,), I32)]
            return fn, specs, \
                pnames + ["k_cache", "k_scale", "v_cache", "v_scale",
                          "tokens", "pos"], \
                ["logits", "k_cache", "k_scale", "v_cache", "v_scale",
                 "k_rows", "k_row_scale", "v_rows", "v_row_scale",
                 "attn_mass"]
        fn = M.make_decode(cfg, b, n=n, impl=impl)
        specs = _param_arg_specs(cfg) + [
            _spec((cfg.n_layers, b, n, kd)), _spec((cfg.n_layers, b, n, vd)),
            _spec((b,), I32), _spec((b,), I32)]
        return fn, specs, pnames + ["k_cache", "v_cache", "tokens", "pos"], \
            ["logits", "k_cache", "v_cache", "k_rows", "v_rows",
             "attn_mass"]
    raise ValueError(kind)


def build_manifest(artifacts):
    """Assemble the manifest dict from finished artifact entries.

    Split from main() so tests can build (and validate) a real manifest
    without lowering a single HLO module — build_entry only constructs
    ShapeDtypeStructs, which is cheap.
    """
    configs_out = {}
    for name_ in sorted({a["config"] for a in artifacts}):
        cfg = REGISTRY[name_]
        cd = config_dict(cfg)
        cd["params"] = [
            {"name": s.name, "shape": list(s.shape), "init": s.init,
             "std": s.std, "wd": s.wd, "qk": s.qk}
            for s in M.param_specs(cfg)]
        b, s = train_geometry(cfg)
        cd["train_batch"], cd["train_seq"] = b, s
        configs_out[name_] = cd

    return {
        "version": 1,
        "schema_version": SCHEMA_VERSION,
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS,
                 "weight_decay": M.WEIGHT_DECAY},
        "decode_batches": list(DECODE_BATCHES),
        "decode_tiers": {
            name: decode_tiers(REGISTRY[name].max_seq)
            for name in sorted({a["config"] for a in artifacts
                                if a["kind"] == "decode"})},
        "prefill_seq": PREFILL_SEQ,
        "prefill_chunks": {
            name: list(PREFILL_CHUNKS)
            for name in sorted({a["config"] for a in artifacts
                                if a["kind"] == "prefill"
                                and "c" in a["geom"]})},
        # KV-cache quantization axis: serving config -> exported quant
        # modes. Manifests without this key are pre-quantization — the
        # rust Manifest::kv_quants_for falls back to ["fp32"] and the
        # engine refuses --kv-quant q8 rather than inventing names.
        "kv_quant": {
            name: list(KV_QUANTS)
            for name in sorted({a["config"] for a in artifacts
                                if a["kind"] == "decode"
                                and a["geom"].get("quant") == "q8"})},
        "configs": configs_out,
        "artifacts": artifacts,
    }


def _input_spec(art, name):
    for n_, dtype, shape in art["inputs"]:
        if n_ == name:
            return dtype, list(shape)
    return None


def validate_manifest(manifest):
    """Export-time mirror of `thinkeys check` (rust analysis::grid).

    Raises ValueError("{artifact}: {rule}: {detail}") on the first
    violation, so a broken grid can never be written to disk in the first
    place — the rust checker then guards the *cached* grid in CI.
    """
    def fail(artifact, rule, detail):
        raise ValueError(f"{artifact}: {rule}: {detail}")

    if manifest.get("schema_version") != SCHEMA_VERSION:
        fail("manifest", "schema-version",
             f"expected {SCHEMA_VERSION}, found "
             f"{manifest.get('schema_version')}")

    arts = {a["name"]: a for a in manifest["artifacts"]}
    if len(arts) != len(manifest["artifacts"]):
        fail("manifest", "grid-unique", "duplicate artifact names")

    # Config algebra: every derived dimension must re-derive.
    for name, c in manifest["configs"].items():
        if c["n_kv_heads"] == 0 or c["n_heads"] % c["n_kv_heads"]:
            fail(name, "config-algebra",
                 "GQA group {}/{} not integral".format(
                     c["n_heads"], c["n_kv_heads"]))
        if c["d_select"] % c["n_heads"]:
            fail(name, "config-algebra",
                 "d_select {} not divisible by {} heads".format(
                     c["d_select"], c["n_heads"]))
        if c["attn"] == "mla":
            k, v = c["d_c"] + c["d_r"], 0
        else:
            k = c["n_kv_heads"] * c["d_qk_head"]
            v = c["n_kv_heads"] * c["d_v_head"]
        if c["k_cache_dims"] != k or c["v_cache_dims"] != v:
            fail(name, "config-algebra",
                 "cache dims ({}, {}) != derived ({}, {})".format(
                     c["k_cache_dims"], c["v_cache_dims"], k, v))
        if c["kv_budget"] != k + v:
            fail(name, "config-algebra",
                 "kv_budget {} != {} + {}".format(c["kv_budget"], k, v))

    # Ladders: tiers ascending pow2 (final tier == max_seq), chunks
    # ascending and dividing prefill_seq.
    for name, tiers in manifest["decode_tiers"].items():
        if not tiers:
            fail(name, "tier-ladder", "empty tier ladder")
        if sorted(set(tiers)) != tiers:
            fail(name, "tier-ladder", f"not strictly ascending: {tiers}")
        for tier in tiers[:-1]:
            if tier & (tier - 1):
                fail(name, "tier-ladder",
                     f"non-final tier {tier} not a power of two")
        if tiers[-1] != manifest["configs"][name]["max_seq"]:
            fail(name, "tier-ladder",
                 "last tier {} != max_seq {}".format(
                     tiers[-1], manifest["configs"][name]["max_seq"]))
    for name, chunks in manifest["prefill_chunks"].items():
        if sorted(set(chunks)) != chunks:
            fail(name, "chunk-ladder", f"not strictly ascending: {chunks}")
        for c in chunks:
            if c == 0 or manifest["prefill_seq"] % c:
                fail(name, "chunk-ladder",
                     "chunk {} does not divide prefill_seq {}".format(
                         c, manifest["prefill_seq"]))

    # Decode grid completeness + per-artifact shape/dtype invariants.
    for cfg_name, tiers in manifest["decode_tiers"].items():
        c = manifest["configs"][cfg_name]
        quants = manifest["kv_quant"].get(cfg_name, ["fp32"])
        for b in manifest["decode_batches"]:
            for n in tiers:
                for q in quants:
                    suffix = "" if q == "fp32" else f"_{q}"
                    aname = f"decode_{cfg_name}_b{b}_n{n}{suffix}"
                    art = arts.get(aname)
                    if art is None:
                        fail(aname, "grid-missing",
                             f"cell (b={b}, n={n}, {q}) has no artifact")
                    payload = "int8" if q == "q8" else "float32"
                    for plane, width in (("k_cache", c["k_cache_dims"]),
                                         ("v_cache", c["v_cache_dims"])):
                        got = _input_spec(art, plane)
                        want = (payload, [c["n_layers"], b, n, width])
                        if got != want:
                            fail(aname, "artifact-geometry",
                                 f"{plane}: {got} != {want}")
                    for scale in ("k_scale", "v_scale"):
                        got = _input_spec(art, scale)
                        if q == "q8":
                            want = ("float32", [c["n_layers"], b, n])
                            if got != want:
                                fail(aname, "artifact-geometry",
                                     f"{scale}: {got} != {want} (q8 arenas "
                                     "carry one fp32 scale per row)")
                        elif got is not None:
                            fail(aname, "artifact-geometry",
                                 f"fp32 artifact carries a {scale} plane")
                    for vec in ("tokens", "pos"):
                        got = _input_spec(art, vec)
                        if got != ("int32", [b]):
                            fail(aname, "artifact-geometry",
                                 "{}: {} != ('int32', [{}])".format(
                                     vec, got, b))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None,
                    help="only export artifacts whose name starts with this")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest_path = os.path.join(out_dir, "manifest.json")
    prev = {}
    if os.path.exists(manifest_path) and not args.force:
        with open(manifest_path) as f:
            prev = {a["name"]: a for a in json.load(f).get("artifacts", [])}

    digest = _source_digest()
    plan = artifact_plan()
    artifacts = []
    n_built = n_skipped = 0
    for name, kind, cfg, geom in plan:
        fname = f"{name}.hlo.txt"
        fpath = os.path.join(out_dir, fname)
        h = hashlib.sha1(json.dumps(
            [digest, config_dict(cfg), kind, sorted(geom.items())],
            sort_keys=True, default=str).encode()).hexdigest()
        entry_meta = {
            "name": name, "file": fname, "kind": kind, "config": cfg.name,
            "geom": {k: v for k, v in geom.items()}, "hash": h,
        }
        fn, specs, in_names, out_names = build_entry(kind, cfg, geom)
        entry_meta["inputs"] = [
            [n_, str(s.dtype), list(s.shape)] for n_, s in zip(in_names, specs)]
        entry_meta["n_params"] = len(M.param_specs(cfg))
        entry_meta["outputs"] = out_names
        artifacts.append(entry_meta)
        if (not args.force and args.only is None and os.path.exists(fpath)
                and prev.get(name, {}).get("hash") == h):
            n_skipped += 1
            continue
        if args.only is not None and not name.startswith(args.only):
            if os.path.exists(fpath):
                n_skipped += 1
                continue
        print(f"[aot] lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(fpath, "w") as f:
            f.write(text)
        n_built += 1

    manifest = build_manifest(artifacts)
    validate_manifest(manifest)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)

    # L1 kernel report: VMEM/MXU estimates for the serving geometries.
    reports = []
    for name_ in SERVE_CONFIGS:
        cfg = REGISTRY[name_]
        reports.append(vmem_report(
            name_, 1, cfg.n_heads, cfg.n_kv_heads, PREFILL_SEQ,
            cfg.d_qk_head, cfg.d_v_head))
    with open(os.path.join(out_dir, "kernel_report.json"), "w") as f:
        json.dump(reports, f, indent=1)

    print(f"[aot] done: {n_built} built, {n_skipped} cached, "
          f"{len(artifacts)} total -> {out_dir}")


if __name__ == "__main__":
    main()
