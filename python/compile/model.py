"""Layer 2 — the JAX model family (build-time only; lowered to HLO text).

Implements every architecture the paper evaluates, all with *asymmetric
attention* (per-head d_qk decoupled from d_v, paper §2.1):

- ``vanilla``: learned positions, LayerNorm, GELU MLP, tied embeddings —
  the GPT-2-shaped family (Experiments 1-5, 8).
- ``llama``: RMSNorm, SwiGLU, RoPE, no biases, tied embeddings —
  Experiments 6/7/7b and the Table 17 GQA/MLA baselines.

Attention variants: MHA, GQA (n_kv_heads < n_heads), and MLA (joint latent
d_c + decoupled-RoPE key d_r, DeepSeek-V2 style).

Exported entry points (see aot.py) take FLAT positional tensor lists in the
order given by :func:`param_specs`; the rust runtime reconstructs that order
from artifacts/manifest.json.

Attention implementation is selectable: ``impl="ref"`` (XLA-fused jnp, the
default for training artifacts) or ``impl="pallas"`` (the Layer-1 kernel,
lowered into the same HLO via interpret=True).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.asym_attention import (pallas_attention_prefill,
                                     pallas_attention_decode,
                                     pallas_attention_decode_q8)

# AdamW constants (baked into the train-step artifacts; lr/step are args).
ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.1


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple
    init: str      # "normal" | "normal_scaled" | "zeros" | "ones"
    std: float     # for normal inits
    wd: bool       # weight decay applies
    qk: bool       # part of the QK projection set (trainable in qkft mode)


def param_specs(cfg: ModelConfig):
    """Ordered parameter list — THE flattening order for all artifacts."""
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dqk, dvh = cfg.d_qk_head, cfg.d_v_head
    scaled_std = 0.02 / (2.0 * cfg.n_layers) ** 0.5
    sp = []

    def p(name, shape, init="normal", std=0.02, wd=True, qk=False):
        sp.append(ParamSpec(name, tuple(shape), init, std, wd, qk))

    p("emb.tok", (cfg.vocab, d), wd=False)
    if cfg.arch == "vanilla":
        p("emb.pos", (cfg.max_seq, d), wd=False)
    for i in range(cfg.n_layers):
        L = f"l{i}"
        p(f"{L}.ln1.g", (d,), init="ones", wd=False)
        if cfg.arch == "vanilla":
            p(f"{L}.ln1.b", (d,), init="zeros", wd=False)
        if cfg.attn == "mla":
            p(f"{L}.attn.wq", (d, h * dqk), qk=True)
            p(f"{L}.attn.wqr", (d, h * cfg.d_r), qk=True)
            p(f"{L}.attn.wdkv", (d, cfg.d_c))
            p(f"{L}.attn.wkr", (d, cfg.d_r), qk=True)
            p(f"{L}.attn.wuk", (cfg.d_c, h * dqk), qk=True)
            p(f"{L}.attn.wuv", (cfg.d_c, h * dvh))
        else:
            p(f"{L}.attn.wq", (d, h * dqk), qk=True)
            p(f"{L}.attn.wk", (d, hkv * dqk), qk=True)
            p(f"{L}.attn.wv", (d, hkv * dvh))
        p(f"{L}.attn.wo", (h * dvh, d), init="normal_scaled", std=scaled_std)
        p(f"{L}.ln2.g", (d,), init="ones", wd=False)
        if cfg.arch == "vanilla":
            p(f"{L}.ln2.b", (d,), init="zeros", wd=False)
        p(f"{L}.mlp.w1", (d, cfg.d_ff))
        if cfg.arch == "llama":
            p(f"{L}.mlp.w3", (d, cfg.d_ff))
        p(f"{L}.mlp.w2", (cfg.d_ff, d), init="normal_scaled", std=scaled_std)
    p("ln_f.g", (d,), init="ones", wd=False)
    if cfg.arch == "vanilla":
        p("ln_f.b", (d,), init="zeros", wd=False)
    return sp


def init_params(cfg: ModelConfig, key):
    """Initialize params per the specs (python-side twin of rust model::init,
    used by the python tests)."""
    out = {}
    for s in param_specs(cfg):
        key, sub = jax.random.split(key)
        if s.init == "zeros":
            out[s.name] = jnp.zeros(s.shape, jnp.float32)
        elif s.init == "ones":
            out[s.name] = jnp.ones(s.shape, jnp.float32)
        else:
            out[s.name] = s.std * jax.random.normal(sub, s.shape, jnp.float32)
    return out


def unflatten(cfg: ModelConfig, flat):
    specs = param_specs(cfg)
    assert len(flat) == len(specs), (len(flat), len(specs))
    return {s.name: t for s, t in zip(specs, flat)}


def flatten(cfg: ModelConfig, params):
    return [params[s.name] for s in param_specs(cfg)]


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def layer_norm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def rms_norm(x, g):
    return x * jax.lax.rsqrt((x * x).mean(-1, keepdims=True) + 1e-5) * g


def rope(x, positions, base=10000.0):
    """Rotary embedding, split-half convention.

    x: (..., S, D) with D even; positions: (..., S) int32 broadcastable.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def _heads(x, n, dh):
    """(B, S, n*dh) -> (B, n, S, dh)"""
    b, s, _ = x.shape
    return x.reshape(b, s, n, dh).transpose(0, 2, 1, 3)


def _unheads(x):
    """(B, n, S, dh) -> (B, S, n*dh)"""
    b, n, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, n * dh)


# ---------------------------------------------------------------------------
# Attention (per layer): projections + kernel + output proj
# ---------------------------------------------------------------------------

def _attn_qkv(cfg, p, L, xn, positions):
    """Project to q, k, v head tensors (RoPE already applied where needed).

    Returns q (B,H,S,dq'), k (B,Hkv,S,dq'), v (B,Hkv,S,dv) where for MLA the
    q/k carry the concatenated [content | rope] dims.
    """
    h, hkv, dqk, dvh = cfg.n_heads, cfg.n_kv_heads, cfg.d_qk_head, cfg.d_v_head
    if cfg.attn == "mla":
        q = _heads(xn @ p[f"{L}.attn.wq"], h, dqk)
        qr = _heads(xn @ p[f"{L}.attn.wqr"], h, cfg.d_r)
        c = xn @ p[f"{L}.attn.wdkv"]                        # (B,S,d_c)
        kr = xn @ p[f"{L}.attn.wkr"]                        # (B,S,d_r) shared
        if cfg.arch == "llama":
            qr = rope(qr, positions[:, None, :])
            kr = rope(kr, positions)
        k = _heads(c @ p[f"{L}.attn.wuk"], h, dqk)
        v = _heads(c @ p[f"{L}.attn.wuv"], h, dvh)
        kr_b = jnp.broadcast_to(kr[:, None], (kr.shape[0], h) + kr.shape[1:])
        q = jnp.concatenate([q, qr], -1)
        k = jnp.concatenate([k, kr_b], -1)
        return q, k, v
    q = _heads(xn @ p[f"{L}.attn.wq"], h, dqk)
    k = _heads(xn @ p[f"{L}.attn.wk"], hkv, dqk)
    v = _heads(xn @ p[f"{L}.attn.wv"], hkv, dvh)
    if cfg.arch == "llama":
        q = rope(q, positions[:, None, :])
        k = rope(k, positions[:, None, :])
    return q, k, v


def _attention(cfg, q, k, v, lengths, impl):
    if impl == "pallas":
        return pallas_attention_prefill(q, k, v, lengths)
    return ref.attention_prefill(q, k, v, lengths)


def _mlp(cfg, p, L, xn):
    if cfg.arch == "llama":
        return (jax.nn.silu(xn @ p[f"{L}.mlp.w1"]) *
                (xn @ p[f"{L}.mlp.w3"])) @ p[f"{L}.mlp.w2"]
    return jax.nn.gelu(xn @ p[f"{L}.mlp.w1"]) @ p[f"{L}.mlp.w2"]


def _norm(cfg, p, name, x):
    if cfg.arch == "vanilla":
        return layer_norm(x, p[f"{name}.g"], p[f"{name}.b"])
    return rms_norm(x, p[f"{name}.g"])


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, p, tokens, lengths=None, impl="ref"):
    """tokens: (B, S) int32 -> logits (B, S, vocab) float32."""
    b, s = tokens.shape
    x = p["emb.tok"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.arch == "vanilla":
        x = x + p["emb.pos"][:s][None]
    for i in range(cfg.n_layers):
        L = f"l{i}"
        xn = _norm(cfg, p, f"{L}.ln1", x)
        q, k, v = _attn_qkv(cfg, p, L, xn, positions)
        o = _attention(cfg, q, k, v, lengths, impl)
        x = x + _unheads(o) @ p[f"{L}.attn.wo"]
        xn = _norm(cfg, p, f"{L}.ln2", x)
        x = x + _mlp(cfg, p, L, xn)
    x = _norm(cfg, p, "ln_f", x)
    return x @ p["emb.tok"].T  # tied embeddings


def masked_nll(logits, targets, mask):
    """Returns (sum of masked token NLLs, sum of mask)."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    return nll.sum(), mask.sum()


# ---------------------------------------------------------------------------
# Exported entry factories (each returns fn taking flat positional args)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, trainable="all", impl="ref"):
    """AdamW train step over flat params.

    args: *params, *m, *v, tokens(B,S)i32, targets(B,S)i32, mask(B,S)f32,
          lr f32, step f32 (1-based, for bias correction)
    returns: (loss, *new_params, *new_m, *new_v)
    """
    specs = param_specs(cfg)
    n = len(specs)
    train_mask = [s.qk if trainable == "qk" else True for s in specs]

    def loss_fn(plist, tokens, targets, mask):
        # freeze non-trainable params so backward prunes their grads
        plist = [t if tr else jax.lax.stop_gradient(t)
                 for t, tr in zip(plist, train_mask)]
        logits = forward(cfg, unflatten(cfg, plist), tokens, impl=impl)
        s, c = masked_nll(logits, targets, mask)
        return s / c

    def step_fn(*args):
        plist = list(args[:n])
        mlist = list(args[n:2 * n])
        vlist = list(args[2 * n:3 * n])
        tokens, targets, mask, lr, step = args[3 * n:]
        loss, grads = jax.value_and_grad(loss_fn)(plist, tokens, targets, mask)
        bc1 = 1.0 - ADAM_B1 ** step
        bc2 = 1.0 - ADAM_B2 ** step
        new_p, new_m, new_v = [], [], []
        for sp, tr, pt, mt, vt, gt in zip(specs, train_mask, plist, mlist,
                                          vlist, grads):
            if not tr:
                new_p.append(pt); new_m.append(mt); new_v.append(vt)
                continue
            mt = ADAM_B1 * mt + (1 - ADAM_B1) * gt
            vt = ADAM_B2 * vt + (1 - ADAM_B2) * gt * gt
            upd = (mt / bc1) / (jnp.sqrt(vt / bc2) + ADAM_EPS)
            if sp.wd:
                upd = upd + WEIGHT_DECAY * pt
            new_p.append(pt - lr * upd)
            new_m.append(mt)
            new_v.append(vt)
        return tuple([loss] + new_p + new_m + new_v)

    return step_fn


def make_evalloss(cfg: ModelConfig, impl="ref"):
    """args: *params, tokens, targets, mask -> (sum_nll, sum_mask)"""
    n = len(param_specs(cfg))

    def fn(*args):
        p = unflatten(cfg, list(args[:n]))
        tokens, targets, mask = args[n:]
        logits = forward(cfg, p, tokens)
        s, c = masked_nll(logits, targets, mask)
        return (s, c)

    return fn


def make_logits(cfg: ModelConfig, impl="ref"):
    """args: *params, tokens -> logits (B,S,V)"""
    n = len(param_specs(cfg))

    def fn(*args):
        p = unflatten(cfg, list(args[:n]))
        tokens = args[n]
        return (forward(cfg, p, tokens, impl=impl),)

    return fn


# ---------------------------------------------------------------------------
# Serving: prefill + decode with dense cache arenas
#
# Cache layout (flat trailing dim, mirrored by rust coordinator::kvcache):
#   k_cache: (L, B, N, KD)  KD = n_kv_heads * d_qk_head
#   v_cache: (L, B, N, VD)  VD = n_kv_heads * d_v_head
# ---------------------------------------------------------------------------

def _cache_dims(cfg):
    assert cfg.attn != "mla", "MLA serving artifacts not exported (see DESIGN)"
    return cfg.n_kv_heads * cfg.d_qk_head, cfg.n_kv_heads * cfg.d_v_head


def make_prefill(cfg: ModelConfig, seq, impl="ref"):
    """Single-request prefill.

    args: *params, tokens (1, seq) i32, length () i32
    returns: (last_logits (1, vocab), k_cache (L, seq, KD), v_cache (L, seq, VD))

    last_logits is taken at position length-1. Cache rows >= length are
    zeroed (the rust cache manager only copies rows < length anyway).
    """
    n = len(param_specs(cfg))
    kd, vd = _cache_dims(cfg)

    def fn(*args):
        p = unflatten(cfg, list(args[:n]))
        tokens, length = args[n], args[n + 1]
        b, s = tokens.shape
        lengths = jnp.reshape(length, (1,)).astype(jnp.int32)
        x = p["emb.tok"][tokens]
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if cfg.arch == "vanilla":
            x = x + p["emb.pos"][:s][None]
        kcs, vcs = [], []
        valid = (jnp.arange(s) < length)[None, :, None].astype(jnp.float32)
        for i in range(cfg.n_layers):
            L = f"l{i}"
            xn = _norm(cfg, p, f"{L}.ln1", x)
            q, k, v = _attn_qkv(cfg, p, L, xn, positions)
            kcs.append((_unheads(k) * valid)[0])   # (seq, KD)
            vcs.append((_unheads(v) * valid)[0])
            if impl == "pallas":
                o = pallas_attention_prefill(q, k, v, lengths)
            else:
                o = ref.attention_prefill(q, k, v, lengths)
            x = x + _unheads(o) @ p[f"{L}.attn.wo"]
            xn = _norm(cfg, p, f"{L}.ln2", x)
            x = x + _mlp(cfg, p, L, xn)
        x = _norm(cfg, p, "ln_f", x)
        last = x[0, length - 1][None]              # (1, d)
        logits = last @ p["emb.tok"].T
        return (logits, jnp.stack(kcs), jnp.stack(vcs))

    return fn


def make_prefill_chunk(cfg: ModelConfig, chunk, seq, impl="ref"):
    """Resumable chunked prefill: process prompt positions
    [start, start+chunk) against a `seq`-length cache arena already holding
    rows [0, start).

    args: *params, k_cache (L, seq, KD), v_cache (L, seq, VD),
          tokens (1, chunk) i32, start () i32, length () i32
    returns: (last_logits (1, vocab), k_cache', v_cache',
              k_rows (L, chunk, KD), v_rows (L, chunk, VD))

    `length` is the TOTAL prompt length; rows at positions >= length are
    zeroed exactly as make_prefill zeroes them, so running ceil(p/chunk)
    chunks leaves the arena bit-identical to the single-shot artifact (the
    parity contract enforced by rust/tests/serving_e2e.rs). last_logits is
    taken at the last valid position covered by this chunk — only the
    final chunk's value is meaningful (the others are mid-prompt logits).
    k_rows/v_rows are this chunk's written rows — the delta the engine
    scatters into its host mirror so chunked prefill never downloads the
    full arenas between chunks.
    """
    assert impl == "ref", "chunked prefill is exported ref-only (see aot.py)"
    n = len(param_specs(cfg))
    _cache_dims(cfg)  # assert non-MLA

    def fn(*args):
        p = unflatten(cfg, list(args[:n]))
        k_cache, v_cache, tokens, start, length = args[n:]
        b, c = tokens.shape                          # (1, chunk)
        qpos = start + jnp.arange(c, dtype=jnp.int32)[None]   # (1, c) absolute
        x = p["emb.tok"][tokens]
        if cfg.arch == "vanilla":
            x = x + jnp.take(p["emb.pos"], qpos[0], axis=0)[None]
        valid = (qpos[0] < length)[None, :, None].astype(jnp.float32)
        new_k, new_v, row_k, row_v = [], [], [], []
        hkv, dqk, dvh = cfg.n_kv_heads, cfg.d_qk_head, cfg.d_v_head
        for i in range(cfg.n_layers):
            L = f"l{i}"
            xn = _norm(cfg, p, f"{L}.ln1", x)
            q, k, v = _attn_qkv(cfg, p, L, xn, qpos)  # (1,H,c,dqk) etc.
            krows = (_unheads(k) * valid)[0]          # (c, KD)
            vrows = (_unheads(v) * valid)[0]          # (c, VD)
            kc = jax.lax.dynamic_update_slice(k_cache[i], krows, (start, 0))
            vc = jax.lax.dynamic_update_slice(v_cache[i], vrows, (start, 0))
            new_k.append(kc)
            new_v.append(vc)
            row_k.append(krows)
            row_v.append(vrows)
            kh = kc.reshape(seq, hkv, dqk).transpose(1, 0, 2)[None]
            vh = vc.reshape(seq, hkv, dvh).transpose(1, 0, 2)[None]
            o = ref.attention_prefill_chunk(q, kh, vh, qpos)
            x = x + _unheads(o) @ p[f"{L}.attn.wo"]
            xn = _norm(cfg, p, f"{L}.ln2", x)
            x = x + _mlp(cfg, p, L, xn)
        x = _norm(cfg, p, "ln_f", x)
        last = x[0, jnp.clip(length - 1 - start, 0, c - 1)][None]  # (1, d)
        logits = last @ p["emb.tok"].T
        return (logits, jnp.stack(new_k), jnp.stack(new_v),
                jnp.stack(row_k), jnp.stack(row_v))

    return fn


def make_prefill_chunk_q8(cfg: ModelConfig, chunk, seq, impl="ref"):
    """Resumable chunked prefill over QUANTIZED arenas (ISSUE 4): the
    int8 twin of :func:`make_prefill_chunk`. New rows are computed in
    fp32, quantized on write (per-row symmetric int8, one fp32 scale per
    (layer, position) cache row), and the chunk's attention reads the
    quantized arena through the dequant-fused kernel — so the chunk sees
    exactly the same values a later decode step will see.

    args: *params, k_cache (L, seq, KD) i8, k_scale (L, seq) f32,
          v_cache (L, seq, VD) i8, v_scale (L, seq) f32,
          tokens (1, chunk) i32, start () i32, length () i32
    returns: (last_logits (1, vocab), k_cache', k_scale', v_cache',
              v_scale', k_rows (L, chunk, KD) i8, k_row_scale (L, chunk),
              v_rows (L, chunk, VD) i8, v_row_scale (L, chunk))

    Masking/positions follow make_prefill_chunk exactly; rows >= length
    are zero (scale = eps, codes = 0), so the parked arena is identical
    whatever chunk schedule produced it.
    """
    assert impl == "ref", "q8 chunked prefill is exported ref-only"
    n = len(param_specs(cfg))
    _cache_dims(cfg)  # assert non-MLA

    def fn(*args):
        p = unflatten(cfg, list(args[:n]))
        (k_cache, k_scale, v_cache, v_scale, tokens, start,
         length) = args[n:]
        b, c = tokens.shape                          # (1, chunk)
        qpos = start + jnp.arange(c, dtype=jnp.int32)[None]   # (1, c)
        x = p["emb.tok"][tokens]
        if cfg.arch == "vanilla":
            x = x + jnp.take(p["emb.pos"], qpos[0], axis=0)[None]
        valid = (qpos[0] < length)[None, :, None].astype(jnp.float32)
        new_k, new_ks, new_v, new_vs = [], [], [], []
        row_k, row_ks, row_v, row_vs = [], [], [], []
        hkv, dqk, dvh = cfg.n_kv_heads, cfg.d_qk_head, cfg.d_v_head
        for i in range(cfg.n_layers):
            L = f"l{i}"
            xn = _norm(cfg, p, f"{L}.ln1", x)
            q, k, v = _attn_qkv(cfg, p, L, xn, qpos)  # (1,H,c,dqk) etc.
            krows = (_unheads(k) * valid)[0]          # (c, KD) f32
            vrows = (_unheads(v) * valid)[0]          # (c, VD) f32
            kq, ks = ref.quantize_rows(krows)         # (c, KD) i8, (c,)
            vq, vs = ref.quantize_rows(vrows)
            kc = jax.lax.dynamic_update_slice(k_cache[i], kq, (start, 0))
            ksc = jax.lax.dynamic_update_slice(k_scale[i], ks, (start,))
            vc = jax.lax.dynamic_update_slice(v_cache[i], vq, (start, 0))
            vsc = jax.lax.dynamic_update_slice(v_scale[i], vs, (start,))
            new_k.append(kc)
            new_ks.append(ksc)
            new_v.append(vc)
            new_vs.append(vsc)
            row_k.append(kq)
            row_ks.append(ks)
            row_v.append(vq)
            row_vs.append(vs)
            kh = kc.reshape(seq, hkv, dqk).transpose(1, 0, 2)[None]
            vh = vc.reshape(seq, hkv, dvh).transpose(1, 0, 2)[None]
            o = ref.attention_prefill_chunk_q8(
                q, kh, ksc[None], vh, vsc[None], qpos)
            x = x + _unheads(o) @ p[f"{L}.attn.wo"]
            xn = _norm(cfg, p, f"{L}.ln2", x)
            x = x + _mlp(cfg, p, L, xn)
        x = _norm(cfg, p, "ln_f", x)
        last = x[0, jnp.clip(length - 1 - start, 0, c - 1)][None]  # (1, d)
        logits = last @ p["emb.tok"].T
        return (logits, jnp.stack(new_k), jnp.stack(new_ks),
                jnp.stack(new_v), jnp.stack(new_vs),
                jnp.stack(row_k), jnp.stack(row_ks),
                jnp.stack(row_v), jnp.stack(row_vs))

    return fn


def make_decode(cfg: ModelConfig, batch, n=None, impl="ref"):
    """Batched single-token decode against dense cache arenas.

    ``n`` is the cache arena length (a context tier <= cfg.max_seq; defaults
    to cfg.max_seq). Artifacts are exported for every (batch bucket, tier)
    pair so serving cost scales with live context, not model max context.

    args: *params, k_cache (L,B,N,KD), v_cache (L,B,N,VD),
          tokens (B,) i32, pos (B,) i32   [pos = index of THIS token]
    returns: (logits (B, vocab), k_cache', v_cache',
              k_rows (L,B,KD), v_rows (L,B,VD), attn_mass (B,N))

    k_rows/v_rows are the cache rows written THIS step (one per lane per
    layer) — the delta the host mirrors in O(L*B*(KD+VD)) per step instead
    of downloading the full arenas on membership changes.

    attn_mass is the per-row post-softmax attention mass of THIS step,
    meaned over layers and heads (rows past pos are exactly 0) — the
    score plane the eviction policies rank cache rows by (ISSUE 10).
    """
    nparams = len(param_specs(cfg))
    hkv, dqk, dvh = cfg.n_kv_heads, cfg.d_qk_head, cfg.d_v_head
    N = cfg.max_seq if n is None else n
    assert N <= cfg.max_seq, (N, cfg.max_seq)

    def write_row(cache_layer, row, pos):
        """cache_layer (B,N,D), row (B,D), pos (B,) -> updated (B,N,D)."""
        return jax.vmap(
            lambda c, r, q: jax.lax.dynamic_update_slice(c, r[None], (q, 0))
        )(cache_layer, row, pos)

    def fn(*args):
        p = unflatten(cfg, list(args[:nparams]))
        k_cache, v_cache, tokens, pos = args[nparams:]
        b = tokens.shape[0]
        x = p["emb.tok"][tokens][:, None]            # (B,1,d)
        positions = pos[:, None]                     # (B,1)
        if cfg.arch == "vanilla":
            x = x + jnp.take(p["emb.pos"], pos, axis=0)[:, None]
        new_k, new_v, row_k, row_v, mass = [], [], [], [], []
        for i in range(cfg.n_layers):
            L = f"l{i}"
            xn = _norm(cfg, p, f"{L}.ln1", x)
            q, k, v = _attn_qkv(cfg, p, L, xn, positions)  # (B,H,1,dqk) etc.
            krow = _unheads(k)[:, 0]                       # (B, KD)
            vrow = _unheads(v)[:, 0]                       # (B, VD)
            kc = write_row(k_cache[i], krow, pos)
            vc = write_row(v_cache[i], vrow, pos)
            new_k.append(kc)
            new_v.append(vc)
            row_k.append(krow)
            row_v.append(vrow)
            kh = kc.reshape(b, N, hkv, dqk).transpose(0, 2, 1, 3)
            vh = vc.reshape(b, N, hkv, dvh).transpose(0, 2, 1, 3)
            if impl == "pallas":
                o, w = pallas_attention_decode(q[:, :, 0], kh, vh, pos,
                                               return_mass=True)
            else:
                o, w = ref.attention_decode(q[:, :, 0], kh, vh, pos,
                                            return_mass=True)
            mass.append(w)
            x = x + (o.reshape(b, 1, -1) @ p[f"{L}.attn.wo"])
            xn = _norm(cfg, p, f"{L}.ln2", x)
            x = x + _mlp(cfg, p, L, xn)
        x = _norm(cfg, p, "ln_f", x)
        logits = x[:, 0] @ p["emb.tok"].T
        attn_mass = jnp.mean(jnp.stack(mass), axis=0)    # (B, N)
        return (logits, jnp.stack(new_k), jnp.stack(new_v),
                jnp.stack(row_k), jnp.stack(row_v), attn_mass)

    return fn


def make_decode_q8(cfg: ModelConfig, batch, n=None, impl="ref"):
    """Batched single-token decode over QUANTIZED cache arenas (ISSUE 4).

    The arena is int8 with one fp32 scale per (layer, lane, position)
    cache row; this step's K/V rows are computed in fp32, quantized on
    write, and attention streams the int8 arena through the dequant-fused
    kernel (ref or the Pallas q8 kernel) — the fp32 arena never exists.

    args: *params, k_cache (L,B,N,KD) i8, k_scale (L,B,N) f32,
          v_cache (L,B,N,VD) i8, v_scale (L,B,N) f32,
          tokens (B,) i32, pos (B,) i32
    returns: (logits (B, vocab), k_cache', k_scale', v_cache', v_scale',
              k_rows (L,B,KD) i8, k_row_scale (L,B) f32,
              v_rows (L,B,VD) i8, v_row_scale (L,B) f32,
              attn_mass (B,N) f32)

    k_rows/k_row_scale etc. are the delta the host mirrors — int8 codes
    plus scales, so per-step host traffic also shrinks ~4x vs fp32.
    """
    nparams = len(param_specs(cfg))
    hkv, dqk, dvh = cfg.n_kv_heads, cfg.d_qk_head, cfg.d_v_head
    N = cfg.max_seq if n is None else n
    assert N <= cfg.max_seq, (N, cfg.max_seq)

    def write_row(cache_layer, row, pos):
        """cache_layer (B,N,D), row (B,D), pos (B,) -> updated (B,N,D)."""
        return jax.vmap(
            lambda c, r, q: jax.lax.dynamic_update_slice(c, r[None], (q, 0))
        )(cache_layer, row, pos)

    def write_scale(scale_layer, s, pos):
        """scale_layer (B,N), s (B,), pos (B,) -> updated (B,N)."""
        return jax.vmap(
            lambda c, r, q: jax.lax.dynamic_update_slice(c, r[None], (q,))
        )(scale_layer, s, pos)

    def fn(*args):
        p = unflatten(cfg, list(args[:nparams]))
        k_cache, k_scale, v_cache, v_scale, tokens, pos = args[nparams:]
        b = tokens.shape[0]
        x = p["emb.tok"][tokens][:, None]            # (B,1,d)
        positions = pos[:, None]                     # (B,1)
        if cfg.arch == "vanilla":
            x = x + jnp.take(p["emb.pos"], pos, axis=0)[:, None]
        new_k, new_ks, new_v, new_vs = [], [], [], []
        row_k, row_ks, row_v, row_vs = [], [], [], []
        mass = []
        for i in range(cfg.n_layers):
            L = f"l{i}"
            xn = _norm(cfg, p, f"{L}.ln1", x)
            q, k, v = _attn_qkv(cfg, p, L, xn, positions)  # (B,H,1,dqk) etc.
            krow = _unheads(k)[:, 0]                       # (B, KD) f32
            vrow = _unheads(v)[:, 0]                       # (B, VD) f32
            kq, ks = ref.quantize_rows(krow)               # (B, KD) i8, (B,)
            vq, vs = ref.quantize_rows(vrow)
            kc = write_row(k_cache[i], kq, pos)
            ksc = write_scale(k_scale[i], ks, pos)
            vc = write_row(v_cache[i], vq, pos)
            vsc = write_scale(v_scale[i], vs, pos)
            new_k.append(kc)
            new_ks.append(ksc)
            new_v.append(vc)
            new_vs.append(vsc)
            row_k.append(kq)
            row_ks.append(ks)
            row_v.append(vq)
            row_vs.append(vs)
            kh = kc.reshape(b, N, hkv, dqk).transpose(0, 2, 1, 3)
            vh = vc.reshape(b, N, hkv, dvh).transpose(0, 2, 1, 3)
            if impl == "pallas":
                o, w = pallas_attention_decode_q8(q[:, :, 0], kh, ksc, vh,
                                                  vsc, pos,
                                                  return_mass=True)
            else:
                o, w = ref.attention_decode_q8(q[:, :, 0], kh, ksc, vh,
                                               vsc, pos, return_mass=True)
            mass.append(w)
            x = x + (o.reshape(b, 1, -1) @ p[f"{L}.attn.wo"])
            xn = _norm(cfg, p, f"{L}.ln2", x)
            x = x + _mlp(cfg, p, L, xn)
        x = _norm(cfg, p, "ln_f", x)
        logits = x[:, 0] @ p["emb.tok"].T
        attn_mass = jnp.mean(jnp.stack(mass), axis=0)    # (B, N)
        return (logits, jnp.stack(new_k), jnp.stack(new_ks),
                jnp.stack(new_v), jnp.stack(new_vs),
                jnp.stack(row_k), jnp.stack(row_ks),
                jnp.stack(row_v), jnp.stack(row_vs), attn_mass)

    return fn
