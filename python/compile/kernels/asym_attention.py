"""Pallas asymmetric-attention kernels (Layer 1).

The paper's hot spot: attention where the query/key dimension ``d_qk_head``
is much smaller than the value dimension ``d_v_head`` (thin keys, full
values). Two kernels:

- :func:`pallas_attention_prefill` — causal flash attention over a prompt,
  online-softmax so the (S, S) score matrix never materializes.
- :func:`pallas_attention_decode` — one query token against a dense KV
  arena, streaming the *thin* key cache in tiles.

TPU adaptation (DESIGN.md §7). The paper's H100 framing (warps, SRAM tiles,
HBM roofline) maps to TPU as:

- BlockSpecs express the HBM->VMEM schedule the paper expressed with
  threadblocks: the grid walks (batch, q-head, q-tile, kv-tile); K tiles are
  (block_k, d_qk_head) — 4x smaller than full-dim keys at d_select=d/4, so
  a 4x longer context fits per VMEM residency.
- GQA is expressed in the *index map* (kv head = q head // group), never by
  materializing repeated K/V in HBM.
- The online-softmax accumulator lives in revisited output blocks
  (``dimension_semantics``: the kv-tile axis is a reduction axis), the
  canonical Pallas reduction pattern.
- MXU note: QK^T contracts over d_qk_head in {2..32}, under-filling the
  128-wide MXU contraction; thin keys deliberately trade contraction fill
  for 4x less K-cache bandwidth — the right trade for bandwidth-bound
  decode. Lane padding for real-TPU Mosaic lowering would pad d_qk_head to
  the 8-sublane multiple; under ``interpret=True`` (mandatory here: the CPU
  PJRT plugin cannot run Mosaic custom-calls) shapes are unconstrained.

Correctness is pinned to ``ref.py`` by ``python/tests/test_kernel.py``
(hypothesis sweeps shapes/dtypes/group sizes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _cdiv(a, b):
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# Prefill kernel
# ---------------------------------------------------------------------------

def _prefill_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref, *,
                    scale, block_q, block_k, n_k_blocks, causal):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0, 0]                      # (block_q, d_qk)
    k = k_ref[0, 0]                      # (block_k, d_qk)
    v = v_ref[0, 0]                      # (block_k, d_v)
    s = jnp.dot(q, k.T) * scale          # (block_q, block_k)
    s = s + bias_ref[0][None, :]         # length mask: 0 valid / NEG_INF pad
    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_ref[0, 0]                 # (block_q,)
    l_prev = l_ref[0, 0]
    o_prev = o_ref[0, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + p.sum(axis=-1)
    o_new = o_prev * alpha[:, None] + jnp.dot(p, v)
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new

    @pl.when(ik == n_k_blocks - 1)
    def _final():
        o_ref[0, 0] = o_new / l_new[:, None]

    @pl.when(ik != n_k_blocks - 1)
    def _mid():
        o_ref[0, 0] = o_new


def pallas_attention_prefill(q, k, v, lengths=None, causal=True,
                             block_q=32, block_k=32, interpret=True):
    """Flash-style asymmetric attention. Shapes as in ref.attention_prefill.

    q: (B, H, S, dqk)  k: (B, Hkv, S, dqk)  v: (B, Hkv, S, dv) -> (B, H, S, dv)
    """
    b, h, s, dqk = q.shape
    hkv = k.shape[1]
    dv = v.shape[3]
    group = h // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / float(dqk) ** 0.5

    if lengths is None:
        bias = jnp.zeros((b, s), q.dtype)
    else:
        bias = jnp.where(jnp.arange(s)[None, :] < lengths[:, None],
                         0.0, NEG_INF).astype(q.dtype)

    kernel = functools.partial(
        _prefill_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_k_blocks=nk, causal=causal)
    grid = (b, h, nq, nk)
    out, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dqk), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dqk),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, block_k), lambda ib, ih, iq, ik: (ib, ik)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, dv), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda ib, ih, iq, ik: (ib, ih, iq)),
            pl.BlockSpec((1, 1, block_q), lambda ib, ih, iq, ik: (ib, ih, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
            jax.ShapeDtypeStruct((b, h, s), q.dtype),
            jax.ShapeDtypeStruct((b, h, s), q.dtype),
        ],
        interpret=interpret,
    )(q, k, v, bias)
    return out


# ---------------------------------------------------------------------------
# Decode kernel
# ---------------------------------------------------------------------------

def _decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref,
                   s_ref, *, scale, n_k_blocks):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0, 0]                      # (dqk,)
    k = k_ref[0, 0]                      # (block_k, dqk)
    v = v_ref[0, 0]                      # (block_k, dv)
    s = jnp.dot(k, q) * scale + bias_ref[0]     # (block_k,)
    # raw (biased) scores land in the per-row score plane; the caller
    # renormalizes with the final (m, l) accumulators — attention-mass
    # support without touching the online-softmax loop (ISSUE 10)
    s_ref[0, 0] = s

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    o_prev = o_ref[0, 0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + p.sum()
    o_new = o_prev * alpha + jnp.dot(p, v)
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new

    @pl.when(ik == n_k_blocks - 1)
    def _final():
        o_ref[0, 0] = o_new / l_new

    @pl.when(ik != n_k_blocks - 1)
    def _mid():
        o_ref[0, 0] = o_new


def pallas_attention_decode(q, k_cache, v_cache, pos, block_k=64,
                            interpret=True, return_mass=False):
    """One-token decode attention, streaming the thin key cache in tiles.

    q: (B, H, dqk)  k_cache: (B, Hkv, N, dqk)  v_cache: (B, Hkv, N, dv)
    pos: (B,) int32, current position (inclusive). -> (B, H, dv)

    With ``return_mass=True`` also returns the per-row post-softmax
    attention mass (B, N) (head-mean, 0 past ``pos``), rebuilt outside
    the kernel from the raw score plane and the final online-softmax
    (m, l) accumulators: w = exp(s - m) / l.
    """
    b, h, dqk = q.shape
    hkv, n = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[3]
    group = h // hkv
    block_k = min(block_k, n)
    assert n % block_k == 0, (n, block_k)
    nk = n // block_k
    scale = 1.0 / float(dqk) ** 0.5
    bias = jnp.where(jnp.arange(n)[None, :] <= pos[:, None],
                     0.0, NEG_INF).astype(q.dtype)

    kernel = functools.partial(_decode_kernel, scale=scale, n_k_blocks=nk)
    out, m, l, s = pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, dqk), lambda ib, ih, ik: (ib, ih, 0)),
            pl.BlockSpec((1, 1, block_k, dqk),
                         lambda ib, ih, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda ib, ih, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, block_k), lambda ib, ih, ik: (ib, ik)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, dv), lambda ib, ih, ik: (ib, ih, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, ik: (ib, ih)),
            pl.BlockSpec((1, 1), lambda ib, ih, ik: (ib, ih)),
            pl.BlockSpec((1, 1, block_k), lambda ib, ih, ik: (ib, ih, ik)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, dv), q.dtype),
            jax.ShapeDtypeStruct((b, h), q.dtype),
            jax.ShapeDtypeStruct((b, h), q.dtype),
            jax.ShapeDtypeStruct((b, h, n), q.dtype),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, bias)
    if return_mass:
        w = jnp.exp(s - m[..., None]) / l[..., None]
        return out, jnp.mean(w, axis=1)
    return out


def _decode_kernel_q8(q_ref, k_ref, ks_ref, v_ref, vs_ref, bias_ref, o_ref,
                      m_ref, l_ref, s_ref, *, scale, n_k_blocks):
    """q8 decode tile: K/V arrive as raw int8 tiles plus (block_k,) per-row
    fp32 scales. The dequant is fused into the online-softmax loop — the
    K scale lands on the scalar score (q·k_q)·s and the V scale folds into
    the softmax weights before the PV dot — so the fp32 arena never
    materializes in VMEM (nor HBM): only the int8 tiles stream."""
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0, 0]                              # (dqk,) f32
    k = k_ref[0, 0].astype(q.dtype)              # (block_k, dqk) <- int8
    ks = ks_ref[0]                               # (block_k,) f32
    v = v_ref[0, 0].astype(q.dtype)              # (block_k, dv)  <- int8
    vs = vs_ref[0]                               # (block_k,) f32
    s = jnp.dot(k, q) * ks * scale + bias_ref[0]  # (block_k,)
    s_ref[0, 0] = s

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    o_prev = o_ref[0, 0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + p.sum()
    o_new = o_prev * alpha + jnp.dot(p * vs, v)
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new

    @pl.when(ik == n_k_blocks - 1)
    def _final():
        o_ref[0, 0] = o_new / l_new

    @pl.when(ik != n_k_blocks - 1)
    def _mid():
        o_ref[0, 0] = o_new


def pallas_attention_decode_q8(q, k_cache_q, k_scale, v_cache_q, v_scale,
                               pos, block_k=64, interpret=True,
                               return_mass=False):
    """One-token decode attention streaming INT8 key/value tiles.

    q: (B, H, dqk) f32; k_cache_q: (B, Hkv, N, dqk) int8; k_scale: (B, N)
    f32 (one scale per cache row, shared across kv heads); v likewise.
    pos: (B,) int32 current position (inclusive). -> (B, H, dv) f32.

    The K tile is dqk/dv·4x smaller than a full-dim fp32 tile — the
    thin-keys bandwidth win and the int8 win compose in the same
    BlockSpec (paper §6: "compose with GQA and quantization").

    ``return_mass=True`` as in :func:`pallas_attention_decode`.
    """
    b, h, dqk = q.shape
    hkv, n = k_cache_q.shape[1], k_cache_q.shape[2]
    dv = v_cache_q.shape[3]
    group = h // hkv
    block_k = min(block_k, n)
    assert n % block_k == 0, (n, block_k)
    nk = n // block_k
    scale = 1.0 / float(dqk) ** 0.5
    bias = jnp.where(jnp.arange(n)[None, :] <= pos[:, None],
                     0.0, NEG_INF).astype(q.dtype)

    kernel = functools.partial(_decode_kernel_q8, scale=scale, n_k_blocks=nk)
    out, m, l, s = pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, dqk), lambda ib, ih, ik: (ib, ih, 0)),
            pl.BlockSpec((1, 1, block_k, dqk),
                         lambda ib, ih, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, block_k), lambda ib, ih, ik: (ib, ik)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda ib, ih, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, block_k), lambda ib, ih, ik: (ib, ik)),
            pl.BlockSpec((1, block_k), lambda ib, ih, ik: (ib, ik)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, dv), lambda ib, ih, ik: (ib, ih, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, ik: (ib, ih)),
            pl.BlockSpec((1, 1), lambda ib, ih, ik: (ib, ih)),
            pl.BlockSpec((1, 1, block_k), lambda ib, ih, ik: (ib, ih, ik)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, dv), q.dtype),
            jax.ShapeDtypeStruct((b, h), q.dtype),
            jax.ShapeDtypeStruct((b, h), q.dtype),
            jax.ShapeDtypeStruct((b, h, n), q.dtype),
        ],
        interpret=interpret,
    )(q, k_cache_q, k_scale, v_cache_q, v_scale, bias)
    if return_mass:
        w = jnp.exp(s - m[..., None]) / l[..., None]
        return out, jnp.mean(w, axis=1)
    return out


def vmem_report(cfg_name, b, h, hkv, s, dqk, dv, block_q=32, block_k=32,
                bytes_per_el=2):
    """Estimate per-core VMEM residency and MXU utilization for the prefill
    kernel at a given geometry (real-TPU estimate; interpret mode gives no
    hardware timing). Returns a dict merged into artifacts/kernel_report.json.
    """
    vmem = bytes_per_el * (
        block_q * dqk +          # Q tile
        block_k * dqk +          # K tile (thin!)
        block_k * dv +           # V tile
        block_q * dv +           # O accumulator
        2 * block_q +            # m, l
        block_k)                 # bias
    # MXU: contraction fill for QK^T is dqk/128; for PV it's block_k/128.
    return {
        "config": cfg_name,
        "block_q": block_q, "block_k": block_k,
        "d_qk_head": dqk, "d_v_head": dv,
        "vmem_bytes_per_block": vmem,
        "mxu_qk_contraction_fill": min(1.0, dqk / 128.0),
        "mxu_pv_contraction_fill": min(1.0, block_k / 128.0),
        "k_tile_bytes": bytes_per_el * block_k * dqk,
        "k_tile_bytes_full_dim": bytes_per_el * block_k * dv,
        "k_bandwidth_saving": 1.0 - dqk / dv if dv else 0.0,
    }
