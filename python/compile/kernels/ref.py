"""Pure-jnp reference attention — the correctness oracle for the Pallas
kernels, and the XLA-fused fast path used inside training artifacts.

All functions implement *asymmetric* attention: the per-head query/key dim
``d_qk_head`` is decoupled from the value dim ``d_v_head``. Softmax scaling
uses ``1/sqrt(d_qk_head)`` (paper Eq. 4).
"""

import jax.numpy as jnp

NEG_INF = -1e30

# Symmetric per-row int8 quantization (ISSUE 4). A cache "row" is the full
# flat trailing dim of one (layer, lane, position) entry — KD or VD
# elements sharing ONE fp32 scale. Zero rows get the epsilon scale (and
# quantize to exactly 0); the floor also keeps x/scale finite. The rust
# twin (substrate::tensor::quantize_rows_q8) mirrors these exact ops —
# same eps, same round-half-to-even — so host-quantized rows (monolithic
# prefill park) and device-quantized rows (decode/chunk artifacts) agree.
Q8_SCALE_EPS = 1e-12


def quantize_rows(x):
    """x (..., D) f32 -> (q (..., D) int8, scale (...,) f32) with
    symmetric per-row scale max|row|/127; worst-case |x - q*scale| <=
    scale/2 elementwise (see python/tests/test_kernel.py)."""
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(amax / 127.0, Q8_SCALE_EPS).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_rows(q, scale):
    """(q (..., D) int8, scale (...,) f32) -> (..., D) f32."""
    return q.astype(jnp.float32) * scale[..., None]


def repeat_kv(x, group):
    """(B, Hkv, S, D) -> (B, Hkv*group, S, D) by repeating each kv head."""
    if group == 1:
        return x
    b, hkv, s, d = x.shape
    x = jnp.broadcast_to(x[:, :, None], (b, hkv, group, s, d))
    return x.reshape(b, hkv * group, s, d)


def attention_prefill(q, k, v, lengths=None, causal=True):
    """Causal (optionally length-masked) attention.

    q: (B, H, S, dqk)   k: (B, Hkv, S, dqk)   v: (B, Hkv, S, dv)
    lengths: (B,) int32 valid prompt lengths, or None.
    Returns (B, H, S, dv).
    """
    b, h, s, dqk = q.shape
    group = h // k.shape[1]
    k = repeat_kv(k, group)
    v = repeat_kv(v, group)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(dqk, q.dtype))
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        scores = jnp.where(qi >= ki, scores, NEG_INF)
    if lengths is not None:
        ki = jnp.arange(s)[None, None, None, :]
        scores = jnp.where(ki < lengths[:, None, None, None], scores, NEG_INF)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def attention_prefill_chunk(q, k_cache, v_cache, qpos):
    """Chunked-prefill attention: a window of C queries against a dense
    cache arena that already holds every earlier row (and this chunk's own
    rows, written before the call).

    q: (B, H, C, dqk)  k_cache: (B, Hkv, N, dqk)  v_cache: (B, Hkv, N, dv)
    qpos: (B, C) int32 — ABSOLUTE position of each chunk query; key j is
    valid for query i iff j <= qpos[i] (the causal mask of the single-shot
    prefill, expressed against arena indices).
    Returns (B, H, C, dv).

    Kept score-identical to :func:`attention_prefill` at N == S: the same
    NEG_INF masking, softmax over the same N-long key axis, so a chunked
    pass reproduces the single-shot prefill bit-for-bit.
    """
    b, h, c, dqk = q.shape
    n = k_cache.shape[2]
    group = h // k_cache.shape[1]
    k = repeat_kv(k_cache, group)
    v = repeat_kv(v_cache, group)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(dqk, q.dtype))
    ki = jnp.arange(n)[None, None, None, :]
    scores = jnp.where(ki <= qpos[:, None, :, None], scores, NEG_INF)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def attention_prefill_chunk_q8(q, k_cache_q, k_scale, v_cache_q, v_scale,
                               qpos):
    """Dequant-fused chunked-prefill attention over int8 arenas.

    q: (B, H, C, dqk) f32; k_cache_q: (B, Hkv, N, dqk) int8;
    k_scale: (B, N) f32 — ONE scale per cache row, shared across kv heads
    (the row is the flat KD entry); v_cache_q/v_scale likewise.
    Returns (B, H, C, dv) f32.

    The dequant never touches the arenas as fp32 *values*: scores are
    computed on the raw int8 codes and the per-row scale is applied to the
    scalar score (q·k_q_j)·s_j, and the V scales fold into the softmax
    weights before the PV contraction — algebraically identical to
    attending over dequantized rows (the oracle equality pinned by
    test_kernel.py::test_fused_q8_equals_dequant_then_attend).
    """
    b, h, c, dqk = q.shape
    n = k_cache_q.shape[2]
    group = h // k_cache_q.shape[1]
    k = repeat_kv(k_cache_q.astype(q.dtype), group)
    v = repeat_kv(v_cache_q.astype(q.dtype), group)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) \
        * k_scale[:, None, None, :] / jnp.sqrt(jnp.asarray(dqk, q.dtype))
    ki = jnp.arange(n)[None, None, None, :]
    scores = jnp.where(ki <= qpos[:, None, :, None], scores, NEG_INF)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", w * v_scale[:, None, None, :], v)


def attention_decode_q8(q, k_cache_q, k_scale, v_cache_q, v_scale, pos,
                        return_mass=False):
    """Dequant-fused single-token decode attention over int8 arenas.

    q: (B, H, dqk) f32; k_cache_q: (B, Hkv, N, dqk) int8; k_scale: (B, N)
    f32 per-row scales (shared across kv heads); v likewise.
    Returns (B, H, dv) f32. See attention_prefill_chunk_q8 on the fusion.

    With ``return_mass=True`` also returns the per-row post-softmax
    attention mass ``(B, N)`` — the head-mean of the softmax weights this
    step spent on each cache row (rows past ``pos`` get exactly 0, the
    NEG_INF mask). The eviction policies (ISSUE 10) rank rows by this.
    """
    b, h, dqk = q.shape
    n = k_cache_q.shape[2]
    group = h // k_cache_q.shape[1]
    k = repeat_kv(k_cache_q.astype(q.dtype), group)
    v = repeat_kv(v_cache_q.astype(q.dtype), group)
    scores = jnp.einsum("bhd,bhkd->bhk", q, k) \
        * k_scale[:, None, :] / jnp.sqrt(jnp.asarray(dqk, q.dtype))
    ki = jnp.arange(n)[None, None, :]
    scores = jnp.where(ki <= pos[:, None, None], scores, NEG_INF)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhk,bhkd->bhd", w * v_scale[:, None, :], v)
    if return_mass:
        return o, jnp.mean(w, axis=1)
    return o


def attention_decode(q, k_cache, v_cache, pos, return_mass=False):
    """Single-token decode attention against a dense cache arena.

    q: (B, H, dqk)  k_cache: (B, Hkv, N, dqk)  v_cache: (B, Hkv, N, dv)
    pos: (B,) int32 — index of the CURRENT token; positions 0..pos are valid
    (the current token's k/v are assumed already written at index pos).
    Returns (B, H, dv); with ``return_mass=True`` additionally the per-row
    post-softmax attention mass (B, N) — head-mean softmax weight per
    cache row, 0 past ``pos`` (see attention_decode_q8).
    """
    b, h, dqk = q.shape
    n = k_cache.shape[2]
    group = h // k_cache.shape[1]
    k = repeat_kv(k_cache, group)
    v = repeat_kv(v_cache, group)
    scores = jnp.einsum("bhd,bhkd->bhk", q, k) / jnp.sqrt(
        jnp.asarray(dqk, q.dtype))
    ki = jnp.arange(n)[None, None, :]
    scores = jnp.where(ki <= pos[:, None, None], scores, NEG_INF)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhk,bhkd->bhd", w, v)
    if return_mass:
        return o, jnp.mean(w, axis=1)
    return o
