//! Analytical models from the paper, reproduced exactly:
//!
//! - **Eq. 10** decode bandwidth roofline:
//!   `speedup(b) = (W + b·C_kv) / (W' + b·C'_kv)`
//! - **Table 6**: KV cache comparison at LLaMA-7B/128K (bf16, GiB),
//!   MHA vs thin keys vs GQA vs MLA vs GQA+thin.
//! - **Table 10**: KV GB/user at 128K and 1M context (fp16, decimal GB,
//!   128K = 128,000 as the paper's arithmetic implies).
//! - **§12** prefill arithmetic-intensity model (compute-bound check).

/// Generic per-token per-layer KV cache dims (elements).
#[derive(Clone, Copy, Debug)]
pub struct KvGeometry {
    pub k_dims: usize,
    pub v_dims: usize,
}

impl KvGeometry {
    pub fn mha(d_model: usize) -> Self {
        KvGeometry { k_dims: d_model, v_dims: d_model }
    }

    pub fn thin(d_model: usize, d_select: usize) -> Self {
        KvGeometry { k_dims: d_select, v_dims: d_model }
    }

    pub fn gqa(n_kv_heads: usize, d_head: usize) -> Self {
        KvGeometry {
            k_dims: n_kv_heads * d_head,
            v_dims: n_kv_heads * d_head,
        }
    }

    pub fn gqa_thin(n_kv_heads: usize, d_head: usize, ratio: usize) -> Self {
        KvGeometry {
            k_dims: n_kv_heads * d_head / ratio,
            v_dims: n_kv_heads * d_head,
        }
    }

    /// General per-head cache geometry (ISSUE 5): `n_kv_heads` KV heads
    /// with asymmetric per-head widths — `d_qk_head` for keys (thin),
    /// `d_v_head` for values (full). This is exactly the manifest's
    /// `k_cache_dims`/`v_cache_dims` contract, so the analytic rows and
    /// the engine's measured `arena_k_bytes` gauge share one formula:
    /// `heads(2, 2, 8)` is `servegqathin`, `heads(8, 8, 8)` is
    /// `servefull`.
    pub fn heads(n_kv_heads: usize, d_qk_head: usize, d_v_head: usize)
        -> Self {
        KvGeometry {
            k_dims: n_kv_heads * d_qk_head,
            v_dims: n_kv_heads * d_v_head,
        }
    }

    /// MLA stores a joint latent + decoupled RoPE key; v_dims = 0.
    pub fn mla(d_c: usize, d_h_r: usize) -> Self {
        KvGeometry { k_dims: d_c + d_h_r, v_dims: 0 }
    }

    pub fn total_dims(&self) -> usize {
        self.k_dims + self.v_dims
    }

    /// Cache bytes for a full context.
    pub fn cache_bytes(&self, ctx: usize, layers: usize, bytes_per_el: f64)
        -> f64 {
        ctx as f64 * layers as f64 * self.total_dims() as f64 * bytes_per_el
    }

    pub fn k_bytes(&self, ctx: usize, layers: usize, bytes_per_el: f64) -> f64 {
        ctx as f64 * layers as f64 * self.k_dims as f64 * bytes_per_el
    }

    pub fn v_bytes(&self, ctx: usize, layers: usize, bytes_per_el: f64) -> f64 {
        ctx as f64 * layers as f64 * self.v_dims as f64 * bytes_per_el
    }
}

pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
pub const GB: f64 = 1e9;

/// Storage format of cache elements (ISSUE 4): bytes per element plus
/// the per-row metadata a quantized format carries (one fp32 scale per
/// cache row per layer in our q8 scheme). Keeps the analytic tables
/// honest about scale overhead instead of quoting bare element widths.
#[derive(Clone, Copy, Debug)]
pub struct QuantFormat {
    pub bytes_per_el: f64,
    /// Extra bytes per (token, layer) cache row (scales/zero-points).
    pub scale_bytes_per_row: f64,
}

pub const FMT_FP32: QuantFormat =
    QuantFormat { bytes_per_el: 4.0, scale_bytes_per_row: 0.0 };
pub const FMT_FP16: QuantFormat =
    QuantFormat { bytes_per_el: 2.0, scale_bytes_per_row: 0.0 };
/// Our serving q8: int8 codes + one fp32 scale per row.
pub const FMT_Q8: QuantFormat =
    QuantFormat { bytes_per_el: 1.0, scale_bytes_per_row: 4.0 };

impl KvGeometry {
    /// K-cache bytes for a full context under a storage format,
    /// including per-row scale overhead.
    pub fn k_bytes_fmt(&self, ctx: usize, layers: usize, fmt: QuantFormat)
        -> f64 {
        ctx as f64
            * layers as f64
            * (self.k_dims as f64 * fmt.bytes_per_el
               + fmt.scale_bytes_per_row)
    }
}

/// The paper's §6 composition claim made numeric: key-cache bytes per
/// token at LLaMA-7B geometry (d_model 4096, 32 layers) for the factored
/// rank × GQA × quantization stack. Returns
/// `(label, k_bytes_per_token, compression_x_vs_fp32_mha)` rows; the
/// thin(d/4) × q8 row is the "up to 16x" headline (15.94x after the
/// honest per-row scale overhead), and GQA (exp8's 4x-group sharing at
/// 8 kv heads) composes on top.
pub fn quantized_composition_rows()
    -> Vec<(&'static str, f64, f64)> {
    let (d, layers) = (4096usize, 32usize);
    let rows: Vec<(&'static str, KvGeometry, QuantFormat)> = vec![
        ("MHA fp32 (baseline)", KvGeometry::mha(d), FMT_FP32),
        ("thin keys r=d/4, fp32", KvGeometry::thin(d, d / 4), FMT_FP32),
        ("thin keys r=d/4, q8", KvGeometry::thin(d, d / 4), FMT_Q8),
        ("GQA-8, fp32", KvGeometry::gqa(8, 128), FMT_FP32),
        ("GQA-8 + thin r/4, q8", KvGeometry::gqa_thin(8, 128, 4), FMT_Q8),
    ];
    let base = rows[0].1.k_bytes_fmt(1, layers, rows[0].2);
    rows.into_iter()
        .map(|(label, g, fmt)| {
            let b = g.k_bytes_fmt(1, layers, fmt);
            (label, b, base / b)
        })
        .collect()
}

/// Eq. 10: decode-step bytes = weights (shared) + per-sequence KV.
pub fn eq10_speedup(w_bytes: f64, w_thin_bytes: f64, ckv_bytes: f64,
                    ckv_thin_bytes: f64, batch: f64) -> f64 {
    (w_bytes + batch * ckv_bytes) / (w_thin_bytes + batch * ckv_thin_bytes)
}

/// The b→∞ asymptote of Eq. 10.
pub fn eq10_asymptote(ckv_bytes: f64, ckv_thin_bytes: f64) -> f64 {
    ckv_bytes / ckv_thin_bytes
}

/// Table 6 row: (label, K GiB, V GiB, total GiB, saved %).
pub fn table6_rows() -> Vec<(&'static str, f64, f64, f64, f64)> {
    let (d, layers, ctx, b) = (4096usize, 32usize, 131072usize, 2.0);
    let to_gib = |x: f64| x / GIB;
    let geoms: Vec<(&'static str, KvGeometry)> = vec![
        ("MHA (baseline)", KvGeometry::mha(d)),
        ("Thin keys (d_select=d/4)", KvGeometry::thin(d, d / 4)),
        ("GQA-8", KvGeometry::gqa(8, 128)),
        ("MLA (d_c=512, d_h^R=64)", KvGeometry::mla(512, 64)),
        ("GQA-8 + thin keys", KvGeometry::gqa_thin(8, 128, 4)),
    ];
    let base = geoms[0].1.cache_bytes(ctx, layers, b);
    geoms
        .into_iter()
        .map(|(label, g)| {
            let total = g.cache_bytes(ctx, layers, b);
            (
                label,
                to_gib(g.k_bytes(ctx, layers, b)),
                to_gib(g.v_bytes(ctx, layers, b)),
                to_gib(total),
                100.0 * (1.0 - total / base),
            )
        })
        .collect()
}

/// Table 10 row: (context label, K GB, V GB, total GB, savings GB, savings %).
pub fn table10_rows() -> Vec<(String, f64, f64, f64, f64, f64)> {
    // fp16, decimal GB, 128K = 128,000 (paper arithmetic), 1M = 1,000,000.
    let (d, layers, b) = (4096usize, 32usize, 2.0);
    let mut rows = Vec::new();
    for (ctx_label, ctx) in [("128K", 128_000usize), ("1M", 1_000_000usize)] {
        let std = KvGeometry::mha(d);
        let std_total = std.cache_bytes(ctx, layers, b) / GB;
        for (variant, ds) in
            [("standard", d), ("d_model/2", d / 2), ("d_model/4", d / 4)]
        {
            let g = KvGeometry::thin(d, ds);
            let k = g.k_bytes(ctx, layers, b) / GB;
            let v = g.v_bytes(ctx, layers, b) / GB;
            let total = k + v;
            rows.push((
                format!("{ctx_label} {variant}"),
                k,
                v,
                total,
                std_total - total,
                100.0 * (std_total - total) / std_total,
            ));
        }
    }
    rows
}

/// §12 prefill attention FLOPs for one layer at prompt length `s`
/// (QK^T: 2·s²·d_qk per head; PV: 2·s²·d_v per head).
pub fn prefill_attention_flops(s: usize, n_heads: usize, d_qk: usize,
                               d_v: usize) -> f64 {
    2.0 * (s as f64) * (s as f64) * (d_qk as f64 + d_v as f64)
        * n_heads as f64
}

/// §12 prefill arithmetic intensity: attention FLOPs per byte of KV read
/// for one layer at prompt length `s` (= 2s/bytes_per_el under this
/// counting — linear in context, so long prompts are compute-bound).
pub fn prefill_intensity(s: usize, n_heads: usize, d_qk: usize, d_v: usize,
                         bytes_per_el: f64) -> f64 {
    let kv_bytes =
        (s as f64) * n_heads as f64 * (d_qk + d_v) as f64 * bytes_per_el;
    prefill_attention_flops(s, n_heads, d_qk, d_v) / kv_bytes
}

/// Mistral-7B constants used by the paper's Table 11 prediction.
#[derive(Clone, Copy, Debug)]
pub struct MistralRoofline {
    pub w_gb: f64,
    pub ckv_mb: f64,
}

pub const MISTRAL: MistralRoofline = MistralRoofline { w_gb: 14.2, ckv_mb: 537.0 };

/// Paper's published thin variants: (label, W' GB, C'_kv MB).
pub fn mistral_thin_variants() -> Vec<(&'static str, f64, f64)> {
    // r256: W'=13.2 GB, C'kv=336 MB (paper §4.2). r512 interpolated the
    // same way: half the projection saving, half the K-cache saving.
    vec![("r512", 13.7, 436.5), ("r256", 13.2, 336.0)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_matches_paper() {
        let rows = table6_rows();
        // MHA: 32 + 32 = 64 GiB
        assert!((rows[0].1 - 32.0).abs() < 0.01);
        assert!((rows[0].3 - 64.0).abs() < 0.01);
        // thin: 8 + 32 = 40 GiB, 37.5% saved
        assert!((rows[1].1 - 8.0).abs() < 0.01);
        assert!((rows[1].3 - 40.0).abs() < 0.01);
        assert!((rows[1].4 - 37.5).abs() < 0.1);
        // GQA-8: 16 GiB total, 75%
        assert!((rows[2].3 - 16.0).abs() < 0.01);
        assert!((rows[2].4 - 75.0).abs() < 0.1);
        // MLA: 4.5 GiB, 93%
        assert!((rows[3].3 - 4.5).abs() < 0.01);
        assert!((rows[3].4 - 93.0).abs() < 0.5);
        // GQA+thin: 10 GiB, 84.4%
        assert!((rows[4].3 - 10.0).abs() < 0.01);
        assert!((rows[4].4 - 84.4).abs() < 0.1);
    }

    #[test]
    fn table10_matches_paper() {
        let rows = table10_rows();
        // 128K standard: K 33.6, total 67.2
        assert!((rows[0].1 - 33.6).abs() < 0.1);
        assert!((rows[0].3 - 67.2).abs() < 0.1);
        // 128K /2: total 50.4, saving 16.8 (25%)
        assert!((rows[1].3 - 50.4).abs() < 0.1);
        assert!((rows[1].4 - 16.8).abs() < 0.1);
        assert!((rows[1].5 - 25.0).abs() < 0.1);
        // 128K /4: total 42.0, saving 25.2 (37.5%)
        assert!((rows[2].3 - 42.0).abs() < 0.1);
        assert!((rows[2].5 - 37.5).abs() < 0.1);
        // 1M standard: 524 GB; /2: 393; /4: 328
        assert!((rows[3].3 - 524.0).abs() < 1.0);
        assert!((rows[4].3 - 393.0).abs() < 1.0);
        assert!((rows[5].3 - 328.0).abs() < 1.0);
    }

    #[test]
    fn eq10_monotone_in_batch_and_bounded() {
        let (w, ck) = (MISTRAL.w_gb * GB, MISTRAL.ckv_mb * 1e6);
        for (_, w_thin, ck_thin) in mistral_thin_variants() {
            let (wt, ckt) = (w_thin * GB, ck_thin * 1e6);
            let mut last = 0.0;
            for b in [1.0, 4.0, 8.0, 16.0, 32.0, 256.0] {
                let s = eq10_speedup(w, wt, ck, ckt, b);
                assert!(s >= last, "not monotone at b={b}");
                assert!(s <= eq10_asymptote(ck, ckt) + 1e-9);
                last = s;
            }
        }
        // r256 asymptote ~1.60x (paper §4.2)
        let a = eq10_asymptote(537.0, 336.0);
        assert!((a - 1.60).abs() < 0.02, "{a}");
    }

    #[test]
    fn prefill_is_compute_bound_at_4k() {
        // H100 ridge point is ~295 FLOP/byte (989 TFLOP/s / 3.35 TB/s);
        // prefill at 4K context sits far above it -> compute-bound (§12).
        let i = prefill_intensity(4096, 8, 128, 128, 2.0);
        assert!(i > 2000.0, "{i}");
        // reducing d_k 128 -> 32 cuts QK^T FLOPs 4x per head (paper §12):
        let f_full = prefill_attention_flops(4096, 8, 128, 0);
        let f_thin = prefill_attention_flops(4096, 8, 32, 0);
        assert!((f_full / f_thin - 4.0).abs() < 1e-9);
    }
}

#[cfg(test)]
mod extra_tests {
    use super::*;

    #[test]
    fn quantized_composition_hits_16x() {
        let rows = quantized_composition_rows();
        // baseline is 1x by construction
        assert!((rows[0].2 - 1.0).abs() < 1e-12);
        // thin r=d/4 fp32: exactly 4x
        assert!((rows[1].2 - 4.0).abs() < 1e-9, "{}", rows[1].2);
        // thin r=d/4 q8: the paper's "up to 16x" composition — 15.94x
        // with the honest per-row fp32 scale overhead
        assert!((rows[2].2 - 16.0).abs() < 0.1, "{}", rows[2].2);
        assert!(rows[2].2 < 16.0, "scale overhead must show");
        // GQA-8 composes multiplicatively on top (~63x more than fp32 MHA)
        assert!(rows[4].2 > 60.0, "{}", rows[4].2);
        // every row's bytes are positive and monotone with compression
        for (label, b, x) in &rows {
            assert!(*b > 0.0 && *x > 0.0, "{label}");
        }
    }

    #[test]
    fn quant_format_overhead_vanishes_at_scale() {
        // at 7B widths the per-row scale is <0.4% of the q8 payload; at
        // toy widths (KD=16) it is 25% — the analytic table must use the
        // real geometry, not the toy one (this pins the distinction)
        let wide = KvGeometry::thin(4096, 1024);
        let toy = KvGeometry::thin(64, 16);
        let w = wide.k_bytes_fmt(1, 1, FMT_Q8) / wide.k_dims as f64;
        let t = toy.k_bytes_fmt(1, 1, FMT_Q8) / toy.k_dims as f64;
        assert!(w < 1.01 && t > 1.2, "{w} {t}");
    }

    #[test]
    fn kv_geometry_composition_algebra() {
        // gqa_thin == gqa with k_dims divided
        let g = KvGeometry::gqa(8, 128);
        let gt = KvGeometry::gqa_thin(8, 128, 4);
        assert_eq!(gt.k_dims * 4, g.k_dims);
        assert_eq!(gt.v_dims, g.v_dims);
        // thin at ratio 1 is MHA
        let t = KvGeometry::thin(4096, 4096);
        let m = KvGeometry::mha(4096);
        assert_eq!(t.total_dims(), m.total_dims());
        // the general per-head constructor subsumes both special cases
        assert_eq!(KvGeometry::heads(8, 128, 128).k_dims, g.k_dims);
        assert_eq!(KvGeometry::heads(8, 32, 128).k_dims, gt.k_dims);
        assert_eq!(KvGeometry::heads(8, 32, 128).v_dims, gt.v_dims);
    }

    /// The serve-grid key-cache composition (ISSUE 5), analytic side:
    /// at the toy serving geometry (8q heads, d_qk_head 8, d_v_head 8)
    /// the grouped thin config (2 kv heads, thin head dim 2) cuts K
    /// dims 16x; with q8 element width that is 64x payload, and ≥ 15x
    /// even after the per-row fp32 scale at the toy KD=4 width — the
    /// same floor bench_table10_kvmemory asserts off the engine gauges.
    #[test]
    fn serve_grid_key_composition_hits_16x_floor() {
        let full = KvGeometry::heads(8, 8, 8); // servefull
        let gqa_thin = KvGeometry::heads(2, 2, 8); // servegqathin
        assert_eq!(full.k_dims, 16 * gqa_thin.k_dims);
        let layers = 3;
        let full_fp32 = full.k_bytes_fmt(1, layers, FMT_FP32);
        let thin_q8 = gqa_thin.k_bytes_fmt(1, layers, FMT_Q8);
        assert!((full_fp32 / (thin_q8 - layers as f64 * 4.0) - 64.0).abs()
                    < 1e-9);
        assert!(full_fp32 / thin_q8 >= 15.0, "{}", full_fp32 / thin_q8);
    }

    #[test]
    fn eq10_at_batch_zero_is_weight_ratio() {
        let s = eq10_speedup(10.0, 8.0, 1.0, 0.5, 0.0);
        assert!((s - 10.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn cache_bytes_linear_in_context_and_width() {
        let g = KvGeometry::mha(1024);
        let b1 = g.cache_bytes(1000, 8, 2.0);
        assert_eq!(g.cache_bytes(2000, 8, 2.0), 2.0 * b1);
        assert_eq!(g.cache_bytes(1000, 8, 4.0), 2.0 * b1);
        assert_eq!(g.cache_bytes(1000, 16, 2.0), 2.0 * b1);
    }

    #[test]
    fn table6_internal_consistency() {
        for (label, k, v, total, _saved) in table6_rows() {
            assert!((k + v - total).abs() < 1e-9, "{label}");
        }
    }

    #[test]
    fn table10_savings_consistent() {
        for (label, k, v, total, saved_gb, saved_pct) in table10_rows() {
            assert!((k + v - total).abs() < 1e-9, "{label}");
            assert!(saved_pct >= 0.0 && saved_gb >= -1e-9, "{label}");
        }
    }
}
