//! Layer 3 — the serving coordinator (the vLLM-shaped part of the paper).
//!
//! The paper's asymmetry is made physical here: the paged KV cache keeps
//! *separate pools* for thin keys (r dims/token) and full values
//! (d dims/token), the batcher schedules prefill/decode over static-shape
//! buckets (HLO executables are shape-specialized), and the router admits
//! requests against the KV memory budget — which is exactly where factored
//! keys buy ~60% more concurrent users (paper §1, Table 10).
//!
//! Module map:
//! - [`errors`]    — typed engine-error taxonomy (Transient / SequenceLocal
//!                   / Fatal) for retry, quarantine, and escalation policy
//! - [`kvcache`]   — split-pool paged block allocator + accounting
//! - [`sequence`]  — request/sequence lifecycle state
//! - [`sampling`]  — greedy / temperature·top-k sampling
//! - [`lanes`]     — lane-stable group membership + incremental regroup
//! - [`engine`]    — execution: prefill/decode artifacts + cache packing
//! - [`scheduler`] — continuous batching policy over the engine
//! - [`supervisor`] — checkpoint cadence + warm restart on Fatal/wedge
//! - [`router`]    — front end: arrival traces → scheduler → metrics
//! - [`metrics`]   — latency/throughput accounting
//! - [`roofline`]  — paper Eq. 10 + Tables 6/10 analytical models
//! - [`capacity`]  — concurrent-user capacity planning ("60% more users")

pub mod errors;
pub mod kvcache;
pub mod sequence;
pub mod sampling;
pub mod lanes;
pub mod engine;
pub mod eviction;
pub mod scheduler;
pub mod supervisor;
pub mod router;
pub mod metrics;
pub mod roofline;
pub mod capacity;
