//! Typed engine-error taxonomy at the coordinator boundary.
//!
//! `Engine::prefill` / `prefill_chunk` / `decode_step` classify every
//! failure into one of three recovery classes, so the scheduler's policy
//! is written against *meaning* instead of string-matching anyhow chains:
//!
//! - **Transient** — the step failed but engine state was rolled back and
//!   no single sequence is implicated (injected exec/artifact-load
//!   faults). Retry with backoff; the whole batch is re-runnable.
//! - **SequenceLocal** — one sequence is implicated (a corrupt output row
//!   attributed to its lane, or a genuine per-request validation failure
//!   like an over-long prompt). Retry if the fault was injected; if it
//!   persists, quarantine that sequence (`FinishReason::Failed`) and keep
//!   serving the rest of the batch.
//! - **Fatal** — a real (non-injected) runtime failure. State may be
//!   rolled back but the device is not trustworthy; escalate, never
//!   retry-loop.
//!
//! `EngineError` implements `std::error::Error`, so anyhow's blanket
//! `From` keeps every legacy `?` call site in experiments/tests/benches
//! compiling unchanged — only the scheduler opts into typed handling.

use crate::coordinator::sequence::SeqId;
use crate::runtime::faults::{FaultKind, InjectedFault};

/// A classified engine-step failure. The wrapped `anyhow::Error` retains
/// the full context chain (including the `InjectedFault` payload when the
/// failure was injected).
#[derive(Debug)]
pub enum EngineError {
    /// Whole-step failure, state rolled back, nobody's fault: retry.
    Transient {
        op: &'static str,
        source: anyhow::Error,
    },
    /// Attributable to one sequence: quarantine it if the fault persists.
    SequenceLocal {
        seq: SeqId,
        op: &'static str,
        source: anyhow::Error,
    },
    /// Real runtime failure: escalate.
    Fatal {
        op: &'static str,
        source: anyhow::Error,
    },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Transient { op, source } => {
                write!(f, "transient fault in {op}: {source}")
            }
            EngineError::SequenceLocal { seq, op, source } => {
                write!(f, "sequence-local fault in {op} (seq {seq}): {source}")
            }
            EngineError::Fatal { op, source } => {
                write!(f, "fatal engine error in {op}: {source}")
            }
        }
    }
}

impl std::error::Error for EngineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        // anyhow::Error derefs to `dyn Error + Send + Sync + 'static`,
        // which coerces down to `dyn Error + 'static`.
        Some(&**self.source_ref())
    }
}

impl EngineError {
    pub fn transient(op: &'static str, source: anyhow::Error) -> Self {
        EngineError::Transient { op, source }
    }

    pub fn sequence_local(
        seq: SeqId,
        op: &'static str,
        source: anyhow::Error,
    ) -> Self {
        EngineError::SequenceLocal { seq, op, source }
    }

    pub fn fatal(op: &'static str, source: anyhow::Error) -> Self {
        EngineError::Fatal { op, source }
    }

    /// Classify a `Runtime::execute` failure. Injected corrupt-output
    /// faults carry a lane hint; `lane_seq` maps it to the implicated
    /// sequence (None when the batch context offers no attribution, e.g.
    /// an empty batch — then the fault degrades to Transient). Injected
    /// exec/load/latency faults are Transient. Injected FATAL faults are
    /// Fatal — same recovery class as a real runtime failure (the engine
    /// is poisoned; only a supervisor restart recovers), but still
    /// carrying the `InjectedFault` payload so chaos tests can tell them
    /// apart via `injected_kind()`. Anything that does not carry an
    /// `InjectedFault` is a REAL runtime failure: Fatal.
    pub fn from_runtime(
        op: &'static str,
        source: anyhow::Error,
        lane_seq: impl FnOnce(u64) -> Option<SeqId>,
    ) -> Self {
        let injected: Option<InjectedFault> =
            source.downcast_ref::<InjectedFault>().copied();
        match injected {
            Some(fault) if fault.kind == FaultKind::CorruptOutput => {
                match lane_seq(fault.lane_hint) {
                    Some(seq) => EngineError::SequenceLocal { seq, op, source },
                    None => EngineError::Transient { op, source },
                }
            }
            Some(fault) if fault.kind == FaultKind::FatalError => {
                EngineError::Fatal { op, source }
            }
            Some(_) => EngineError::Transient { op, source },
            None => EngineError::Fatal { op, source },
        }
    }

    /// The step that failed (`"prefill"` / `"prefill_chunk"` /
    /// `"decode_step"` / ...).
    pub fn op(&self) -> &'static str {
        match self {
            EngineError::Transient { op, .. }
            | EngineError::SequenceLocal { op, .. }
            | EngineError::Fatal { op, .. } => op,
        }
    }

    /// The implicated sequence, for SequenceLocal failures.
    pub fn seq_id(&self) -> Option<SeqId> {
        match self {
            EngineError::SequenceLocal { seq, .. } => Some(*seq),
            _ => None,
        }
    }

    /// The injected fault kind, when this failure came from the
    /// `FaultInjector` (None for genuine failures).
    pub fn injected_kind(&self) -> Option<FaultKind> {
        self.source_ref()
            .downcast_ref::<InjectedFault>()
            .map(|f| f.kind)
    }

    /// Retry policy: Transient always retries; SequenceLocal retries only
    /// when injected (a genuine validation failure — over-long prompt —
    /// will fail identically forever); Fatal never retries.
    pub fn is_retryable(&self) -> bool {
        match self {
            EngineError::Transient { .. } => true,
            EngineError::SequenceLocal { .. } => self.injected_kind().is_some(),
            EngineError::Fatal { .. } => false,
        }
    }

    fn source_ref(&self) -> &anyhow::Error {
        match self {
            EngineError::Transient { source, .. }
            | EngineError::SequenceLocal { source, .. }
            | EngineError::Fatal { source, .. } => source,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn injected(kind: FaultKind, lane_hint: u64) -> anyhow::Error {
        anyhow::Error::new(InjectedFault { kind, lane_hint })
            .context("injected fault in execute(decode_b8)")
    }

    #[test]
    fn injected_exec_fault_is_transient_and_retryable() {
        let e = EngineError::from_runtime(
            "decode_step",
            injected(FaultKind::ExecFailure, 3),
            |_| Some(99),
        );
        assert!(matches!(e, EngineError::Transient { .. }));
        assert!(e.is_retryable());
        assert_eq!(e.injected_kind(), Some(FaultKind::ExecFailure));
        assert_eq!(e.seq_id(), None);
    }

    #[test]
    fn injected_corrupt_output_attributes_to_lane_seq() {
        let e = EngineError::from_runtime(
            "decode_step",
            injected(FaultKind::CorruptOutput, 7),
            |hint| Some(hint * 10),
        );
        assert_eq!(e.seq_id(), Some(70));
        assert!(e.is_retryable(), "injected corrupt rows retry first");
        assert_eq!(e.injected_kind(), Some(FaultKind::CorruptOutput));
    }

    #[test]
    fn corrupt_without_attribution_degrades_to_transient() {
        let e = EngineError::from_runtime(
            "prefill_chunk",
            injected(FaultKind::CorruptOutput, 7),
            |_| None,
        );
        assert!(matches!(e, EngineError::Transient { .. }));
    }

    #[test]
    fn injected_fatal_is_fatal_but_keeps_its_injected_kind() {
        let e = EngineError::from_runtime(
            "decode_step",
            injected(FaultKind::FatalError, 2),
            |_| Some(1),
        );
        assert!(matches!(e, EngineError::Fatal { .. }));
        assert!(!e.is_retryable(), "fatal never retries in place");
        assert_eq!(e.injected_kind(), Some(FaultKind::FatalError),
                   "supervisor telemetry needs the injected payload");
    }

    #[test]
    fn real_errors_are_fatal_and_never_retry() {
        let e = EngineError::from_runtime(
            "decode_step",
            anyhow::anyhow!("execute decode_b8: device wedged"),
            |_| Some(1),
        );
        assert!(matches!(e, EngineError::Fatal { .. }));
        assert!(!e.is_retryable());
        assert_eq!(e.injected_kind(), None);
    }

    #[test]
    fn genuine_sequence_local_does_not_retry() {
        let e = EngineError::sequence_local(
            5,
            "prefill_chunk",
            anyhow::anyhow!("prompt 900 exceeds max prefill 512"),
        );
        assert!(!e.is_retryable(), "deterministic failures must not loop");
        assert_eq!(e.seq_id(), Some(5));
    }

    #[test]
    fn anyhow_interop_keeps_legacy_call_sites_compiling() {
        fn step() -> Result<(), EngineError> {
            Err(EngineError::fatal("decode_step", anyhow::anyhow!("boom")))
        }
        fn legacy() -> anyhow::Result<()> {
            step()?; // anyhow's blanket From<E: std::error::Error>
            Ok(())
        }
        let err = legacy().expect_err("propagates");
        assert!(err.to_string().contains("decode_step"));
    }

    #[test]
    fn display_names_the_class_and_op() {
        let e = EngineError::transient("decode_step", anyhow::anyhow!("x"));
        assert!(e.to_string().starts_with("transient fault in decode_step"));
        let e = EngineError::sequence_local(3, "prefill", anyhow::anyhow!("y"));
        assert!(e.to_string().contains("seq 3"));
    }

    #[test]
    fn source_chain_reaches_the_injected_payload() {
        let e = EngineError::from_runtime(
            "decode_step",
            injected(FaultKind::ArtifactLoad, 0),
            |_| None,
        );
        let mut cur: Option<&(dyn std::error::Error + 'static)> =
            std::error::Error::source(&e);
        let mut found = false;
        while let Some(err) = cur {
            if err.downcast_ref::<InjectedFault>().is_some() {
                found = true;
                break;
            }
            cur = err.source();
        }
        assert!(found, "InjectedFault reachable via the source chain");
    }
}
