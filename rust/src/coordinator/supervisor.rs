//! Supervised warm restart: the layer that turns an `EngineError::Fatal`
//! from a run-ending outage into a bounded latency blip.
//!
//! The supervisor wraps the scheduler loop. Every K rounds
//! ([`SupervisorConfig::checkpoint_every`]) it takes a
//! [`SchedCheckpoint`] — a pure host-side clone of the complete serving
//! state, cheap because the delta-synced host mirrors (PR 2) and the
//! paged block accounting (PR 8) already hold everything the device
//! holds. When a step fails Fatal (or completes but overruns the
//! per-step wall-clock watchdog — a wedged execute that never errors),
//! the supervisor:
//!
//! 1. drops the poisoned [`Engine`] and builds a fresh one from the same
//!    `Manifest` via the injected factory,
//! 2. restores the checkpoint into it
//!    ([`Scheduler::restore_from`] re-uploads device literals from the
//!    host mirrors, charged to `sync_upload_bytes` — the only traffic
//!    that distinguishes a restart from a tier switch),
//! 3. rewinds its logical round counter to the checkpoint's round and
//!    resumes stepping: **replay is ordinary re-stepping**. The sampler
//!    RNG was captured in the checkpoint and is a pure function of seed
//!    + consumption, so the ≤K replayed rounds regenerate bit-exact
//!    tokens. The fault injector's RNG stream is deliberately NOT
//!    restored — replay draws fresh fault randomness, so the same
//!    injected Fatal does not re-fire deterministically forever.
//!
//! Restarts run under a bounded budget with exponential backoff
//! ([`SupervisorConfig::max_restarts`]): each consecutive restart (no
//! successful round in between) sleeps a doubling slot, and exhaustion
//! returns a typed [`RestartBudgetExhausted`] the router downcasts to
//! drain/shed per its policy — recovery code returns errors, it never
//! dies (enforced by `cargo xtask lint`'s `no-exit-in-recovery` rule).
//!
//! Determinism contract (pinned by rust/tests/restart_e2e.rs): the
//! checkpoint cadence counts LOGICAL rounds (restarts rewind the
//! counter), so a faulted run and its fault-free twin checkpoint at the
//! same logical rounds 0, K, 2K, … and their
//! [`Supervisor::checkpoint_fingerprints`] sequences must be equal —
//! `state_fingerprint` equality at matched rounds is the bit-exactness
//! oracle.

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::RecoveryStats;
use crate::coordinator::scheduler::{backoff_slot_us, SchedCheckpoint,
                                    Scheduler};

/// Supervision knobs. `Default` checkpoints every 8 rounds and allows 8
/// consecutive restarts with 200µs-base exponential backoff (clamped at
/// 50ms); the watchdog is off unless a deadline is set.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// Take a checkpoint every this many successful scheduler rounds.
    /// The worst-case replay after a restart is this many rounds.
    pub checkpoint_every: usize,
    /// Consecutive restarts (no successful round in between) tolerated
    /// before the supervisor escalates with [`RestartBudgetExhausted`].
    pub max_restarts: usize,
    /// Base pre-restart backoff, in microseconds; doubles per
    /// consecutive restart (same slot arithmetic as step retries).
    pub restart_backoff_us: u64,
    /// Clamp on one pre-restart backoff slot, in microseconds.
    pub max_restart_backoff_us: u64,
    /// Per-step wall-clock deadline, in seconds: a round that completes
    /// but overruns it is treated as a wedged engine and discarded via
    /// restart. `None` disables the watchdog.
    pub watchdog_step_s: Option<f64>,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            checkpoint_every: 8,
            max_restarts: 8,
            restart_backoff_us: 200,
            max_restart_backoff_us: 50_000,
            watchdog_step_s: None,
        }
    }
}

/// Typed escalation: the restart budget is spent and the engine could
/// not be kept alive. The router downcasts this to trigger its
/// drain/shed path instead of crashing the serve loop.
#[derive(Debug)]
pub struct RestartBudgetExhausted {
    /// Consecutive restarts attempted before giving up.
    pub restarts: usize,
    /// Rendering of the failure that spent the last attempt.
    pub last_error: String,
}

impl std::fmt::Display for RestartBudgetExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "restart budget exhausted after {} consecutive restarts \
             (last error: {})",
            self.restarts, self.last_error
        )
    }
}

impl std::error::Error for RestartBudgetExhausted {}

/// The supervision loop state: the current checkpoint, the logical
/// round clock, the restart budget, and the recovery telemetry that
/// ends up in the `ServeReport`.
pub struct Supervisor<'rt> {
    pub cfg: SupervisorConfig,
    /// Builds a fresh engine from the same manifest/config/seed as the
    /// one being supervised — the restore target after a Fatal.
    factory: Box<dyn FnMut() -> Result<Engine<'rt>> + 'rt>,
    checkpoint: Option<SchedCheckpoint>,
    /// Logical round the current checkpoint was taken at.
    checkpoint_round: u64,
    /// Logical rounds completed — rewinds to `checkpoint_round` on
    /// restart, so replayed rounds do not advance the clock and the
    /// checkpoint cadence realigns with a fault-free twin.
    rounds_done: u64,
    rounds_since_ckpt: usize,
    /// Restarts since the last successful round — the budget counter.
    consecutive_restarts: usize,
    pub stats: RecoveryStats,
    /// `(logical_round, state_fingerprint)` at every checkpoint — the
    /// replay bit-exactness oracle (equal across a faulted run and its
    /// fault-free twin).
    fingerprints: Vec<(u64, u64)>,
}

impl<'rt> Supervisor<'rt> {
    pub fn new(
        cfg: SupervisorConfig,
        factory: impl FnMut() -> Result<Engine<'rt>> + 'rt,
    ) -> Supervisor<'rt> {
        Supervisor {
            cfg,
            factory: Box::new(factory),
            checkpoint: None,
            checkpoint_round: 0,
            rounds_done: 0,
            rounds_since_ckpt: 0,
            consecutive_restarts: 0,
            stats: RecoveryStats::default(),
            fingerprints: Vec::new(),
        }
    }

    /// The `(logical_round, state_fingerprint)` sequence recorded at
    /// checkpoint time — restart_e2e compares it across runs.
    pub fn checkpoint_fingerprints(&self) -> &[(u64, u64)] {
        &self.fingerprints
    }

    /// Logical rounds completed (replayed rounds count once).
    pub fn rounds_done(&self) -> u64 {
        self.rounds_done
    }

    /// One supervised scheduler round. Returns the decode tokens the
    /// round produced; a round that was discarded by a restart returns
    /// 0 (its tokens will be regenerated by replay). Errors only when
    /// the restart budget is exhausted or recovery itself failed — the
    /// caller (router) downcasts [`RestartBudgetExhausted`] to drain.
    pub fn step(&mut self, sched: &mut Scheduler<'rt>) -> Result<usize> {
        if self.checkpoint.is_none()
            || self.rounds_since_ckpt >= self.cfg.checkpoint_every.max(1)
        {
            self.take_checkpoint(sched);
        }
        let t0 = std::time::Instant::now();
        match sched.step() {
            Ok(produced) => {
                let wedged = self
                    .cfg
                    .watchdog_step_s
                    .is_some_and(|d| t0.elapsed().as_secs_f64() > d);
                if wedged {
                    // the round "succeeded" but stalled past the
                    // deadline — a wedged execute. Discard its effects
                    // via restore and count the trip.
                    self.stats.watchdog_trips += 1;
                    self.restart(sched, "watchdog: step deadline overrun")?;
                    return Ok(0);
                }
                self.rounds_done += 1;
                self.rounds_since_ckpt += 1;
                self.consecutive_restarts = 0;
                Ok(produced)
            }
            Err(e) => {
                self.restart(sched, &format!("{e:#}"))?;
                Ok(0)
            }
        }
    }

    fn take_checkpoint(&mut self, sched: &mut Scheduler<'rt>) {
        let ck = sched.checkpoint();
        let bytes = ck.host_bytes() as u64;
        self.stats.checkpoint_bytes = bytes;
        self.stats.peak_checkpoint_bytes =
            self.stats.peak_checkpoint_bytes.max(bytes);
        self.stats.checkpoint_rounds += 1;
        self.fingerprints
            .push((self.rounds_done, sched.engine.state_fingerprint()));
        self.checkpoint_round = self.rounds_done;
        self.rounds_since_ckpt = 0;
        self.checkpoint = Some(ck);
    }

    /// Drop the poisoned engine, restore the checkpoint into a fresh
    /// one, rewind the logical clock. The checkpoint survives the
    /// restart (it is NOT re-taken), so repeated failures inside the
    /// same replay window keep restoring the same state.
    fn restart(&mut self, sched: &mut Scheduler<'rt>, why: &str)
        -> Result<()> {
        if self.consecutive_restarts >= self.cfg.max_restarts {
            self.stats.escalations += 1;
            return Err(anyhow::Error::new(RestartBudgetExhausted {
                restarts: self.consecutive_restarts,
                last_error: why.to_string(),
            }));
        }
        let us = backoff_slot_us(
            self.cfg.restart_backoff_us,
            self.consecutive_restarts,
            0,
            self.cfg.max_restart_backoff_us,
        );
        if us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(us));
        }
        self.stats.restart_backoff.record_us(us as f64);
        let Some(ck) = self.checkpoint.take() else {
            self.stats.escalations += 1;
            anyhow::bail!(
                "supervisor invariant: restart at round {} without a \
                 checkpoint (step() always checkpoints first)",
                self.rounds_done
            );
        };
        let fresh = match (self.factory)() {
            Ok(engine) => engine,
            Err(e) => {
                self.checkpoint = Some(ck);
                self.stats.escalations += 1;
                return Err(e.context(
                    "supervisor could not build a replacement engine",
                ));
            }
        };
        // tokens generated past the checkpoint are about to be
        // regenerated by replay — count them before the restore
        // overwrites the queues
        let replayed = sched
            .generated_token_total()
            .saturating_sub(ck.generated_token_total());
        if let Err(e) = sched.restore_from(fresh, &ck) {
            self.checkpoint = Some(ck);
            self.stats.escalations += 1;
            return Err(e.context("checkpoint restore failed"));
        }
        self.checkpoint = Some(ck);
        self.stats.replayed_tokens += replayed as u64;
        self.stats.engine_restarts += 1;
        self.consecutive_restarts += 1;
        self.rounds_since_ckpt = 0;
        self.rounds_done = self.checkpoint_round;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_checkpoints_and_bounds_restarts() {
        let cfg = SupervisorConfig::default();
        assert_eq!(cfg.checkpoint_every, 8);
        assert_eq!(cfg.max_restarts, 8);
        assert!(cfg.watchdog_step_s.is_none(), "watchdog is opt-in");
    }

    #[test]
    fn budget_exhaustion_is_a_typed_downcastable_error() {
        let e = anyhow::Error::new(RestartBudgetExhausted {
            restarts: 8,
            last_error: "fatal engine error in decode_step".into(),
        });
        let x = e
            .downcast_ref::<RestartBudgetExhausted>()
            .expect("router relies on this downcast");
        assert_eq!(x.restarts, 8);
        assert!(e.to_string().contains("8 consecutive restarts"));
        assert!(e.to_string().contains("decode_step"));
    }

    #[test]
    fn restart_backoff_doubles_and_clamps() {
        let cfg = SupervisorConfig::default();
        assert_eq!(
            backoff_slot_us(cfg.restart_backoff_us, 0, 0,
                            cfg.max_restart_backoff_us),
            200
        );
        assert_eq!(
            backoff_slot_us(cfg.restart_backoff_us, 3, 0,
                            cfg.max_restart_backoff_us),
            1_600
        );
        assert_eq!(
            backoff_slot_us(cfg.restart_backoff_us, 16, 0,
                            cfg.max_restart_backoff_us),
            cfg.max_restart_backoff_us
        );
    }
}
