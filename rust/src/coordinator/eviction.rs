//! Bounded-cache eviction policies over the paged block tables
//! (ISSUE 10): sink + recency pinning with an optional attention-score
//! ordering for the evictable middle.
//!
//! The policy layer decides WHICH position-slot to give up; the
//! mechanism lives elsewhere — [`crate::coordinator::kvcache`] frees the
//! block (refusing shared/registered/shared-region blocks), and
//! [`crate::coordinator::engine`] zeroes the mirror rows. Three
//! orderings over the unpinned middle:
//!
//! - **Sink**: score-free FIFO — evict the oldest unpinned slot. The
//!   attention-sink literature (StreamingLLM) motivates the pinned
//!   head; the middle falls off oldest-first.
//! - **A2SF**: forgetting-factor accumulated attention —
//!   `acc[slot] = ff * acc[slot] + step_mass[slot]` every decode step,
//!   evict the argmin. Old mass decays, so a slot that WAS hot but went
//!   cold becomes evictable (the A2SF correction to raw accumulation,
//!   which over-protects early tokens).
//! - **TOVA**: the current step's attention alone — evict the argmin of
//!   the most recent step's mass, no memory.
//!
//! Scores arrive per POSITION from the decode kernels' `attn_mass`
//! output plane (post-softmax weight, mean over layers and heads) and
//! are summed per 16-token slot; the policies only ever rank whole
//! slots because eviction frees whole blocks.

use std::collections::BTreeMap;

use super::kvcache::SeqId;

/// Which ordering picks the victim slot. `Sink` needs no scores and
/// works on legacy manifests; `A2sf`/`Tova` require the `attn_mass`
/// decode output plane.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EvictionPolicy {
    /// Eviction off: full reservations, reject-on-overflow (seed
    /// behaviour).
    #[default]
    None,
    /// Pin sink + recency, evict the oldest middle slot (FIFO).
    Sink,
    /// Pin sink + recency, evict the lowest forgetting-factor
    /// accumulated attention score.
    A2sf,
    /// Pin sink + recency, evict the lowest current-step attention.
    Tova,
}

impl EvictionPolicy {
    pub fn parse(s: &str) -> Option<EvictionPolicy> {
        match s {
            "none" => Some(EvictionPolicy::None),
            "sink" => Some(EvictionPolicy::Sink),
            "a2sf" => Some(EvictionPolicy::A2sf),
            "tova" => Some(EvictionPolicy::Tova),
            _ => None,
        }
    }

    pub fn needs_scores(&self) -> bool {
        matches!(self, EvictionPolicy::A2sf | EvictionPolicy::Tova)
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvictionPolicy::None => "none",
            EvictionPolicy::Sink => "sink",
            EvictionPolicy::A2sf => "a2sf",
            EvictionPolicy::Tova => "tova",
        }
    }
}

/// Per-sequence cache budget in blocks: `sink + window + slack` live
/// blocks is the steady-state holding of a capped stream.
#[derive(Clone, Copy, Debug)]
pub struct EvictionConfig {
    pub policy: EvictionPolicy,
    /// Leading slots never evicted (attention sinks).
    pub sink_blocks: usize,
    /// Trailing WRITTEN slots never evicted (the recency window).
    pub window_blocks: usize,
    /// Evictable middle slots the budget grants beyond the pinned
    /// regions — must be >= 1 or a stream could never grow past its
    /// pins.
    pub slack_blocks: usize,
    /// A2SF forgetting factor in (0, 1]: 1.0 = raw accumulation (H2O),
    /// smaller forgets faster.
    pub forgetting: f64,
}

impl Default for EvictionConfig {
    fn default() -> Self {
        EvictionConfig {
            policy: EvictionPolicy::None,
            sink_blocks: 1,
            window_blocks: 2,
            slack_blocks: 1,
            forgetting: 0.3,
        }
    }
}

impl EvictionConfig {
    pub fn active(&self) -> bool {
        self.policy != EvictionPolicy::None
    }

    /// Per-sequence live-block budget.
    pub fn budget_blocks(&self) -> usize {
        self.sink_blocks + self.window_blocks + self.slack_blocks
    }
}

/// Per-sequence slot scores + victim selection. Owned by the scheduler
/// and cloned into its checkpoints, so replay after a restore ranks
/// victims identically.
#[derive(Clone, Debug, Default)]
pub struct Evictor {
    pub cfg: EvictionConfig,
    /// A2SF forgetting-factor accumulated mass per slot.
    acc: BTreeMap<SeqId, Vec<f64>>,
    /// The most recent step's mass per slot (TOVA's whole memory).
    last: BTreeMap<SeqId, Vec<f64>>,
}

impl Evictor {
    pub fn new(cfg: EvictionConfig) -> Evictor {
        Evictor { cfg, ..Default::default() }
    }

    /// Fold one decode step's per-position attention mass (positions
    /// `0..rows`) into the per-slot scores. A step without a mass plane
    /// (legacy manifest, or the step before the first decode) leaves the
    /// scores untouched — Sink never calls this path's scores anyway.
    pub fn observe(&mut self, id: SeqId, mass: &[f32], bt: usize) {
        let slots = mass.len().div_ceil(bt);
        let acc = self.acc.entry(id).or_default();
        let last = self.last.entry(id).or_default();
        acc.resize(slots.max(acc.len()), 0.0);
        last.clear();
        last.resize(acc.len(), 0.0);
        for (slot, chunk) in mass.chunks(bt).enumerate() {
            let m: f64 = chunk.iter().map(|&x| x as f64).sum();
            acc[slot] = self.cfg.forgetting * acc[slot] + m;
            last[slot] = m;
        }
    }

    /// Pick the victim position-slot for `id`, or `None` when every
    /// live slot is pinned. `live_slots` are the sequence's live slots
    /// ascending (from the block table), `rows` its written rows.
    ///
    /// Pinning: slots below `sink_blocks`, slots whose range reaches
    /// into the trailing `window_blocks * bt` written rows, slots inside
    /// the shared-prefix region (`shared_rows`), and the partially
    /// written tail slot are all ineligible.
    pub fn pick_victim(&self, id: SeqId, live_slots: &[usize],
                       rows: usize, shared_rows: usize, bt: usize)
        -> Option<usize> {
        let window_floor = rows
            .saturating_sub(self.cfg.window_blocks * bt);
        let candidates: Vec<usize> = live_slots
            .iter()
            .copied()
            .filter(|&s| {
                s >= self.cfg.sink_blocks
                    && s * bt >= shared_rows
                    && (s + 1) * bt <= rows
                    && (s + 1) * bt <= window_floor
            })
            .collect();
        match self.cfg.policy {
            EvictionPolicy::None => None,
            EvictionPolicy::Sink => candidates.first().copied(),
            EvictionPolicy::A2sf => {
                self.argmin(&candidates, self.acc.get(&id))
            }
            EvictionPolicy::Tova => {
                self.argmin(&candidates, self.last.get(&id))
            }
        }
    }

    /// Candidate with the smallest score; a slot with no recorded score
    /// counts 0 (never observed => nothing recent speaks for keeping
    /// it). Ties break oldest-first, matching Sink.
    fn argmin(&self, candidates: &[usize], scores: Option<&Vec<f64>>)
        -> Option<usize> {
        candidates.iter().copied().min_by(|&a, &b| {
            let sa = scores.and_then(|s| s.get(a)).copied().unwrap_or(0.0);
            let sb = scores.and_then(|s| s.get(b)).copied().unwrap_or(0.0);
            sa.partial_cmp(&sb)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        })
    }

    /// Forget a retired sequence's scores.
    pub fn drop_seq(&mut self, id: SeqId) {
        self.acc.remove(&id);
        self.last.remove(&id);
    }

    /// Accumulated A2SF score per slot (fidelity experiment surface).
    pub fn acc_scores(&self, id: SeqId) -> Option<&[f64]> {
        self.acc.get(&id).map(|v| v.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn evictor(policy: EvictionPolicy) -> Evictor {
        Evictor::new(EvictionConfig {
            policy,
            sink_blocks: 1,
            window_blocks: 1,
            slack_blocks: 2,
            forgetting: 0.5,
        })
    }

    const BT: usize = 16;

    #[test]
    fn sink_evicts_oldest_unpinned_slot() {
        let ev = evictor(EvictionPolicy::Sink);
        // 6 slots, 96 written rows: slot 0 is sink, slot 5 is window
        let live: Vec<usize> = (0..6).collect();
        assert_eq!(ev.pick_victim(1, &live, 96, 0, BT), Some(1));
        // with slot 1 already evicted, the next-oldest middle goes
        let live = vec![0, 2, 3, 4, 5];
        assert_eq!(ev.pick_victim(1, &live, 96, 0, BT), Some(2));
    }

    #[test]
    fn window_and_sink_are_never_candidates() {
        let ev = evictor(EvictionPolicy::Sink);
        // only sink + window written: nothing evictable
        let live = vec![0, 1];
        assert_eq!(ev.pick_victim(1, &live, 32, 0, BT), None);
        // partial tail slot is pinned even outside the window
        let live = vec![0, 1, 2];
        assert_eq!(ev.pick_victim(1, &live, 40, 0, BT), None);
    }

    #[test]
    fn shared_region_is_pinned() {
        let ev = evictor(EvictionPolicy::Sink);
        let live: Vec<usize> = (0..6).collect();
        // slots 0..3 shared: the first evictable middle slot is 3
        assert_eq!(ev.pick_victim(1, &live, 96, 48, BT), Some(3));
    }

    #[test]
    fn a2sf_evicts_lowest_accumulated_mass() {
        let mut ev = evictor(EvictionPolicy::A2sf);
        // slot 2 consistently cold, slot 1 and 3 hot
        let mut mass = vec![0.2f32; 64];
        for p in 32..48 {
            mass[p] = 0.001;
        }
        ev.observe(1, &mass, BT);
        ev.observe(1, &mass, BT);
        let live: Vec<usize> = (0..5).collect();
        assert_eq!(ev.pick_victim(1, &live, 80, 0, BT), Some(2));
    }

    #[test]
    fn a2sf_forgetting_lets_cold_slots_overtake_old_hot_ones() {
        let mut ev = evictor(EvictionPolicy::A2sf);
        // step 1: slot 1 very hot, everything else modestly warm
        let mut m1 = vec![0.1f32; 64];
        for p in 16..32 {
            m1[p] = 1.0;
        }
        for p in 32..48 {
            m1[p] = 0.3;
        }
        ev.observe(1, &m1, BT);
        // many later steps: slot 1 stone cold, the rest stay warm
        let mut m2 = vec![0.1f32; 64];
        for p in 16..32 {
            m2[p] = 0.0;
        }
        for p in 32..48 {
            m2[p] = 0.3;
        }
        for _ in 0..8 {
            ev.observe(1, &m2, BT);
        }
        let live: Vec<usize> = (0..5).collect();
        // ff=0.5 decayed slot 1's old glory below slot 2's steady mass
        assert_eq!(ev.pick_victim(1, &live, 80, 0, BT), Some(1));
        // raw accumulation (ff=1.0) would have kept slot 1 forever
        let mut raw = ev.clone();
        raw.cfg.forgetting = 1.0;
        raw.drop_seq(1);
        raw.observe(1, &m1, BT);
        for _ in 0..8 {
            raw.observe(1, &m2, BT);
        }
        assert_eq!(raw.pick_victim(1, &live, 80, 0, BT), Some(3),
                   "H2O-style accumulation protects the old hot slot");
    }

    #[test]
    fn tova_uses_only_the_current_step() {
        let mut ev = evictor(EvictionPolicy::Tova);
        // history says slot 1 cold — but TOVA must ignore history
        let mut m1 = vec![0.2f32; 64];
        for p in 16..32 {
            m1[p] = 0.001;
        }
        ev.observe(1, &m1, BT);
        // current step: slot 3 cold
        let mut m2 = vec![0.2f32; 64];
        for p in 48..64 {
            m2[p] = 0.001;
        }
        ev.observe(1, &m2, BT);
        let live: Vec<usize> = (0..5).collect();
        assert_eq!(ev.pick_victim(1, &live, 80, 0, BT), Some(3));
    }

    #[test]
    fn policy_parse_round_trips() {
        for p in [EvictionPolicy::None, EvictionPolicy::Sink,
                  EvictionPolicy::A2sf, EvictionPolicy::Tova] {
            assert_eq!(EvictionPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(EvictionPolicy::parse("h2o"), None);
        assert!(EvictionPolicy::A2sf.needs_scores());
        assert!(!EvictionPolicy::Sink.needs_scores());
    }
}
