//! Continuous-batching scheduler: token-budget rounds, priority-aware
//! admission, chunked prefill interleaved with decode, preemption on
//! cache pressure.
//!
//! A scheduling round spends a configurable token budget
//! ([`SchedConfig::round_budget`]) split between the decode lanes (one
//! token per running sequence) and **at most one in-flight chunked
//! prefill** ([`SchedConfig::chunk_tokens`]): instead of ingesting a whole
//! prompt in one monolithic call — which stalls every decoding chat user
//! for the duration of a 4K-token document — prefill advances one C-token
//! chunk per round through the resumable `prefill_{cfg}_c{C}` artifacts
//! ([`crate::coordinator::engine::Engine::prefill_chunk`]).
//!
//! Priority classes ([`Priority`]): Interactive traffic is admitted and
//! granted chunks ahead of Batch traffic, so a chat request arriving
//! mid-document preempts the ingestion *at the chunk boundary* rather
//! than mid-prompt or (worse) after the full prompt. A weighted
//! anti-starvation counter ([`SchedConfig::interactive_weight`]) grants a
//! Batch chunk after that many consecutive Interactive grants, so
//! document ingestion keeps making progress under sustained chat load —
//! including ADMISSION of a still-waiting Batch document: the boosted
//! grant probes the Batch class's own head-of-line directly
//! (`admissible_in_class`) instead of the fixed Interactive-first scan
//! that used to starve a queued document for as long as admissible chats
//! kept arriving (the ROADMAP open item, regression-tested in
//! rust/tests/serving_e2e.rs::batch_doc_survives_sustained_interactive_stream).
//!
//! Admission reserves the *full* context (prompt + max_new) per sequence —
//! the same per-user reservation the paper's Table 10 capacity math uses,
//! which is exactly where thin keys admit more concurrent users. A
//! partially prefilled sequence holds its reservation across rounds (its
//! chunks are already in the arena); cancelling it (failure, drain)
//! releases blocks and arena rows on the same event.
//!
//! The scheduler is also the keeper of the unified accounting contract:
//! after every prefill chunk and decode step it mirrors the engine's
//! physically written rows into `KvCacheManager::commit_rows`, and a
//! sequence's logical blocks and physical arena rows are always freed
//! together on the same event ([`Scheduler::free_seq`]). The invariants
//! are property-tested under randomized traffic in
//! rust/tests/scheduler_props.rs.

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Result};

use crate::coordinator::engine::{Engine, EngineCheckpoint};
use crate::coordinator::errors::EngineError;
use crate::coordinator::eviction::{EvictionConfig, Evictor};
use crate::coordinator::kvcache::{KvCacheManager, SeqId};
use crate::coordinator::sequence::{FinishReason, Priority, Sequence};

/// Round-scheduler knobs. `Default` reproduces the pre-chunking scheduler
/// (monolithic prefill, one per round).
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Max concurrent sequences holding KV reservations (running +
    /// in-flight prefills).
    pub max_batch: usize,
    /// Tokens one scheduling round may spend: each running sequence's
    /// decode step costs 1, a prefill chunk costs `chunk_tokens`. Only
    /// enforced in chunked mode; size it so a chunk fits next to the
    /// expected decode load (see EXPERIMENTS.md §Chunked).
    pub round_budget: usize,
    /// `Some(c)` = chunked prefill with C-token chunks (must be an
    /// exported chunk size, `manifest.prefill_chunks`); `None` =
    /// monolithic prefill (legacy behaviour).
    pub chunk_tokens: Option<usize>,
    /// After this many consecutive chunk grants to Interactive prefills
    /// while Batch work is pending, grant one Batch chunk (anti-
    /// starvation; 0 disables the boost and Batch waits indefinitely).
    pub interactive_weight: usize,
    /// Bounded retry budget for retryable engine-step failures
    /// (Transient, or injected SequenceLocal): a step is re-attempted up
    /// to this many times with exponential backoff before the failure is
    /// terminal (quarantine or escalation). Sized above the injector's
    /// burst clamp, a transient fault schedule always recovers.
    pub max_step_retries: usize,
    /// Base backoff before the first retry, in microseconds; doubles per
    /// attempt (`base << attempt`), clamped by `max_step_backoff_us`.
    pub retry_backoff_us: u64,
    /// Hard cap on the CUMULATIVE backoff sleep one engine step (and
    /// hence one scheduling round) may spend, in microseconds. The
    /// uncapped shift used to real-sleep `200µs << 16` ≈ 13s inside a
    /// round — no shed/deadline pass can run mid-round, so a bursty
    /// fault plan inflated TTFT of unaffected Interactive sequences far
    /// past their deadlines. Keep this well below the Interactive
    /// deadline (regression-tested in this module).
    pub max_step_backoff_us: u64,
    /// Copy-on-write shared-prefix sharing (ISSUE 8): admission matches
    /// prompts against the prefix tree and adopts shared blocks instead
    /// of re-prefilling them. Off reproduces fully private per-sequence
    /// storage (the bit-exactness baseline).
    pub prefix_sharing: bool,
    /// Bounded-cache eviction (ISSUE 10): with an active policy,
    /// admission reserves at most `budget_blocks()` worth of tokens per
    /// sequence (instead of the full `prompt + max_new`) and every
    /// decode round trims each running sequence back to the budget by
    /// evicting whole middle blocks — sink and recency-window slots
    /// pinned, shared-prefix blocks never touched.
    /// `EvictionPolicy::None` reproduces the seed's full-reservation,
    /// reject-on-overflow behaviour exactly.
    pub eviction: EvictionConfig,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig {
            max_batch: 16,
            round_budget: 128,
            chunk_tokens: None,
            interactive_weight: 4,
            max_step_retries: 4,
            retry_backoff_us: 200,
            max_step_backoff_us: 10_000,
            prefix_sharing: true,
            eviction: EvictionConfig::default(),
        }
    }
}

/// How many budget-stalled rounds an in-flight prefill tolerates before
/// it advances anyway — the liveness escape for workloads whose decode
/// lanes permanently exceed `round_budget`.
const STALL_OVERRIDE_ROUNDS: usize = 4;

/// One exponential-backoff slot, clamped so the CUMULATIVE sleep already
/// `spent` within the current engine step never exceeds `cap`. Pure so
/// the satellite-1 regression tests can pin the arithmetic: the raw
/// `base << attempt.min(16)` slot reaches `200µs << 16` ≈ 13.1s, which
/// used to real-sleep inside a serving round with no shed/deadline pass
/// able to run. Once the budget is spent the slot is zero (retry
/// immediately rather than oversleep).
pub fn backoff_slot_us(base: u64, attempt: usize, spent: u64, cap: u64)
    -> u64 {
    base.checked_shl(attempt.min(16) as u32)
        .unwrap_or(u64::MAX)
        .min(cap.saturating_sub(spent))
}

/// A full host-side clone of the scheduler's serving state: the engine's
/// [`EngineCheckpoint`] (lane map, arena mirrors, parked/chunking rows,
/// prefix store, sampler RNG, metrics) plus the paged block accounting
/// ([`KvCacheManager`] — tables, refcounts, prefix tree) and every queue.
/// Where [`EngineCheckpoint`] rebuilds one engine, `SchedCheckpoint`
/// rebuilds the whole serving loop: the supervisor takes one every K
/// rounds and, after a Fatal, restores a FRESH engine from it and replays
/// the rounds since (see `coordinator/supervisor.rs`).
pub struct SchedCheckpoint {
    engine: EngineCheckpoint,
    kv: KvCacheManager,
    next_id: SeqId,
    waiting: VecDeque<Sequence>,
    prefilling: BTreeMap<SeqId, Sequence>,
    running: BTreeMap<SeqId, Sequence>,
    finished: Vec<Sequence>,
    interactive_grants: usize,
    stalled_rounds: usize,
    chunk_checked: bool,
    evictor: Evictor,
}

impl SchedCheckpoint {
    /// Host bytes pinned by this checkpoint's arena mirrors (payload +
    /// scale planes across group/parked/chunking/prefix arenas) — the
    /// supervisor's checkpoint byte gauge.
    pub fn host_bytes(&self) -> usize {
        self.engine.host_bytes()
    }

    /// Total generated tokens captured at checkpoint time — the baseline
    /// the supervisor subtracts to count `replayed_tokens` after a
    /// restart.
    pub fn generated_token_total(&self) -> usize {
        self.prefilling
            .values()
            .chain(self.running.values())
            .chain(self.finished.iter())
            .map(|s| s.generated.len())
            .sum()
    }
}

pub struct Scheduler<'rt> {
    pub engine: Engine<'rt>,
    pub kv: KvCacheManager,
    pub cfg: SchedConfig,
    next_id: SeqId,
    waiting: VecDeque<Sequence>,
    /// Admitted sequences whose prompt is partially ingested (chunked
    /// mode only). They hold full KV reservations; at most one advances
    /// per round, chosen by priority.
    prefilling: BTreeMap<SeqId, Sequence>,
    running: BTreeMap<SeqId, Sequence>,
    pub finished: Vec<Sequence>,
    /// Consecutive chunk grants to Interactive prefills while Batch work
    /// was pending (anti-starvation counter).
    interactive_grants: usize,
    /// Consecutive rounds the pending prefill was budget-stalled.
    stalled_rounds: usize,
    /// Did the last `step()` make prefill/admission progress? Consulted
    /// by `run_to_completion` so an advancing chunked prefill is never
    /// mistaken for a stall (see `flush_unservable`).
    progressed: bool,
    /// `cfg.chunk_tokens` has been validated against the manifest's
    /// exported chunk sizes (checked once, on the first chunked round).
    chunk_checked: bool,
    /// Eviction policy state (per-slot attention scores + victim
    /// selection); inert when `cfg.eviction` is `None`.
    evictor: Evictor,
}

impl<'rt> Scheduler<'rt> {
    /// Monolithic-prefill scheduler (pre-chunking behaviour) with the
    /// given batch cap.
    pub fn new(engine: Engine<'rt>, kv: KvCacheManager, max_batch: usize)
        -> Scheduler<'rt> {
        Self::with_config(
            engine,
            kv,
            SchedConfig { max_batch, ..SchedConfig::default() },
        )
    }

    pub fn with_config(mut engine: Engine<'rt>, kv: KvCacheManager,
                       cfg: SchedConfig) -> Scheduler<'rt> {
        // the engine's shared-prefix store speaks the pool's block
        // geometry from the start
        engine.set_block_tokens(kv.cfg.block_tokens);
        if cfg.eviction.active() {
            engine.metrics.eviction.budget_blocks =
                cfg.eviction.budget_blocks() as u64;
        }
        Scheduler {
            engine,
            kv,
            cfg,
            next_id: 1,
            waiting: VecDeque::new(),
            prefilling: BTreeMap::new(),
            running: BTreeMap::new(),
            finished: Vec::new(),
            interactive_grants: 0,
            stalled_rounds: 0,
            progressed: false,
            chunk_checked: false,
            evictor: Evictor::new(cfg.eviction),
        }
    }

    /// Enqueue an Interactive request. Returns its sequence id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize, eos: Option<i32>)
        -> SeqId {
        self.submit_seq(prompt, max_new, eos, Priority::Interactive, None)
    }

    /// Enqueue a request with an explicit priority class and optional
    /// backdated arrival stamp (the trace arrival time, so TTFT charges
    /// queueing delay incurred while the scheduler was mid-round).
    pub fn submit_seq(&mut self, prompt: Vec<i32>, max_new: usize,
                      eos: Option<i32>, priority: Priority,
                      arrived: Option<std::time::Instant>) -> SeqId {
        let id = self.next_id;
        self.next_id += 1;
        let mut seq =
            Sequence::new(id, prompt, max_new, eos).with_priority(priority);
        if let Some(t) = arrived {
            seq = seq.with_arrival(t);
        }
        self.waiting.push_back(seq);
        id
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    /// In-flight chunked prefills (admitted, prompt partially ingested).
    pub fn n_prefilling(&self) -> usize {
        self.prefilling.len()
    }

    /// Ids of the running (decoding) sequences, ascending — the valid
    /// fork targets for [`Scheduler::fork`].
    pub fn running_ids(&self) -> Vec<SeqId> {
        self.running.keys().copied().collect()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty()
            || !self.running.is_empty()
            || !self.prefilling.is_empty()
    }

    /// The full per-user context reservation (Table 10 capacity math).
    fn full_reservation(seq: &Sequence) -> usize {
        seq.prompt.len() + seq.max_new
    }

    /// Blocks reserved at admission. Without eviction this is the full
    /// `prompt + max_new` context (reject-on-overflow, the seed
    /// behaviour). With an active eviction policy the reservation is
    /// capped at the per-sequence live-block budget — never below the
    /// prompt plus the first decode row, since prefill must land whole —
    /// and the sequence grows past it by evicting its own middle blocks
    /// (`evict_round`), so an unbounded stream admits on a bounded pool.
    fn reservation(&self, seq: &Sequence) -> usize {
        let full = Self::full_reservation(seq);
        if !self.cfg.eviction.active() {
            return full;
        }
        let cap =
            self.cfg.eviction.budget_blocks() * self.kv.cfg.block_tokens;
        full.min(cap.max(seq.prompt.len() + 1))
    }

    /// Snapshot the complete serving state host-side. Pure clone — the
    /// delta-synced host mirrors already hold every arena row, so no
    /// device traffic is charged (the restore side re-uploads).
    pub fn checkpoint(&self) -> SchedCheckpoint {
        SchedCheckpoint {
            engine: self.engine.checkpoint(),
            kv: self.kv.clone(),
            next_id: self.next_id,
            waiting: self.waiting.clone(),
            prefilling: self.prefilling.clone(),
            running: self.running.clone(),
            finished: self.finished.clone(),
            interactive_grants: self.interactive_grants,
            stalled_rounds: self.stalled_rounds,
            chunk_checked: self.chunk_checked,
            evictor: self.evictor.clone(),
        }
    }

    /// Warm restart: drop the (poisoned) engine, install `fresh` — built
    /// from the same `Manifest` — and rebuild every queue, the block
    /// accounting, and the engine's host state from the checkpoint.
    /// Device literals for in-flight chunked prefills are re-uploaded
    /// eagerly (charged to `sync_upload_bytes`); everything else
    /// re-uploads lazily through the same `in_sync` path a tier switch
    /// uses. After this returns, stepping resumes exactly at the
    /// checkpointed round: replay is ordinary re-stepping.
    pub fn restore_from(&mut self, fresh: Engine<'rt>, ck: &SchedCheckpoint)
        -> Result<()> {
        let mut engine = fresh;
        engine.restore(&ck.engine)?;
        // the old engine (with whatever poisoned device state it held)
        // drops here
        self.engine = engine;
        self.kv = ck.kv.clone();
        self.next_id = ck.next_id;
        self.waiting = ck.waiting.clone();
        self.prefilling = ck.prefilling.clone();
        self.running = ck.running.clone();
        self.finished = ck.finished.clone();
        self.interactive_grants = ck.interactive_grants;
        self.stalled_rounds = ck.stalled_rounds;
        self.progressed = false;
        self.chunk_checked = ck.chunk_checked;
        self.evictor = ck.evictor.clone();
        Ok(())
    }

    /// Total generated tokens across in-flight and finished sequences —
    /// compared against a checkpoint's total to count replayed tokens.
    pub fn generated_token_total(&self) -> usize {
        self.prefilling
            .values()
            .chain(self.running.values())
            .chain(self.finished.iter())
            .map(|s| s.generated.len())
            .sum()
    }

    /// Did the last `step()` make prefill/admission progress? The
    /// router's drain loop consults this (like `run_to_completion`) so an
    /// advancing chunked prefill is never mistaken for a stall.
    pub(crate) fn made_progress(&self) -> bool {
        self.progressed
    }

    /// Restart-budget exhaustion: the supervisor gave up on reviving the
    /// engine, so serve what can be served without it — shed the waiting
    /// queue and fail every sequence holding a reservation, releasing
    /// blocks and arena rows on the same event as always. Every
    /// accounting touched here is host-side, so this is safe to run with
    /// a poisoned engine.
    pub fn drain_for_escalation(&mut self) {
        while let Some(mut seq) = self.waiting.pop_front() {
            seq.finish(FinishReason::Shed);
            self.finished.push(seq);
        }
        let ids: Vec<SeqId> = self
            .prefilling
            .keys()
            .chain(self.running.keys())
            .copied()
            .collect();
        for id in ids {
            let seq = self
                .prefilling
                .remove(&id)
                .or_else(|| self.running.remove(&id));
            if let Some(mut seq) = seq {
                self.free_seq(id);
                seq.finish(FinishReason::Failed);
                self.engine.metrics.quarantined_seqs += 1;
                self.finished.push(seq);
            }
        }
    }

    /// Free a sequence's logical KV blocks and physical cache rows on the
    /// same event — the two accountings never disagree about liveness.
    /// Also cancels any in-flight chunked prefill state. Blocks whose
    /// refcount hit zero leave the shared prefix store on the same event
    /// (`release` returns exactly that freed list).
    fn free_seq(&mut self, id: SeqId) {
        let freed = self.kv.release(id);
        self.engine.drop_seq(id);
        self.engine.drop_blocks(&freed);
        self.evictor.drop_seq(id);
    }

    /// Post-decode cache maintenance for one running sequence under an
    /// active eviction policy: fold this step's attention mass into the
    /// slot scores, grow the logical reservation to cover the newly
    /// written row — self-funding the fresh block by evicting one of its
    /// own middle blocks when at budget or the pool is dry, so a capped
    /// stream never takes net-new pool blocks past its admission — and
    /// trim back to the per-sequence live-block budget. Runs before
    /// `commit_rows`, which would otherwise reject rows past the capped
    /// reservation.
    fn evict_round(&mut self, id: SeqId) -> Result<()> {
        let bt = self.kv.cfg.block_tokens;
        let rows = self.engine.rows(id);
        if let Some(m) = self.engine.step_attn_mass(id) {
            self.evictor.observe(id, m, bt);
            self.engine.metrics.eviction.score_steps += 1;
        }
        let reserved = self.kv.seq_tokens(id).unwrap_or(0);
        let budget = self.cfg.eviction.budget_blocks();
        if rows > reserved {
            let need_fresh = rows.div_ceil(bt) > reserved.div_ceil(bt);
            if need_fresh {
                let live = self.kv.live_blocks(id).unwrap_or(0);
                if live >= budget || self.kv.free_token_capacity() == 0 {
                    self.trim_to(id, live.saturating_sub(1), rows)?;
                }
            }
            self.kv.extend(id, rows - reserved)?;
        }
        self.trim_to(id, budget, rows)?;
        let live = self.kv.live_blocks(id).unwrap_or(0) as u64;
        let ev = &mut self.engine.metrics.eviction;
        ev.peak_seq_blocks = ev.peak_seq_blocks.max(live);
        Ok(())
    }

    /// Evict policy-chosen victim blocks from `id` until it holds at
    /// most `target` live blocks. Stops early — without error — when
    /// every live slot is pinned (sink, recency window, shared prefix,
    /// partial tail) or the mechanism refuses the pick (shared or
    /// registered block, counted as `refused_shared`).
    fn trim_to(&mut self, id: SeqId, target: usize, rows: usize)
        -> Result<()> {
        let bt = self.kv.cfg.block_tokens;
        loop {
            let live = self.kv.live_blocks(id).unwrap_or(0);
            if live <= target {
                return Ok(());
            }
            let slots = self.kv.live_slots(id).unwrap_or_default();
            let shared = self.kv.shared_rows(id).unwrap_or(0);
            let Some(victim) =
                self.evictor.pick_victim(id, &slots, rows, shared, bt)
            else {
                return Ok(());
            };
            match self.kv.evict_slot(id, victim) {
                Ok(_) => {
                    self.engine.evict_rows(id, victim * bt, bt)?;
                    self.engine.metrics.eviction.evicted_blocks += 1;
                }
                Err(_) => {
                    self.engine.metrics.eviction.refused_shared += 1;
                    return Ok(());
                }
            }
        }
    }

    /// Reserve blocks for a newly admitted sequence, adopting any
    /// registered shared prefix of its prompt (ISSUE 8): matched blocks
    /// refcount-bump instead of allocating, the engine is pointed at
    /// them, and both prefill paths then skip the adopted rows entirely
    /// — the prefix-hit fast path.
    fn admit_blocks(&mut self, seq: &Sequence) -> Result<()> {
        let res = self.reservation(seq);
        let full = Self::full_reservation(seq);
        let capped = res < full
            && !self.kv.can_admit_prompt(&seq.prompt, full,
                                         self.cfg.prefix_sharing);
        let grant = self.kv.allocate_prompt(
            seq.id,
            &seq.prompt,
            res,
            self.cfg.prefix_sharing,
        )?;
        if capped {
            // this admission only fit because of the eviction cap — the
            // bounded-cache headline the acceptance trace asserts on
            self.engine.metrics.eviction.capped_admissions += 1;
        }
        if grant.matched_rows > 0 {
            if let Err(e) = self.engine.adopt_prefix(
                seq.id, &grant.matched_blocks, grant.matched_rows)
            {
                // logical tables and physical store diverged — roll the
                // reservation back before surfacing the inconsistency
                let freed = self.kv.release(seq.id);
                self.engine.drop_blocks(&freed);
                return Err(e);
            }
            self.engine.metrics.prefix_hits += 1;
            self.engine.metrics.prefix_hit_tokens +=
                grant.matched_rows as u64;
        }
        Ok(())
    }

    /// After a completed prefill (still parked): register the prompt's
    /// full blocks in the prefix tree and publish the newly registered
    /// ones into the engine's shared store, so the NEXT sequence with
    /// this prefix admits straight onto them.
    fn seal_prefix(&mut self, seq: &Sequence) -> Result<()> {
        if !self.cfg.prefix_sharing {
            return Ok(());
        }
        let sealed = self.kv.seal_prefix(seq.id, &seq.prompt)?;
        if sealed.shared_rows > 0 {
            self.engine.publish_prefix(seq.id, &sealed.registered,
                                       &sealed.blocks, sealed.shared_rows)?;
        }
        Ok(())
    }

    /// Fork a RUNNING sequence copy-on-write (ISSUE 8): the child shares
    /// every full block the parent has written (refcount only — zero
    /// bytes for the shared history), privately copies the partial tail
    /// block, and decodes independently from the next round on. Returns
    /// the child's id.
    pub fn fork(&mut self, parent: SeqId, max_new: usize) -> Result<SeqId> {
        if self.running.len() + self.prefilling.len() >= self.cfg.max_batch {
            bail!("fork: batch is full");
        }
        let Some(pseq) = self.running.get(&parent) else {
            bail!("fork: parent {parent} is not running");
        };
        let id = self.next_id;
        self.next_id += 1;
        let child = pseq.fork_as(id, max_new);
        let rows = self.engine.rows(parent);
        let grant = self.kv.fork(parent, id, child.len() + max_new)?;
        if let Err(e) = self.engine.fork_seq(parent, id, &grant) {
            let freed = self.kv.release(id);
            self.engine.drop_blocks(&freed);
            return Err(e);
        }
        self.kv.commit_rows(id, rows)?;
        self.engine.metrics.cow_splits += u64::from(grant.cow_split);
        self.running.insert(id, child);
        Ok(id)
    }

    /// Admit from the waiting queue while budget and batch slots allow
    /// (monolithic mode). At most `max_prefills` prefills per round
    /// (prefill is expensive and would starve decode otherwise).
    /// Admission is priority-aware: the front of the Interactive class is
    /// considered before any Batch request, and a blocked Interactive
    /// head blocks Batch admission too (see [`Scheduler::next_admissible`]).
    fn admit(&mut self, max_prefills: usize) -> Result<usize> {
        let mut admitted = 0;
        while admitted < max_prefills
            && self.running.len() + self.prefilling.len() < self.cfg.max_batch
            && !self.waiting.is_empty()
        {
            let Some(idx) = self.next_admissible() else { break };
            let mut seq = self.waiting.remove(idx)
                .expect("next_admissible returns an index into waiting");
            if let Err(e) = self.admit_blocks(&seq) {
                // the admit_blocks-then-fail window: release whatever the
                // partial grant reserved and surface the failure ON the
                // request — the old `?` propagated the error while
                // silently dropping the sequence with its blocks
                self.free_seq(seq.id);
                seq.finish(FinishReason::PrefillFailed);
                self.finished.push(seq);
                return Err(e);
            }
            self.progressed = true;
            if let Err(e) = self.with_retries(|eng| eng.prefill(&mut seq)) {
                if matches!(e, EngineError::Fatal { .. }) {
                    // poisoned engine: free this sequence's blocks/rows
                    // (host-side accounting only), requeue it untouched
                    // for the post-restart world, and escalate
                    self.free_seq(seq.id);
                    seq.reset_for_restart();
                    self.waiting.push_front(seq);
                    self.engine.metrics.fatal_steps += 1;
                    return Err(e.into());
                }
                // roll the reservation back and fail the request visibly
                // instead of leaking the blocks and dropping the sequence
                self.free_seq(seq.id);
                seq.finish(self.prefill_failure_reason(&e));
                self.finished.push(seq);
                admitted += 1;
                continue;
            }
            self.kv.commit_rows(seq.id, self.engine.rows(seq.id))?;
            if seq.is_finished() {
                self.free_seq(seq.id);
                self.finished.push(seq);
            } else {
                self.seal_prefix(&seq)?;
                self.running.insert(seq.id, seq);
            }
            admitted += 1;
        }
        Ok(admitted)
    }

    /// Class-targeted admissibility probe (the ROADMAP starvation fix):
    /// the waiting-queue index of `class`'s OWN head-of-line request, if
    /// it exists and its reservation fits — independent of what any other
    /// class's head is doing. `prefill_round` uses this so a boosted
    /// Batch grant can actually admit a waiting Batch document instead of
    /// only ever finding the Interactive head under sustained chat load.
    fn admissible_in_class(&self, class: Priority) -> Option<usize> {
        let (idx, seq) = self
            .waiting
            .iter()
            .enumerate()
            .find(|(_, s)| s.priority == class)?;
        // the probe credits a prefix hit's adopted blocks, so sharing
        // admits strictly more concurrent sequences on the same pool
        if self.kv.can_admit_prompt(&seq.prompt, self.reservation(seq),
                                    self.cfg.prefix_sharing) {
            Some(idx)
        } else {
            None
        }
    }

    /// Index of the next admissible waiting request: the front of the
    /// highest-priority class present, if its reservation fits. A blocked
    /// Interactive head gates ALL admission — Batch must not backfill the
    /// freed capacity, or retirements would never accumulate enough free
    /// blocks for a large Interactive request (head-of-line blocking by
    /// design, now class-aware; an Interactive head that can never fit is
    /// still evicted by `flush_unservable`, so this cannot wedge).
    fn next_admissible(&self) -> Option<usize> {
        for class in [Priority::Interactive, Priority::Batch] {
            if self.waiting.iter().any(|s| s.priority == class) {
                return self.admissible_in_class(class);
            }
        }
        None
    }

    /// One prefill-side round in chunked mode: pick the highest-priority
    /// prefill (in-flight before waiting within a class, Interactive
    /// before Batch, with the anti-starvation boost), admit it if still
    /// waiting, and advance it by one chunk. Returns the prompt tokens
    /// consumed (0 when there was nothing to do or admission failed).
    fn prefill_round(&mut self, chunk: usize) -> Result<usize> {
        // who wants to prefill?
        let inflight_classes: Vec<Priority> =
            self.prefilling.values().map(|s| s.priority).collect();
        let has_slot =
            self.running.len() + self.prefilling.len() < self.cfg.max_batch;
        // class-targeted admissibility probes: each class's own waiting
        // head is checked against the cache independently, so the boosted
        // Batch arm below can see past an Interactive head (the
        // `next_admissible` fixed Interactive-first scan starved a
        // WAITING Batch document under sustained admissible Interactive
        // load — the anti-starvation weight fired but the pick loop only
        // ever found the Interactive head; see the
        // `batch_doc_survives_sustained_interactive_stream` e2e test).
        let adm_inter = if has_slot {
            self.admissible_in_class(Priority::Interactive)
        } else {
            None
        };
        let interactive_waiting = self
            .waiting
            .iter()
            .any(|s| s.priority == Priority::Interactive);
        let batch_pending = inflight_classes.contains(&Priority::Batch)
            || self
                .waiting
                .iter()
                .any(|s| s.priority == Priority::Batch);
        let boost_batch = batch_pending
            && self.cfg.interactive_weight > 0
            && self.interactive_grants >= self.cfg.interactive_weight;
        // Head-of-line discipline: a waiting Batch request is only
        // admitted past a present Interactive class when the
        // anti-starvation boost fires AND the Interactive head is itself
        // admissible — i.e. the boost redistributes grants under
        // sustained *servable* Interactive load (the starvation bug),
        // never backfills capacity a BLOCKED Interactive head is
        // accumulating toward (that no-backfill invariant is why
        // `next_admissible` gates all admission on the blocked head; a
        // boosted Batch reservation there would be a priority inversion
        // lasting the document's whole lifetime). In-flight Batch
        // prefills may always resume — they hold their reservation
        // already.
        let interactive_blocked = interactive_waiting && adm_inter.is_none();
        let adm_batch = if has_slot
            && !interactive_blocked
            && (boost_batch || !interactive_waiting)
        {
            self.admissible_in_class(Priority::Batch)
        } else {
            None
        };
        if inflight_classes.is_empty()
            && adm_inter.is_none()
            && adm_batch.is_none()
        {
            return Ok(0);
        }
        // budget: this round's decode spends one token per running lane
        let decode_spend = self.running.len();
        if decode_spend + chunk > self.cfg.round_budget
            && !self.running.is_empty()
        {
            self.engine.metrics.chunk_stall_steps += 1;
            self.stalled_rounds += 1;
            if self.stalled_rounds <= STALL_OVERRIDE_ROUNDS {
                return Ok(0);
            }
            // liveness escape: the decode load alone permanently exceeds
            // the budget — advance the prefill anyway
        }
        self.stalled_rounds = 0;

        // class choice: Interactive first, unless the anti-starvation
        // boost fires for pending Batch work
        let interactive_available =
            inflight_classes.contains(&Priority::Interactive)
                || adm_inter.is_some();
        let class_order = if boost_batch || !interactive_available {
            [Priority::Batch, Priority::Interactive]
        } else {
            [Priority::Interactive, Priority::Batch]
        };

        // pick: in-flight before waiting within the chosen class (finish
        // what was started — bounds the number of half-ingested arenas);
        // the waiting arm uses the class's OWN admissibility probe, so a
        // boosted Batch round admits the waiting Batch head even while
        // Interactive requests keep arriving in front of it
        let mut chosen: Option<Sequence> = None;
        'pick: for class in class_order {
            if let Some(&id) = self
                .prefilling
                .iter()
                .find(|(_, s)| s.priority == class)
                .map(|(id, _)| id)
            {
                chosen = Some(self.prefilling.remove(&id)
                    .expect("in-flight id taken from the prefilling map"));
                break 'pick;
            }
            let admissible = match class {
                Priority::Interactive => adm_inter,
                Priority::Batch => adm_batch,
            };
            if let Some(idx) = admissible {
                let mut seq = self.waiting.remove(idx)
                    .expect("admissibility probe indexes the waiting queue");
                if let Err(e) = self.admit_blocks(&seq) {
                    // same admit_blocks-then-fail window as `admit`: the
                    // request fails visibly instead of leaking with the
                    // propagated error
                    self.free_seq(seq.id);
                    seq.finish(FinishReason::PrefillFailed);
                    self.finished.push(seq);
                    return Err(e);
                }
                chosen = Some(seq);
                break 'pick;
            }
        }
        let Some(mut seq) = chosen else { return Ok(0) };
        self.progressed = true;

        // weighted-admission bookkeeping
        if seq.priority == Priority::Interactive && batch_pending {
            self.interactive_grants += 1;
        } else {
            self.interactive_grants = 0;
        }

        let before = self.engine.rows(seq.id);
        match self.with_retries(|eng| eng.prefill_chunk(&mut seq, chunk)) {
            Err(e) if matches!(e, EngineError::Fatal { .. }) => {
                // poisoned engine mid-chunked-prefill: release the
                // reservation AND the partial arena together (host-side),
                // requeue from scratch, escalate to the supervisor — the
                // pre-fix path quarantined the sequence and kept stepping
                // a dead engine
                self.free_seq(seq.id);
                seq.reset_for_restart();
                self.waiting.push_front(seq);
                self.engine.metrics.fatal_steps += 1;
                Err(e.into())
            }
            Err(e) => {
                // roll back reservation + any partial arena, fail visibly
                self.free_seq(seq.id);
                seq.finish(self.prefill_failure_reason(&e));
                self.finished.push(seq);
                Ok(0)
            }
            Ok(done) => {
                let now = self.engine.rows(seq.id);
                self.kv.commit_rows(seq.id, now)?;
                if !done {
                    self.prefilling.insert(seq.id, seq);
                } else if seq.is_finished() {
                    self.free_seq(seq.id);
                    self.finished.push(seq);
                } else {
                    self.seal_prefix(&seq)?;
                    self.running.insert(seq.id, seq);
                }
                Ok(now - before)
            }
        }
    }

    /// One scheduling round: prefill work (one monolithic admission, or
    /// one budgeted chunk), then one decode step over all running.
    /// Returns the number of decode tokens generated this round.
    ///
    /// In debug builds (and release builds with the `audit` feature) every
    /// round ends with an [`crate::analysis::auditor`] pass that cross-checks
    /// the lane map, the row arenas, and the block accounting against each
    /// other, turning silent state divergence into an immediate error.
    pub fn step(&mut self) -> Result<usize> {
        let produced = self.step_inner()?;
        self.engine.sync_fault_metrics();
        // refresh the sharing gauges so per-round snapshots and final
        // reports both see the post-round pool state
        let sharing = self.kv.sharing_stats();
        self.engine.metrics.shared_blocks = sharing.shared_blocks as u64;
        self.engine.metrics.dedup_bytes = sharing.dedup_bytes;
        self.engine.metrics.block_pool_used = sharing.blocks_used as u64;
        self.engine.metrics.block_pool_total = sharing.blocks_total as u64;
        #[cfg(any(debug_assertions, feature = "audit"))]
        crate::analysis::auditor::audit_step(&mut self.engine, &self.kv)?;
        Ok(produced)
    }

    fn step_inner(&mut self) -> Result<usize> {
        self.progressed = false;
        match self.cfg.chunk_tokens {
            None => {
                self.admit(1)?;
            }
            Some(chunk) => {
                if !self.chunk_checked {
                    let sizes = self.engine.chunk_sizes();
                    if !sizes.contains(&chunk) {
                        bail!(
                            "chunk_tokens {chunk} not exported for {} \
                             (available: {sizes:?})",
                            self.engine.cfg.name
                        );
                    }
                    self.chunk_checked = true;
                }
                self.prefill_round(chunk)?;
            }
        }
        if self.running.is_empty() {
            return Ok(0);
        }
        let produced = self.decode_round()?;
        // eviction maintenance first (grow-and-trim against the capped
        // reservation), then mirror physical rows into the block
        // accounting, then retire finished
        let mut done: Vec<SeqId> = Vec::new();
        let ids: Vec<SeqId> = self.running.keys().copied().collect();
        if self.cfg.eviction.active() {
            for &id in &ids {
                self.evict_round(id)?;
            }
        }
        for s in self.running.values() {
            self.kv.commit_rows(s.id, self.engine.rows(s.id))?;
            if s.is_finished() {
                done.push(s.id);
            }
        }
        for id in done {
            let seq = self.running.remove(&id)
                .expect("retired id collected from the running map");
            self.free_seq(id);
            self.finished.push(seq);
        }
        Ok(produced)
    }

    /// Sleep out one backoff slot — clamped by the per-step cumulative
    /// cap — and account the retry. The histogram records the value
    /// actually slept, not the raw exponential, so latency reports stay
    /// truthful about where round time went.
    fn backoff(&mut self, attempt: usize, spent_us: &mut u64) {
        let us = backoff_slot_us(
            self.cfg.retry_backoff_us,
            attempt,
            *spent_us,
            self.cfg.max_step_backoff_us,
        );
        *spent_us = spent_us.saturating_add(us);
        if us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(us));
        }
        self.engine.metrics.step_retries += 1;
        self.engine.metrics.retry_backoff.record_us(us as f64);
    }

    /// Run an engine step under the bounded retry policy: retryable
    /// failures (Transient, or injected SequenceLocal) are re-attempted
    /// up to `max_step_retries` times with exponential backoff; the final
    /// error is returned typed so the caller can classify the terminal
    /// outcome. Engine steps roll their own state back on failure, so a
    /// retry always starts from the pre-step state.
    fn with_retries<T>(
        &mut self,
        mut op: impl FnMut(&mut Engine<'rt>) -> Result<T, EngineError>,
    ) -> Result<T, EngineError> {
        let mut attempt = 0usize;
        let mut spent_us = 0u64;
        loop {
            match op(&mut self.engine) {
                Ok(v) => {
                    if attempt > 0 {
                        self.engine.metrics.recovered_steps += 1;
                    }
                    return Ok(v);
                }
                Err(e)
                    if e.is_retryable()
                        && attempt < self.cfg.max_step_retries =>
                {
                    self.backoff(attempt, &mut spent_us);
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Terminal classification of a prefill failure after retries: an
    /// exhausted injected fault quarantines the request (`Failed`,
    /// counted); a genuine infeasibility (e.g. an over-long prompt) is
    /// reported as rejected (`PrefillFailed`), preserving the legacy
    /// accounting exactly when no fault plan is installed.
    fn prefill_failure_reason(&mut self, e: &EngineError) -> FinishReason {
        if e.injected_kind().is_some() {
            self.engine.metrics.quarantined_seqs += 1;
            FinishReason::Failed
        } else {
            FinishReason::PrefillFailed
        }
    }

    /// One decode step over all running lanes under the fault-recovery
    /// policy: retryable failures back off and retry (the engine rolled
    /// its state back, so the re-run is exact); a persistent
    /// sequence-local failure quarantines ONLY the implicated sequence
    /// (`FinishReason::Failed`) and the round continues with the
    /// survivors; an exhausted Transient or a Fatal failure escalates.
    /// Returns the decode tokens produced.
    fn decode_round(&mut self) -> Result<usize> {
        let mut attempt = 0usize;
        // the cumulative cap spans the whole round, surviving quarantine
        // (a fresh retry budget must not buy a fresh sleep budget)
        let mut spent_us = 0u64;
        loop {
            if self.running.is_empty() {
                return Ok(0);
            }
            let mut seqs: Vec<&mut Sequence> =
                self.running.values_mut().collect();
            let result = self.engine.decode_step(&mut seqs);
            let produced = seqs.len();
            drop(seqs);
            let e = match result {
                Ok(()) => {
                    if attempt > 0 {
                        self.engine.metrics.recovered_steps += 1;
                    }
                    return Ok(produced);
                }
                Err(e) => e,
            };
            if e.is_retryable() && attempt < self.cfg.max_step_retries {
                self.backoff(attempt, &mut spent_us);
                attempt += 1;
                continue;
            }
            match e.seq_id() {
                Some(id) if self.running.contains_key(&id) => {
                    // quarantine: evict the implicated sequence and keep
                    // the rest of the batch decoding; its blocks and
                    // arena rows free together as always
                    let mut seq = self.running.remove(&id)
                        .expect("quarantine id checked against running");
                    self.free_seq(id);
                    seq.finish(FinishReason::Failed);
                    self.finished.push(seq);
                    self.engine.metrics.quarantined_seqs += 1;
                    // fresh retry budget for the new batch composition
                    attempt = 0;
                }
                _ => {
                    self.engine.metrics.fatal_steps += 1;
                    return Err(e.into());
                }
            }
        }
    }

    /// Deadline-based load shedding over the WAITING queue (requests not
    /// yet holding any KV reservation): finish requests whose queueing
    /// delay exceeds their class deadline with [`FinishReason::Shed`] and
    /// return how many were shed. `None` disables a class's deadline. The
    /// router invokes this only while degraded (sustained faults or KV
    /// pressure), giving Batch the tighter deadline so document ingestion
    /// sheds first and Interactive chat stays alive.
    pub fn shed_overdue(
        &mut self,
        batch_deadline_s: Option<f64>,
        interactive_deadline_s: Option<f64>,
    ) -> usize {
        if batch_deadline_s.is_none() && interactive_deadline_s.is_none() {
            return 0;
        }
        let now = std::time::Instant::now();
        let mut shed = 0usize;
        let mut keep = VecDeque::with_capacity(self.waiting.len());
        while let Some(mut seq) = self.waiting.pop_front() {
            let deadline = match seq.priority {
                Priority::Batch => batch_deadline_s,
                Priority::Interactive => interactive_deadline_s,
            };
            // duration_since saturates to zero for backdated-future stamps
            let waited = now.duration_since(seq.arrived).as_secs_f64();
            match deadline {
                Some(d) if waited > d => {
                    seq.finish(FinishReason::Shed);
                    self.finished.push(seq);
                    shed += 1;
                }
                _ => keep.push_back(seq),
            }
        }
        self.waiting = keep;
        shed
    }

    /// Preempt the most recently admitted running sequence back to the
    /// waiting queue (used under cache pressure when extension-based
    /// accounting is enabled; with full reservation this is rare).
    pub fn preempt_one(&mut self) -> Option<SeqId> {
        let id = *self.running.keys().next_back()?;
        let mut seq = self.running.remove(&id)
            .expect("preempt id taken from the running keys");
        self.free_seq(id);
        // restart from scratch on re-admission; TTFT restarts too, so
        // latency histograms measure the admission that actually served
        seq.reset_for_restart();
        self.waiting.push_front(seq);
        Some(id)
    }

    /// Drain everything (closed-loop execution). An advancing chunked
    /// prefill counts as progress: a round that ingests a chunk but
    /// finishes nothing must never trip the stall flush (the fix for the
    /// eviction-during-prefill bug — see `flush_unservable`).
    pub fn run_to_completion(&mut self) -> Result<()> {
        let mut stall = 0usize;
        while self.has_work() {
            let before = self.finished.len();
            self.step()?;
            if self.finished.len() == before
                && self.n_running() == 0
                && !self.progressed
            {
                stall += 1;
                if stall > 2 {
                    self.flush_unservable(stall);
                }
            } else {
                stall = 0;
            }
        }
        Ok(())
    }

    /// Stall handling: reject only requests whose full reservation exceeds
    /// the *total* cache capacity — those can never be admitted, even into
    /// an empty cache. Requests that would fit once capacity frees stay
    /// queued and keep retrying; in particular, a request that does not
    /// fit *now* because an in-flight chunked prefill still holds its
    /// reservation is re-checked after that prefill completes and
    /// retires, not evicted. A deep stall (should be unreachable with
    /// exact accounting) rejects the head of line to guarantee progress —
    /// but never while a chunked prefill is in flight, since its
    /// completion will free budget at the next chunk boundary.
    pub(crate) fn flush_unservable(&mut self, stall: usize) {
        let cap = self.kv.total_token_capacity();
        let before = self.finished.len();
        let mut keep = VecDeque::with_capacity(self.waiting.len());
        while let Some(mut seq) = self.waiting.pop_front() {
            if self.reservation(&seq) > cap {
                seq.finish(FinishReason::CacheOverflow);
                self.finished.push(seq);
            } else {
                keep.push_back(seq);
            }
        }
        self.waiting = keep;
        if self.finished.len() == before
            && stall > 5
            && self.prefilling.is_empty()
        {
            if let Some(mut seq) = self.waiting.pop_front() {
                seq.finish(FinishReason::CacheOverflow);
                self.finished.push(seq);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite-1 regression: the pre-fix slot was the raw
    /// `base << attempt.min(16)` — 200µs doubles into a ~13.1s sleep
    /// inside one serving round. The fix clamps every slot at the
    /// per-step cap.
    #[test]
    fn backoff_slot_is_clamped_at_the_step_cap() {
        let cfg = SchedConfig::default();
        let raw = cfg.retry_backoff_us << 16usize;
        assert_eq!(raw, 13_107_200, "the pre-fix slot really slept ~13s");
        let slot = backoff_slot_us(
            cfg.retry_backoff_us, 16, 0, cfg.max_step_backoff_us);
        assert_eq!(slot, cfg.max_step_backoff_us);
        assert!(slot < raw);
    }

    /// A max-retry burst — arbitrarily many attempts, ever-growing
    /// exponents — can never stall a round longer than the cumulative
    /// cap: once the budget is spent, further slots are zero.
    #[test]
    fn max_retry_burst_cannot_stall_a_round_past_the_cap() {
        let cap = SchedConfig::default().max_step_backoff_us;
        let mut spent = 0u64;
        for attempt in 0..64 {
            let slot = backoff_slot_us(200, attempt, spent, cap);
            spent = spent.saturating_add(slot);
            assert!(
                spent <= cap,
                "attempt {attempt} pushed the round stall past the cap"
            );
        }
        assert_eq!(spent, cap, "budget spends fully, then slots go to zero");
        assert_eq!(backoff_slot_us(200, 5, spent, cap), 0);
    }

    /// Small attempts below the cap still sleep the raw exponential —
    /// the fix must not flatten ordinary transient-fault pacing.
    #[test]
    fn uncapped_attempts_keep_the_exponential_schedule() {
        let cap = SchedConfig::default().max_step_backoff_us;
        assert_eq!(backoff_slot_us(200, 0, 0, cap), 200);
        assert_eq!(backoff_slot_us(200, 1, 0, cap), 400);
        assert_eq!(backoff_slot_us(200, 2, 0, cap), 800);
        assert_eq!(backoff_slot_us(200, 3, 0, cap), 1_600);
    }

    /// The shift saturates instead of wrapping — a pathological base
    /// still clamps to the cap rather than overflowing to a tiny slot.
    #[test]
    fn shift_overflow_saturates_then_clamps() {
        assert_eq!(backoff_slot_us(u64::MAX, 16, 0, 5_000), 5_000);
        assert_eq!(backoff_slot_us(u64::MAX / 2, 2, 0, 5_000), 5_000);
    }
}
