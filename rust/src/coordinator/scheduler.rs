//! Continuous-batching scheduler: admission against the KV budget, one
//! prefill per scheduling round interleaved with decode steps, preemption
//! on cache pressure.
//!
//! Admission reserves the *full* context (prompt + max_new) per sequence —
//! the same per-user reservation the paper's Table 10 capacity math uses,
//! which is exactly where thin keys admit more concurrent users.
//!
//! The scheduler is also the keeper of the unified accounting contract:
//! after every prefill/decode it mirrors the engine's physically written
//! rows into `KvCacheManager::commit_rows`, and a sequence's logical
//! blocks and physical arena rows are always freed together on the same
//! event ([`Scheduler::free_seq`]).

use std::collections::{BTreeMap, VecDeque};

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::coordinator::kvcache::{KvCacheManager, SeqId};
use crate::coordinator::sequence::{FinishReason, Sequence};

pub struct Scheduler<'rt> {
    pub engine: Engine<'rt>,
    pub kv: KvCacheManager,
    pub max_batch: usize,
    next_id: SeqId,
    waiting: VecDeque<Sequence>,
    running: BTreeMap<SeqId, Sequence>,
    pub finished: Vec<Sequence>,
}

impl<'rt> Scheduler<'rt> {
    pub fn new(engine: Engine<'rt>, kv: KvCacheManager, max_batch: usize)
        -> Scheduler<'rt> {
        Scheduler {
            engine,
            kv,
            max_batch,
            next_id: 1,
            waiting: VecDeque::new(),
            running: BTreeMap::new(),
            finished: Vec::new(),
        }
    }

    /// Enqueue a request. Returns its sequence id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize, eos: Option<i32>)
        -> SeqId {
        let id = self.next_id;
        self.next_id += 1;
        self.waiting.push_back(Sequence::new(id, prompt, max_new, eos));
        id
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    fn reservation(seq: &Sequence) -> usize {
        seq.prompt.len() + seq.max_new
    }

    /// Free a sequence's logical KV blocks and physical cache rows on the
    /// same event — the two accountings never disagree about liveness.
    fn free_seq(&mut self, id: SeqId) {
        self.kv.release(id);
        self.engine.drop_seq(id);
    }

    /// Admit from the waiting queue while budget and batch slots allow.
    /// At most `max_prefills` prefills per round (prefill is expensive and
    /// would starve decode otherwise).
    fn admit(&mut self, max_prefills: usize) -> Result<usize> {
        let mut admitted = 0;
        while admitted < max_prefills
            && self.running.len() < self.max_batch
            && !self.waiting.is_empty()
        {
            let need = Self::reservation(self.waiting.front().unwrap());
            if !self.kv.can_admit(need) {
                break; // head-of-line blocking by design (FIFO fairness)
            }
            let mut seq = self.waiting.pop_front().unwrap();
            self.kv.allocate(seq.id, need)?;
            if self.engine.prefill(&mut seq).is_err() {
                // roll the reservation back and fail the request visibly
                // instead of leaking the blocks and dropping the sequence
                self.free_seq(seq.id);
                seq.finish(FinishReason::PrefillFailed);
                self.finished.push(seq);
                admitted += 1;
                continue;
            }
            self.kv.commit_rows(seq.id, self.engine.rows(seq.id))?;
            if seq.is_finished() {
                self.free_seq(seq.id);
                self.finished.push(seq);
            } else {
                self.running.insert(seq.id, seq);
            }
            admitted += 1;
        }
        Ok(admitted)
    }

    /// One scheduling round: admit then one decode step over all running.
    /// Returns the number of tokens generated this round.
    pub fn step(&mut self) -> Result<usize> {
        self.admit(1)?;
        if self.running.is_empty() {
            return Ok(0);
        }
        let mut seqs: Vec<&mut Sequence> = self.running.values_mut().collect();
        self.engine.decode_step(&mut seqs)?;
        let produced = seqs.len();
        drop(seqs);
        // mirror physical rows into the block accounting, retire finished
        let mut done: Vec<SeqId> = Vec::new();
        for s in self.running.values() {
            self.kv.commit_rows(s.id, self.engine.rows(s.id))?;
            if s.is_finished() {
                done.push(s.id);
            }
        }
        for id in done {
            let seq = self.running.remove(&id).unwrap();
            self.free_seq(id);
            self.finished.push(seq);
        }
        Ok(produced)
    }

    /// Preempt the most recently admitted running sequence back to the
    /// waiting queue (used under cache pressure when extension-based
    /// accounting is enabled; with full reservation this is rare).
    pub fn preempt_one(&mut self) -> Option<SeqId> {
        let id = *self.running.keys().next_back()?;
        let mut seq = self.running.remove(&id).unwrap();
        self.free_seq(id);
        // restart from scratch on re-admission; TTFT restarts too, so
        // latency histograms measure the admission that actually served
        seq.reset_for_restart();
        self.waiting.push_front(seq);
        Some(id)
    }

    /// Drain everything (closed-loop execution).
    pub fn run_to_completion(&mut self) -> Result<()> {
        let mut stall = 0usize;
        while self.has_work() {
            let before = self.finished.len();
            self.step()?;
            if self.finished.len() == before && self.n_running() == 0 {
                stall += 1;
                if stall > 2 {
                    self.flush_unservable(stall);
                }
            } else {
                stall = 0;
            }
        }
        Ok(())
    }

    /// Stall handling: reject only requests whose full reservation exceeds
    /// the *total* cache capacity — those can never be admitted, even into
    /// an empty cache. Requests that would fit once capacity frees stay
    /// queued and keep retrying. A deep stall (should be unreachable with
    /// exact accounting) rejects the head of line to guarantee progress.
    fn flush_unservable(&mut self, stall: usize) {
        let cap = self.kv.total_token_capacity();
        let before = self.finished.len();
        let mut keep = VecDeque::with_capacity(self.waiting.len());
        while let Some(mut seq) = self.waiting.pop_front() {
            if Self::reservation(&seq) > cap {
                seq.finish(FinishReason::CacheOverflow);
                self.finished.push(seq);
            } else {
                keep.push_back(seq);
            }
        }
        self.waiting = keep;
        if self.finished.len() == before && stall > 5 {
            if let Some(mut seq) = self.waiting.pop_front() {
                seq.finish(FinishReason::CacheOverflow);
                self.finished.push(seq);
            }
        }
    }
}
