//! Lane-stable group membership for the decode engine.
//!
//! The decode arena packs sequences into `bucket` lanes. Membership churn
//! (retirements, admissions, preemptions) used to trigger a full
//! park/unpark cycle — every member copied host-side twice per change —
//! and, worse, the engine fed tokens to lanes by *enumeration order*, so a
//! retirement in a low lane silently shifted every survivor's input into
//! the wrong lane. [`LaneMap`] is the fix: an explicit `SeqId → lane`
//! assignment that is the single source of truth for where a sequence's
//! cache rows live, plus an incremental [`RegroupPlan`] that keeps stable
//! sequences in place (zero copies), writes only joining lanes, and moves
//! lanes only when the bucket itself is resized.
//!
//! Everything here is pure bookkeeping (no tensors, no runtime), so the
//! lane-misalignment regression and the copy-cost accounting are unit
//! tested without compiled artifacts.

use std::collections::HashMap;

use crate::coordinator::sequence::SeqId;

/// Explicit sequence→lane assignment. Invariants: `of[id] == lane` iff
/// `lanes[lane] == Some(id)`; a sequence's lane never changes except when
/// the bucket is resized.
#[derive(Clone, Debug, Default)]
pub struct LaneMap {
    lanes: Vec<Option<SeqId>>,
    of: HashMap<SeqId, usize>,
}

/// Incremental membership change: which sequences stay (and where), which
/// join into holes, which leave, and whether the arena must be resized.
#[derive(Clone, Debug)]
pub struct RegroupPlan {
    /// Target bucket (lane count) after the change.
    pub bucket: usize,
    /// True when the arena must be reallocated (bucket changed); every
    /// kept lane is then copied into the new layout.
    pub resize: bool,
    /// `(id, old_lane, new_lane)` — sequences that survive the change.
    /// Without a resize `old_lane == new_lane` and no bytes move.
    pub keep: Vec<(SeqId, usize, usize)>,
    /// `(id, lane)` — sequences unparked into a (possibly freed) lane.
    pub join: Vec<(SeqId, usize)>,
    /// `(id, old_lane)` — live sequences leaving the group (must be
    /// parked before their lane is reused).
    pub leave: Vec<(SeqId, usize)>,
}

/// Host bytes moved by a plan, next to what the old full park/unpark
/// design would have moved for the same membership change.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CopyCost {
    /// Bytes the incremental repack actually copies.
    pub actual: u64,
    /// Bytes the full park-everything/unpark-everything baseline copies:
    /// every previous member out, every new member back in.
    pub full_equiv: u64,
}

impl LaneMap {
    pub fn new() -> LaneMap {
        LaneMap::default()
    }

    /// Current lane count (0 before the first regroup).
    pub fn bucket(&self) -> usize {
        self.lanes.len()
    }

    /// Number of occupied lanes.
    pub fn live(&self) -> usize {
        self.of.len()
    }

    pub fn lane_of(&self, id: SeqId) -> Option<usize> {
        self.of.get(&id).copied()
    }

    /// Occupied sequence ids, in lane order.
    pub fn ids(&self) -> impl Iterator<Item = SeqId> + '_ {
        self.lanes.iter().flatten().copied()
    }

    /// Vacate a sequence's lane (zero-copy retirement: the hole persists
    /// until a join or resize reuses it). Returns true if it was present.
    pub fn remove(&mut self, id: SeqId) -> bool {
        match self.of.remove(&id) {
            Some(lane) => {
                self.lanes[lane] = None;
                true
            }
            None => false,
        }
    }

    /// Compute the incremental change from the current assignment to
    /// `active` (in order) at `bucket` lanes. `active` must fit `bucket`.
    pub fn plan(&self, active: &[SeqId], bucket: usize) -> RegroupPlan {
        assert!(active.len() <= bucket, "active {} > bucket {bucket}", active.len());
        let resize = bucket != self.lanes.len();
        let leave: Vec<(SeqId, usize)> = self
            .lanes
            .iter()
            .enumerate()
            .filter_map(|(lane, s)| s.map(|id| (id, lane)))
            .filter(|(id, _)| !active.contains(id))
            .collect();
        let stays: Vec<(SeqId, usize)> = self
            .lanes
            .iter()
            .enumerate()
            .filter_map(|(lane, s)| s.map(|id| (id, lane)))
            .filter(|(id, _)| active.contains(id))
            .collect();
        let mut used = vec![false; bucket];
        let mut keep = Vec::with_capacity(stays.len());
        // In lane order: keep the old index whenever it exists in the new
        // bucket (always true on grow), else compact into the lowest free
        // lane (shrink only).
        for (id, old) in stays {
            let new = if old < bucket && !used[old] {
                old
            } else {
                (0..bucket).find(|&l| !used[l]).expect("bucket too small")
            };
            used[new] = true;
            keep.push((id, old, new));
        }
        let mut join = Vec::new();
        for &id in active {
            if self.of.contains_key(&id) {
                continue;
            }
            let lane = (0..bucket).find(|&l| !used[l]).expect("bucket too small");
            used[lane] = true;
            join.push((id, lane));
        }
        RegroupPlan { bucket, resize, keep, join, leave }
    }

    /// Bijection audit, consumed by the engine auditor: `of[id] == lane`
    /// iff `lanes[lane] == Some(id)`, and the occupied-lane count equals
    /// the reverse map's size. A violation here is exactly the PR 1
    /// lane-misalignment bug class.
    pub fn check(&self) -> Result<(), String> {
        for (lane, slot) in self.lanes.iter().enumerate() {
            if let Some(id) = slot {
                if self.of.get(id) != Some(&lane) {
                    return Err(format!(
                        "lane {lane} holds seq {id} but of[{id}] = {:?}",
                        self.of.get(id)));
                }
            }
        }
        let occupied = self.lanes.iter().filter(|s| s.is_some()).count();
        if occupied != self.of.len() {
            return Err(format!(
                "{occupied} occupied lanes vs {} mapped sequences",
                self.of.len()));
        }
        for (&id, &lane) in &self.of {
            if lane >= self.lanes.len() {
                return Err(format!(
                    "of[{id}] = {lane} outside bucket {}",
                    self.lanes.len()));
            }
        }
        Ok(())
    }

    /// Rebuild the assignment from an applied plan.
    pub fn apply(&mut self, plan: &RegroupPlan) {
        self.lanes = vec![None; plan.bucket];
        self.of.clear();
        for &(id, _, lane) in &plan.keep {
            self.lanes[lane] = Some(id);
            self.of.insert(id, lane);
        }
        for &(id, lane) in &plan.join {
            self.lanes[lane] = Some(id);
            self.of.insert(id, lane);
        }
    }
}

/// Bucket selection with shrink hysteresis: grow to the smallest exported
/// bucket that fits, but only shrink once the group fits in *half* the
/// current bucket (avoids repack thrash around a bucket boundary).
/// Returns `None` when `n` exceeds the largest bucket.
pub fn target_bucket(buckets: &[usize], n: usize, current: usize) -> Option<usize> {
    let minimal = buckets.iter().copied().find(|&b| b >= n)?;
    if current == 0 || minimal > current {
        Some(minimal)
    } else if minimal * 2 <= current {
        Some(minimal)
    } else {
        Some(current)
    }
}

/// Context-tier selection with asymmetric hysteresis, the arena-length
/// twin of [`target_bucket`]. `need` is the rows the longest live sequence
/// requires; `current` is the arena's current tier (0 before the first
/// group).
///
/// Grow: to the smallest exported tier that fits (tiers are geometric, so
/// a growing sequence re-crosses a boundary only after doubling). Shrink:
/// only down to a tier that still leaves ~2x headroom over `need`, and
/// only when that tier is at most *half* the current one — so a longest
/// sequence oscillating at a tier boundary (grow past it, retire back
/// under it) never thrashes the arena.
///
/// Returns `None` when `need` exceeds the largest exported tier.
pub fn target_tier(tiers: &[usize], need: usize, current: usize) -> Option<usize> {
    let fit = tiers.iter().copied().find(|&t| t >= need)?;
    if current == 0 || fit > current {
        return Some(fit);
    }
    // candidate shrink target keeps one tier (~2x) of headroom above need.
    // No tier has 2x headroom -> stay put (`current` >= `fit` here, and
    // the old last-tier fallback could never pass the halving gate below
    // either, so this is the same fixpoint without the unwrap).
    let roomy = tiers
        .iter()
        .copied()
        .find(|&t| t >= 2 * need)
        .unwrap_or(current);
    if roomy * 2 <= current {
        Some(roomy)
    } else {
        Some(current)
    }
}

/// Host bytes a plan copies (and what the full park/unpark baseline would
/// have copied). `rows(id)` = cache rows currently written for `id`;
/// `row_bytes` = bytes per row across all layers (K + V).
pub fn copy_cost(
    plan: &RegroupPlan,
    rows: impl Fn(SeqId) -> usize,
    row_bytes: usize,
) -> CopyCost {
    let sum = |ids: &mut dyn Iterator<Item = SeqId>| -> u64 {
        ids.map(|id| rows(id) as u64).sum()
    };
    let kept = sum(&mut plan.keep.iter().map(|&(id, _, _)| id));
    let joined = sum(&mut plan.join.iter().map(|&(id, _)| id));
    let left = sum(&mut plan.leave.iter().map(|&(id, _)| id));
    let moved = if plan.resize {
        kept
    } else {
        // without a resize, kept lanes stay physically in place
        0
    };
    CopyCost {
        actual: (moved + joined + left) * row_bytes as u64,
        full_equiv: ((kept + left) + (kept + joined)) * row_bytes as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grouped(active: &[SeqId], bucket: usize) -> LaneMap {
        let mut lm = LaneMap::new();
        let plan = lm.plan(active, bucket);
        lm.apply(&plan);
        lm
    }

    #[test]
    fn initial_grouping_assigns_lanes_in_order() {
        let lm = grouped(&[7, 3, 9], 4);
        assert_eq!(lm.bucket(), 4);
        assert_eq!(lm.live(), 3);
        assert_eq!(lm.lane_of(7), Some(0));
        assert_eq!(lm.lane_of(3), Some(1));
        assert_eq!(lm.lane_of(9), Some(2));
        assert_eq!(lm.ids().collect::<Vec<_>>(), vec![7, 3, 9]);
    }

    /// The lane-misalignment regression: retiring the sequence in lane 0
    /// must NOT shift the survivor down. The old engine fed tokens by
    /// `seqs.iter().enumerate()`, which after a lane-0 retirement put the
    /// survivor's token into lane 0 while its cache rows lived in lane 1.
    #[test]
    fn retiring_lane_zero_keeps_survivor_lane() {
        let mut lm = grouped(&[1, 2], 2);
        assert!(lm.remove(1));
        // survivor must still decode out of lane 1, not enumeration
        // index 0
        assert_eq!(lm.lane_of(2), Some(1));
        assert_eq!(lm.live(), 1);
        // a later join reuses the hole without touching the survivor
        let plan = lm.plan(&[2, 3], 2);
        assert!(!plan.resize);
        assert_eq!(plan.keep, vec![(2, 1, 1)]);
        assert_eq!(plan.join, vec![(3, 0)]);
        assert!(plan.leave.is_empty());
        lm.apply(&plan);
        assert_eq!(lm.lane_of(2), Some(1));
        assert_eq!(lm.lane_of(3), Some(0));
    }

    #[test]
    fn single_leave_in_large_bucket_is_zero_copy() {
        // B=8, one retirement: the incremental plan copies nothing; the
        // full park/unpark baseline copies every survivor out and back in.
        let ids: Vec<SeqId> = (1..=8).collect();
        let mut lm = grouped(&ids, 8);
        assert!(lm.remove(3));
        let active: Vec<SeqId> = ids.iter().copied().filter(|&i| i != 3).collect();
        let plan = lm.plan(&active, 8);
        assert!(!plan.resize);
        assert!(plan.join.is_empty() && plan.leave.is_empty());
        let cost = copy_cost(&plan, |_| 100, 64);
        assert_eq!(cost.actual, 0);
        // 7 survivors parked + 7 unparked
        assert_eq!(cost.full_equiv, 14 * 100 * 64);
        assert!(cost.full_equiv >= 4 * cost.actual.max(1));
    }

    #[test]
    fn live_leave_is_parked_and_costed() {
        let lm = grouped(&[1, 2], 2);
        // seq 1 still live but excluded from the active set: it must be
        // parked (one lane copied), survivor compacted on the shrink
        let plan = lm.plan(&[2], 1);
        assert!(plan.resize);
        assert_eq!(plan.leave, vec![(1, 0)]);
        assert_eq!(plan.keep, vec![(2, 1, 0)]);
        let cost = copy_cost(&plan, |_| 10, 8);
        // park leaver + move survivor
        assert_eq!(cost.actual, 2 * 10 * 8);
        // baseline: park both, unpark survivor
        assert_eq!(cost.full_equiv, 3 * 10 * 8);
    }

    #[test]
    fn grow_preserves_lane_indices() {
        let mut lm = grouped(&[1, 2], 2);
        let plan = lm.plan(&[1, 2, 3], 4);
        assert!(plan.resize);
        assert_eq!(plan.keep, vec![(1, 0, 0), (2, 1, 1)]);
        assert_eq!(plan.join, vec![(3, 2)]);
        lm.apply(&plan);
        assert_eq!(lm.lane_of(1), Some(0));
        assert_eq!(lm.lane_of(2), Some(1));
    }

    #[test]
    fn shrink_compacts_displaced_lanes_only() {
        let mut lm = grouped(&(1..=8).collect::<Vec<_>>(), 8);
        for id in [1, 2, 3, 4, 6, 8] {
            assert!(lm.remove(id));
        }
        // survivors in lanes 4 and 6 → compact into bucket 2
        let plan = lm.plan(&[5, 7], 2);
        assert!(plan.resize);
        assert_eq!(plan.keep, vec![(5, 4, 0), (7, 6, 1)]);
    }

    #[test]
    fn bucket_hysteresis() {
        let buckets = [1usize, 2, 4, 8, 16, 32];
        // first group and growth take the minimal bucket
        assert_eq!(target_bucket(&buckets, 3, 0), Some(4));
        assert_eq!(target_bucket(&buckets, 9, 8), Some(16));
        // one leave inside a bucket does not shrink
        assert_eq!(target_bucket(&buckets, 7, 8), Some(8));
        assert_eq!(target_bucket(&buckets, 5, 8), Some(8));
        // shrink only once the group fits half the bucket
        assert_eq!(target_bucket(&buckets, 4, 8), Some(4));
        assert_eq!(target_bucket(&buckets, 1, 2), Some(1));
        // over the largest exported bucket
        assert_eq!(target_bucket(&buckets, 33, 32), None);
    }

    #[test]
    fn tier_grows_to_minimal_fit() {
        let tiers = [32usize, 64, 128, 256];
        assert_eq!(target_tier(&tiers, 1, 0), Some(32));
        assert_eq!(target_tier(&tiers, 33, 0), Some(64));
        assert_eq!(target_tier(&tiers, 33, 32), Some(64));
        assert_eq!(target_tier(&tiers, 129, 64), Some(256));
        assert_eq!(target_tier(&tiers, 256, 128), Some(256));
        // beyond the largest exported tier
        assert_eq!(target_tier(&tiers, 257, 256), None);
    }

    #[test]
    fn tier_shrinks_only_with_headroom() {
        let tiers = [32usize, 64, 128, 256];
        // need 20 at tier 256: roomy = 64 (>= 2*20), 64*2 <= 256 -> shrink
        assert_eq!(target_tier(&tiers, 20, 256), Some(64));
        // need 40 at tier 128: roomy = 128, no shrink possible
        assert_eq!(target_tier(&tiers, 40, 128), Some(128));
        // need 16 at tier 64: roomy = 32, 32*2 <= 64 -> shrink to 32
        assert_eq!(target_tier(&tiers, 16, 64), Some(32));
        // need 17 at tier 64: roomy = 64 -> stay
        assert_eq!(target_tier(&tiers, 17, 64), Some(64));
    }

    /// THE tier-thrash regression: a longest sequence oscillating at a
    /// tier boundary (grow past 64, retire back just under it, repeat)
    /// must not bounce the arena between 64 and 128 every few steps.
    #[test]
    fn tier_boundary_oscillation_does_not_thrash() {
        let tiers = [32usize, 64, 128, 256];
        // longest sequence crosses 64 -> grow
        let t1 = target_tier(&tiers, 65, 64).unwrap();
        assert_eq!(t1, 128);
        // it retires; the next-longest is just under the boundary. The
        // naive rule (shrink when fit*2 <= current) would shrink to 64
        // here and re-grow next time a sequence crosses — thrash.
        for need in [64, 63, 60, 40] {
            assert_eq!(target_tier(&tiers, need, t1), Some(128),
                       "need {need} must not shrink 128 -> 64");
        }
        // only once live lengths drop far enough that 64 is itself roomy
        // (2x headroom) does the arena come back down...
        assert_eq!(target_tier(&tiers, 32, t1), Some(64));
        // ...and after shrinking to 64 with need <= 32, re-growing
        // requires a sequence to double past 64 again: no oscillation.
        assert_eq!(target_tier(&tiers, 33, 64), Some(64));
        assert_eq!(target_tier(&tiers, 64, 64), Some(64));
    }

    #[test]
    fn remove_unknown_is_noop() {
        let mut lm = grouped(&[1], 1);
        assert!(!lm.remove(99));
        assert_eq!(lm.live(), 1);
    }
}
