//! Router: the serving front end. Feeds arrival traces into the scheduler
//! (open-loop with real wall-clock pacing, or closed-loop for steady-state
//! throughput) and aggregates per-request metrics.
//!
//! Degradation policy ([`RouterPolicy`]): under sustained faults or KV
//! pressure the router enforces per-class queueing deadlines over the
//! waiting queue, shedding Batch work first (tighter deadline) so
//! Interactive chat stays alive. Shedding only touches requests that hold
//! no KV reservation yet — admitted work is never dropped by the router.
//!
//! Report classification is a pure function ([`classify_finished`]):
//! every [`FinishReason`] maps to exactly one [`ReportBucket`], so
//! quarantined (`Failed`) and load-shed (`Shed`) requests are counted in
//! their own buckets instead of silently inflating completions.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::metrics::ServeReport;
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::supervisor::{RestartBudgetExhausted, Supervisor};
use crate::coordinator::sequence::{
    FinishReason, Priority, SeqState, Sequence,
};
use crate::datagen::arrival::RequestSpec;
use crate::substrate::rng::Rng;

/// Generates prompt token ids for a request spec (synthetic content).
pub fn synth_prompt(len: usize, vocab: usize, rng: &mut Rng) -> Vec<i32> {
    (0..len.max(1))
        .map(|_| rng.range(crate::tokenizer::N_SPECIALS, vocab) as i32)
        .collect()
}

/// Per-class queueing deadlines + when to enforce them. Default: no
/// deadlines (shedding disabled) — traces run exactly as before.
#[derive(Clone, Copy, Debug)]
pub struct RouterPolicy {
    /// Shed a waiting Batch request once it has queued this long.
    pub batch_deadline_s: Option<f64>,
    /// Shed a waiting Interactive request once it has queued this long.
    /// Sized looser than (or left `None` next to) the Batch deadline:
    /// degradation sheds document ingestion first, chat last.
    pub interactive_deadline_s: Option<f64>,
    /// Enforce deadlines only while degraded (faults observed since the
    /// last check, or KV free capacity below a quarter of total). When
    /// false, deadlines apply unconditionally.
    pub only_when_degraded: bool,
}

impl Default for RouterPolicy {
    fn default() -> RouterPolicy {
        RouterPolicy {
            batch_deadline_s: None,
            interactive_deadline_s: None,
            only_when_degraded: true,
        }
    }
}

impl RouterPolicy {
    fn active(&self) -> bool {
        self.batch_deadline_s.is_some()
            || self.interactive_deadline_s.is_some()
    }
}

/// Which report bucket a finished request lands in. Exactly one bucket
/// per [`FinishReason`] — see [`classify_finished`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReportBucket {
    /// Served to completion (EOS or max_tokens): counts toward
    /// throughput and the latency histograms.
    Completed,
    /// Never served (cache overflow, rejected prefill): no tokens, no
    /// latency samples.
    Rejected,
    /// Quarantined mid-service after a persistent sequence-local fault:
    /// partial work is discarded, not reported as throughput.
    Failed,
    /// Load-shed from the waiting queue by the degradation policy.
    Shed,
}

/// Pure classification of a finish reason into its report bucket. Pinned
/// by a unit test below so a future `FinishReason` variant cannot
/// silently inflate completions (the compiler forces a bucket choice).
pub fn classify_finished(reason: FinishReason) -> ReportBucket {
    match reason {
        FinishReason::Eos | FinishReason::MaxTokens => ReportBucket::Completed,
        FinishReason::CacheOverflow | FinishReason::PrefillFailed => {
            ReportBucket::Rejected
        }
        FinishReason::Failed => ReportBucket::Failed,
        FinishReason::Shed => ReportBucket::Shed,
    }
}

/// Bucket for a sequence in the finished list. A non-finished state here
/// (e.g. a sequence preempted back to Queued after its quarantine was
/// decided — a scheduler bug) is counted as Rejected rather than
/// panicking the report or inflating completions.
pub fn bucket_of(seq: &Sequence) -> ReportBucket {
    match seq.state {
        SeqState::Finished(reason) => classify_finished(reason),
        SeqState::Queued | SeqState::Decoding => ReportBucket::Rejected,
    }
}

pub struct Router<'rt> {
    pub sched: Scheduler<'rt>,
    pub policy: RouterPolicy,
    /// Crash-recovery supervision (checkpoint cadence + warm restart on
    /// Fatal/wedge). `None` reproduces the unsupervised loop exactly: a
    /// Fatal propagates out of the run.
    pub supervisor: Option<Supervisor<'rt>>,
    /// Fault count at the last degradation check (detects "faults are
    /// still being injected" as a degradation signal).
    last_faults: u64,
    /// Whether the last degradation check said degraded — transition
    /// edges count into `degraded_enters`/`degraded_exits`.
    degraded_now: bool,
    /// Router loop iterations observed degraded (satellite 2: shedding
    /// decisions explainable from the report, not inferred).
    pub degraded_rounds: u64,
    pub degraded_enters: u64,
    pub degraded_exits: u64,
}

impl<'rt> Router<'rt> {
    pub fn new(sched: Scheduler<'rt>) -> Router<'rt> {
        Router {
            sched,
            policy: RouterPolicy::default(),
            supervisor: None,
            last_faults: 0,
            degraded_now: false,
            degraded_rounds: 0,
            degraded_enters: 0,
            degraded_exits: 0,
        }
    }

    /// Builder: attach a degradation/shedding policy.
    pub fn with_policy(mut self, policy: RouterPolicy) -> Router<'rt> {
        self.policy = policy;
        self
    }

    /// Builder: attach a crash-recovery supervisor. Every scheduler
    /// round then runs through [`Supervisor::step`] (checkpoint cadence,
    /// warm restart on Fatal/wedge), and restart-budget exhaustion
    /// triggers the router's drain/shed path instead of ending the run.
    pub fn with_supervisor(mut self, supervisor: Supervisor<'rt>)
        -> Router<'rt> {
        self.supervisor = Some(supervisor);
        self
    }

    /// Degradation signal: faults injected since the last check, or KV
    /// free capacity below a quarter of total (sustained pressure).
    fn degraded(&mut self) -> bool {
        let faults = self.sched.engine.metrics.faults_injected;
        let faulting = faults > self.last_faults;
        self.last_faults = faults;
        let free = self.sched.kv.free_token_capacity();
        let pressure = free < self.sched.kv.total_token_capacity() / 4;
        faulting || pressure
    }

    /// One degradation check per router loop iteration, with the
    /// enter/exit transitions counted — the observable that used to be
    /// inferred from shed counts. Returns the current signal for the
    /// shed pass, so one iteration never double-samples the fault delta.
    fn observe_degraded(&mut self) -> bool {
        let deg = self.degraded();
        if deg {
            self.degraded_rounds += 1;
            if !self.degraded_now {
                self.degraded_enters += 1;
            }
        } else if self.degraded_now {
            self.degraded_exits += 1;
        }
        self.degraded_now = deg;
        deg
    }

    /// Apply the shedding policy to the waiting queue (open-loop traces,
    /// between scheduler rounds). Shed sequences land in
    /// `sched.finished` with [`FinishReason::Shed`] and are bucketed by
    /// `collect` — no separate accounting path.
    fn shed_pass(&mut self, degraded: bool) {
        if !self.policy.active() {
            return;
        }
        if self.policy.only_when_degraded && !degraded {
            return;
        }
        self.sched.shed_overdue(
            self.policy.batch_deadline_s,
            self.policy.interactive_deadline_s,
        );
    }

    /// One serving round, supervised when a supervisor is attached. A
    /// spent restart budget does not crash the loop: the typed
    /// [`RestartBudgetExhausted`] triggers the drain/shed path (every
    /// reservation-holding sequence fails visibly, the waiting queue
    /// sheds) and the run completes with the outcome in the report.
    fn step_round(&mut self) -> Result<usize> {
        let result = match self.supervisor.as_mut() {
            Some(sup) => sup.step(&mut self.sched),
            None => self.sched.step(),
        };
        match result {
            Ok(n) => Ok(n),
            Err(e) if e.downcast_ref::<RestartBudgetExhausted>().is_some() =>
            {
                self.sched.drain_for_escalation();
                Ok(0)
            }
            Err(e) => Err(e),
        }
    }

    /// Run a trace to completion. Requests are injected when their arrival
    /// time elapses (relative to the run start); in between, the scheduler
    /// keeps stepping. Each sequence's arrival stamp is backdated to the
    /// TRACE arrival time, so TTFT charges queueing delay incurred while
    /// the scheduler was mid-round (e.g. blocked on a monolithic prefill)
    /// — the stall that chunked prefill exists to remove. Returns the
    /// aggregate report.
    pub fn run_trace(&mut self, trace: &[RequestSpec], seed: u64)
        -> Result<ServeReport> {
        let vocab = self.sched.engine.cfg.vocab;
        let mut rng = Rng::new(seed);
        let prompts: Vec<Vec<i32>> = trace
            .iter()
            .map(|r| synth_prompt(r.prompt_len, vocab, &mut rng))
            .collect();
        let t0 = Instant::now();
        let mut next = 0usize;
        let mut report = ServeReport::default();
        while next < trace.len() || self.sched.has_work() {
            let now = t0.elapsed().as_secs_f64();
            while next < trace.len() && trace[next].arrive_s <= now {
                let arrived =
                    t0 + std::time::Duration::from_secs_f64(
                        trace[next].arrive_s);
                self.sched.submit_seq(
                    prompts[next].clone(),
                    trace[next].gen_len,
                    None,
                    trace[next].priority,
                    Some(arrived),
                );
                next += 1;
            }
            let degraded = self.observe_degraded();
            self.shed_pass(degraded);
            if self.sched.has_work() {
                self.step_round()?;
            } else if next < trace.len() {
                // idle until the next arrival
                let wait = trace[next].arrive_s - t0.elapsed().as_secs_f64();
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        wait.min(0.01),
                    ));
                }
            }
        }
        report.total_s = t0.elapsed().as_secs_f64();
        self.collect(&mut report);
        Ok(report)
    }

    /// Closed-loop: all requests at t=0 (steady-state throughput).
    /// Deadlines are wall-clock queueing policy for open-loop traces;
    /// closed-loop runs never shed.
    pub fn run_closed_loop(&mut self, trace: &[RequestSpec], seed: u64)
        -> Result<ServeReport> {
        let vocab = self.sched.engine.cfg.vocab;
        let mut rng = Rng::new(seed);
        let t0 = Instant::now();
        let mut report = ServeReport::default();
        for r in trace {
            let prompt = synth_prompt(r.prompt_len, vocab, &mut rng);
            self.sched.submit_seq(prompt, r.gen_len, None, r.priority, None);
        }
        // router-level drain loop mirroring `run_to_completion`'s stall
        // handling, so each round runs through the supervisor when one
        // is attached (closed-loop never sheds, but degradation is still
        // observed for the report)
        let mut stall = 0usize;
        while self.sched.has_work() {
            let before = self.sched.finished.len();
            self.observe_degraded();
            self.step_round()?;
            if self.sched.finished.len() == before
                && self.sched.n_running() == 0
                && !self.sched.made_progress()
            {
                stall += 1;
                if stall > 2 {
                    self.sched.flush_unservable(stall);
                }
            } else {
                stall = 0;
            }
        }
        report.total_s = t0.elapsed().as_secs_f64();
        self.collect(&mut report);
        Ok(report)
    }

    fn collect(&self, report: &mut ServeReport) {
        report.degraded_rounds = self.degraded_rounds;
        report.degraded_enters = self.degraded_enters;
        report.degraded_exits = self.degraded_exits;
        if let Some(sup) = &self.supervisor {
            report.recovery = sup.stats.clone();
        }
        collect_into(&self.sched.finished, report);
    }
}

/// Aggregate a finished list into the report — pure, so the bucket/token
/// accounting is unit-testable without an engine. Prompt tokens are
/// counted HERE, at completion classification, not at submit: PR 1
/// deliberately excluded rejected/shed requests from throughput, and the
/// submit-time accounting quietly re-inflated the prompt side of the
/// report with requests that were never served (the satellite-2 bugfix).
pub fn collect_into(finished: &[Sequence], report: &mut ServeReport) {
    for seq in finished {
        match bucket_of(seq) {
            // rejected/failed/shed requests produced no service: they
            // must not inflate requests_per_sec, prompt/generated
            // tokens, or the latency histograms
            ReportBucket::Rejected => {
                report.rejected += 1;
            }
            ReportBucket::Failed => {
                report.failed += 1;
            }
            ReportBucket::Shed => {
                report.shed_requests += 1;
            }
            ReportBucket::Completed => {
                report.n_requests += 1;
                report.prompt_tokens += seq.prompt.len() as u64;
                report.gen_tokens += seq.generated.len() as u64;
                if let Some(t) = seq.ttft_s() {
                    report.ttft.record_us(t * 1e6);
                    match seq.priority {
                        Priority::Interactive => {
                            report.ttft_interactive.record_us(t * 1e6)
                        }
                        Priority::Batch => {
                            report.ttft_batch.record_us(t * 1e6)
                        }
                    }
                }
                if let Some(t) = seq.e2e_s() {
                    report.e2e.record_us(t * 1e6);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_finish_reason_has_a_pinned_bucket() {
        use FinishReason::*;
        assert_eq!(classify_finished(Eos), ReportBucket::Completed);
        assert_eq!(classify_finished(MaxTokens), ReportBucket::Completed);
        assert_eq!(classify_finished(CacheOverflow), ReportBucket::Rejected);
        assert_eq!(classify_finished(PrefillFailed), ReportBucket::Rejected);
        assert_eq!(classify_finished(Failed), ReportBucket::Failed);
        assert_eq!(classify_finished(Shed), ReportBucket::Shed);
    }

    #[test]
    fn quarantined_sequence_buckets_as_failed_not_completed() {
        let mut s = Sequence::new(1, vec![1, 2, 3], 8, None);
        s.push_token(5); // partial service before the fault
        s.finish(FinishReason::Failed);
        assert_eq!(bucket_of(&s), ReportBucket::Failed);
    }

    #[test]
    fn shed_sequence_buckets_as_shed() {
        let mut s = Sequence::new(2, vec![1], 4, None);
        s.finish(FinishReason::Shed);
        assert_eq!(bucket_of(&s), ReportBucket::Shed);
    }

    #[test]
    fn preempted_after_quarantine_decision_counts_rejected() {
        // a sequence that somehow lands in `finished` while back in
        // Queued (preempt raced the quarantine) must not count as served
        let mut s = Sequence::new(3, vec![1, 2], 4, None);
        s.push_token(9);
        s.reset_for_restart();
        assert_eq!(s.state, SeqState::Queued);
        assert_eq!(bucket_of(&s), ReportBucket::Rejected);
    }

    #[test]
    fn default_policy_is_inert() {
        let p = RouterPolicy::default();
        assert!(!p.active());
        assert!(p.only_when_degraded);
    }

    /// Satellite-2 regression: a trace that rejects and sheds must not
    /// inflate `prompt_tokens` — pre-fix, the router charged prompt
    /// tokens at SUBMIT time, so the 7-token rejected prompt and the
    /// 9-token shed prompt below leaked into the throughput report even
    /// though PR 1 deliberately excluded them. Completion-time
    /// accounting counts served prompts only.
    #[test]
    fn rejected_and_shed_prompts_stay_out_of_the_report() {
        let mut served = Sequence::new(1, vec![1, 2, 3, 4, 5], 4, None);
        served.push_token(9);
        served.finish(FinishReason::MaxTokens);
        let mut rejected = Sequence::new(2, vec![1; 7], 4, None);
        rejected.finish(FinishReason::CacheOverflow);
        let mut shed = Sequence::new(3, vec![1; 9], 4, None);
        shed.finish(FinishReason::Shed);
        let mut failed = Sequence::new(4, vec![1; 11], 4, None);
        failed.push_token(9); // partial service, then quarantined
        failed.finish(FinishReason::Failed);

        let mut report = ServeReport::default();
        collect_into(&[served, rejected, shed, failed], &mut report);
        assert_eq!(report.n_requests, 1);
        assert_eq!(report.prompt_tokens, 5,
                   "only the served request's prompt counts");
        assert_eq!(report.gen_tokens, 1);
        assert_eq!(report.rejected, 1);
        assert_eq!(report.shed_requests, 1);
        assert_eq!(report.failed, 1);
    }
}
