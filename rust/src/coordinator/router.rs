//! Router: the serving front end. Feeds arrival traces into the scheduler
//! (open-loop with real wall-clock pacing, or closed-loop for steady-state
//! throughput) and aggregates per-request metrics.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::metrics::ServeReport;
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::sequence::{FinishReason, Priority, SeqState};
use crate::datagen::arrival::RequestSpec;
use crate::substrate::rng::Rng;

/// Generates prompt token ids for a request spec (synthetic content).
pub fn synth_prompt(len: usize, vocab: usize, rng: &mut Rng) -> Vec<i32> {
    (0..len.max(1))
        .map(|_| rng.range(crate::tokenizer::N_SPECIALS, vocab) as i32)
        .collect()
}

pub struct Router<'rt> {
    pub sched: Scheduler<'rt>,
}

impl<'rt> Router<'rt> {
    pub fn new(sched: Scheduler<'rt>) -> Router<'rt> {
        Router { sched }
    }

    /// Run a trace to completion. Requests are injected when their arrival
    /// time elapses (relative to the run start); in between, the scheduler
    /// keeps stepping. Each sequence's arrival stamp is backdated to the
    /// TRACE arrival time, so TTFT charges queueing delay incurred while
    /// the scheduler was mid-round (e.g. blocked on a monolithic prefill)
    /// — the stall that chunked prefill exists to remove. Returns the
    /// aggregate report.
    pub fn run_trace(&mut self, trace: &[RequestSpec], seed: u64)
        -> Result<ServeReport> {
        let vocab = self.sched.engine.cfg.vocab;
        let mut rng = Rng::new(seed);
        let prompts: Vec<Vec<i32>> = trace
            .iter()
            .map(|r| synth_prompt(r.prompt_len, vocab, &mut rng))
            .collect();
        let t0 = Instant::now();
        let mut next = 0usize;
        let mut report = ServeReport::default();
        while next < trace.len() || self.sched.has_work() {
            let now = t0.elapsed().as_secs_f64();
            while next < trace.len() && trace[next].arrive_s <= now {
                let arrived =
                    t0 + std::time::Duration::from_secs_f64(
                        trace[next].arrive_s);
                self.sched.submit_seq(
                    prompts[next].clone(),
                    trace[next].gen_len,
                    None,
                    trace[next].priority,
                    Some(arrived),
                );
                report.prompt_tokens += trace[next].prompt_len as u64;
                next += 1;
            }
            if self.sched.has_work() {
                self.sched.step()?;
            } else if next < trace.len() {
                // idle until the next arrival
                let wait = trace[next].arrive_s - t0.elapsed().as_secs_f64();
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        wait.min(0.01),
                    ));
                }
            }
        }
        report.total_s = t0.elapsed().as_secs_f64();
        self.collect(&mut report);
        Ok(report)
    }

    /// Closed-loop: all requests at t=0 (steady-state throughput).
    pub fn run_closed_loop(&mut self, trace: &[RequestSpec], seed: u64)
        -> Result<ServeReport> {
        let vocab = self.sched.engine.cfg.vocab;
        let mut rng = Rng::new(seed);
        let t0 = Instant::now();
        let mut report = ServeReport::default();
        for r in trace {
            let prompt = synth_prompt(r.prompt_len, vocab, &mut rng);
            report.prompt_tokens += prompt.len() as u64;
            self.sched.submit_seq(prompt, r.gen_len, None, r.priority, None);
        }
        self.sched.run_to_completion()?;
        report.total_s = t0.elapsed().as_secs_f64();
        self.collect(&mut report);
        Ok(report)
    }

    fn collect(&self, report: &mut ServeReport) {
        for seq in &self.sched.finished {
            // rejected requests produced no service: they must not inflate
            // requests_per_sec or contribute generated tokens
            if matches!(
                seq.state,
                SeqState::Finished(FinishReason::CacheOverflow)
                    | SeqState::Finished(FinishReason::PrefillFailed)
            ) {
                report.rejected += 1;
                continue;
            }
            report.n_requests += 1;
            report.gen_tokens += seq.generated.len() as u64;
            if let Some(t) = seq.ttft_s() {
                report.ttft.record_us(t * 1e6);
                match seq.priority {
                    Priority::Interactive => {
                        report.ttft_interactive.record_us(t * 1e6)
                    }
                    Priority::Batch => report.ttft_batch.record_us(t * 1e6),
                }
            }
            if let Some(t) = seq.e2e_s() {
                report.e2e.record_us(t * 1e6);
            }
        }
    }
}
