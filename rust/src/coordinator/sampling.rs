//! Token sampling from a logits row: greedy, temperature, top-k.

use crate::substrate::mathutil::{argmax, softmax};
use crate::substrate::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub enum Sampler {
    Greedy,
    /// temperature > 0; top_k == 0 means no truncation.
    TopK { temperature: f32, top_k: usize },
}

impl Sampler {
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> i32 {
        match *self {
            Sampler::Greedy => argmax(logits) as i32,
            Sampler::TopK { temperature, top_k } => {
                assert!(temperature > 0.0);
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                if top_k > 0 && top_k < logits.len() {
                    idx.sort_unstable_by(|&a, &b| {
                        logits[b].total_cmp(&logits[a])
                    });
                    idx.truncate(top_k);
                }
                let mut probs: Vec<f32> =
                    idx.iter().map(|&i| logits[i] / temperature).collect();
                softmax(&mut probs);
                let w: Vec<f64> = probs.iter().map(|&p| p as f64).collect();
                idx[rng.categorical(&w)] as i32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Rng::new(0);
        let logits = vec![0.0, 3.0, -1.0, 2.9];
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn topk_restricts_support() {
        let mut rng = Rng::new(1);
        let logits = vec![5.0, 4.9, -50.0, -50.0];
        let s = Sampler::TopK { temperature: 1.0, top_k: 2 };
        for _ in 0..100 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 0 || t == 1, "sampled {t}");
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut rng = Rng::new(2);
        let logits = vec![1.0, 1.2, 0.8];
        let s = Sampler::TopK { temperature: 0.01, top_k: 0 };
        for _ in 0..50 {
            assert_eq!(s.sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let mut rng = Rng::new(3);
        let logits = vec![1.0, 1.2, 0.8];
        let s = Sampler::TopK { temperature: 100.0, top_k: 0 };
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[s.sample(&logits, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
