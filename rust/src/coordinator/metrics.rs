//! Serving metrics: engine-level step timings and router-level per-request
//! latency/throughput summaries.

use std::collections::BTreeMap;

use crate::substrate::histogram::Histogram;

#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    pub prefill: Histogram,
    pub decode: Histogram,
    pub prefill_tokens: u64,
    /// Chunked-prefill invocations (each processes up to `--chunk-tokens`
    /// prompt positions; a monolithic prefill counts 0 here).
    pub prefill_chunks: u64,
    /// Scheduling rounds where an in-flight chunked prefill wanted to
    /// advance but the round's token budget was already spent by decode
    /// lanes — the backpressure signal for sizing `round_budget`.
    pub chunk_stall_steps: u64,
    pub decode_tokens: u64,
    pub decode_steps: u64,
    pub regroups: u64,
    /// Sequences that joined a decode lane (unparked into the arena).
    pub lane_joins: u64,
    /// Sequences that vacated a decode lane (retirement or parking).
    pub lane_leaves: u64,
    /// Host bytes the incremental lane-stable repack actually copied.
    pub copyback_bytes: u64,
    /// Host bytes the full park/unpark baseline would have copied for the
    /// same membership changes (every member out + every member back in).
    pub copyback_bytes_full: u64,
    /// Sum of (active/bucket) per decode step — mean = batch efficiency.
    pub occupancy_sum: f64,
    /// Host→device bytes uploaded into cache arenas: decode-arena uploads
    /// on membership changes (join / bucket resize / tier switch — never
    /// per step) plus the zero-arena initialization of each chunked
    /// prefill. Monolithic prefill uploads no arena (the artifact
    /// allocates its own), so chunked mode's extra traffic is visible
    /// here rather than hidden.
    pub sync_upload_bytes: u64,
    /// Device→host FULL-ARENA cache downloads. The delta-synced host
    /// mirror makes these unnecessary; the counter is the regression
    /// tripwire — it must stay 0 (asserted by the steady-churn e2e test
    /// and reported by bench_serving).
    pub sync_download_bytes: u64,
    /// Per-step delta-row download bytes (`k_rows`/`v_rows`), the O(L·B)
    /// host traffic that replaced the O(L·B·max_seq) arena round trips.
    pub row_sync_bytes: u64,
    /// Current decode arena allocation (K+V, bytes) — a gauge, sized by
    /// the active tier and bucket rather than max context.
    pub arena_bytes: u64,
    /// Context-tier switches (arena grow or shrink).
    pub tier_switches: u64,
    /// Decode steps executed per context tier — per-tier occupancy of the
    /// artifact grid (mixed-length workloads exercise several tiers).
    pub tier_steps: BTreeMap<usize, u64>,
}

impl EngineMetrics {
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.occupancy_sum / self.decode_steps as f64
        }
    }

    pub fn decode_tokens_per_sec(&self) -> f64 {
        let total_s = self.decode.mean_us() * self.decode.count() as f64 / 1e6;
        if total_s == 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / total_s
        }
    }

    /// How many times fewer bytes the incremental repack copied vs the
    /// full park/unpark baseline (None while nothing was copied).
    pub fn copyback_savings(&self) -> Option<f64> {
        if self.copyback_bytes_full == 0 {
            None
        } else if self.copyback_bytes == 0 {
            Some(f64::INFINITY)
        } else {
            Some(self.copyback_bytes_full as f64 / self.copyback_bytes as f64)
        }
    }

    /// Mean delta-sync bytes per decode step — the per-step host traffic,
    /// which is O(L·B·(KD+VD)) and independent of max_seq.
    pub fn row_sync_bytes_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.row_sync_bytes as f64 / self.decode_steps as f64
        }
    }

    pub fn report(&self) -> String {
        let savings = match self.copyback_savings() {
            Some(s) if s.is_finite() => format!("{s:.1}x saved"),
            Some(_) => "all saved".to_string(),
            None => "no churn".to_string(),
        };
        let tiers: Vec<String> = self
            .tier_steps
            .iter()
            .map(|(t, n)| format!("n{t}:{n}"))
            .collect();
        format!(
            "prefill: {} ({} tokens, {} chunks, {} stalled rounds)\n\
             decode:  {} ({} tokens, {} steps, \
             {:.2} occupancy, {} regroups)\n\
             lanes:   {} joins, {} leaves, copyback {} B vs {} B \
             full-repack baseline ({savings})\n\
             sync:    up {} B, down {} B (full-arena), delta {:.0} B/step, \
             arena {} B, {} tier switches [{}]\n\
             decode throughput: {:.1} tok/s",
            self.prefill.summary(),
            self.prefill_tokens,
            self.prefill_chunks,
            self.chunk_stall_steps,
            self.decode.summary(),
            self.decode_tokens,
            self.decode_steps,
            self.mean_occupancy(),
            self.regroups,
            self.lane_joins,
            self.lane_leaves,
            self.copyback_bytes,
            self.copyback_bytes_full,
            self.sync_upload_bytes,
            self.sync_download_bytes,
            self.row_sync_bytes_per_step(),
            self.arena_bytes,
            self.tier_switches,
            tiers.join(" "),
            self.decode_tokens_per_sec()
        )
    }
}

/// Per-request latency summary produced by the router. Rejected requests
/// (cache overflow, prefill failure) are counted only in `rejected` —
/// they contribute neither tokens nor requests to the throughput rates.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Requests that completed generation (excludes `rejected`).
    pub n_requests: usize,
    pub total_s: f64,
    pub prompt_tokens: u64,
    pub gen_tokens: u64,
    pub ttft: Histogram,
    /// TTFT split by priority class — the chunked-prefill acceptance
    /// metric is `ttft_interactive.quantile_us(0.99)` under the mixed
    /// chat+doc trace (see `serving::chunked_prefill_table`).
    pub ttft_interactive: Histogram,
    pub ttft_batch: Histogram,
    pub e2e: Histogram,
    pub rejected: usize,
}

impl ServeReport {
    pub fn gen_tokens_per_sec(&self) -> f64 {
        if self.total_s == 0.0 {
            0.0
        } else {
            self.gen_tokens as f64 / self.total_s
        }
    }

    pub fn requests_per_sec(&self) -> f64 {
        if self.total_s == 0.0 {
            0.0
        } else {
            self.n_requests as f64 / self.total_s
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{} requests in {:.2}s ({:.2} req/s, {:.1} gen tok/s, {} rejected)\n\
             TTFT: {}\nE2E:  {}",
            self.n_requests,
            self.total_s,
            self.requests_per_sec(),
            self.gen_tokens_per_sec(),
            self.rejected,
            self.ttft.summary(),
            self.e2e.summary()
        )
    }

    /// The per-class TTFT lines (only meaningful when the trace carries
    /// both priority classes; empty histograms render with n=0).
    pub fn report_by_class(&self) -> String {
        format!(
            "TTFT (interactive): {}\nTTFT (batch):       {}",
            self.ttft_interactive.summary(),
            self.ttft_batch.summary()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_mean() {
        let mut m = EngineMetrics::default();
        m.decode_steps = 2;
        m.occupancy_sum = 1.5;
        assert!((m.mean_occupancy() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn throughput_zero_when_empty() {
        let m = EngineMetrics::default();
        assert_eq!(m.decode_tokens_per_sec(), 0.0);
        let r = ServeReport::default();
        assert_eq!(r.gen_tokens_per_sec(), 0.0);
    }

    #[test]
    fn copyback_savings_ratio() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.copyback_savings(), None);
        m.copyback_bytes_full = 800;
        assert_eq!(m.copyback_savings(), Some(f64::INFINITY));
        m.copyback_bytes = 100;
        assert_eq!(m.copyback_savings(), Some(8.0));
    }

    #[test]
    fn row_sync_per_step() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.row_sync_bytes_per_step(), 0.0);
        m.decode_steps = 4;
        m.row_sync_bytes = 400;
        assert!((m.row_sync_bytes_per_step() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn reports_render() {
        let mut m = EngineMetrics::default();
        m.tier_steps.insert(32, 5);
        m.tier_steps.insert(256, 1);
        m.prefill_chunks = 7;
        m.chunk_stall_steps = 2;
        assert!(m.report().contains("decode throughput"));
        assert!(m.report().contains("copyback"));
        assert!(m.report().contains("n32:5"));
        assert!(m.report().contains("tier switches"));
        assert!(m.report().contains("7 chunks"));
        assert!(m.report().contains("2 stalled rounds"));
        let r = ServeReport { n_requests: 3, total_s: 1.5, gen_tokens: 30,
                              ..Default::default() };
        assert!(r.report().contains("3 requests"));
        assert!((r.gen_tokens_per_sec() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn per_class_ttft_report() {
        let mut r = ServeReport::default();
        r.ttft_interactive.record_us(1000.0);
        r.ttft_batch.record_us(9000.0);
        let s = r.report_by_class();
        assert!(s.contains("interactive"));
        assert!(s.contains("batch"));
        assert!(r.ttft_interactive.quantile_us(0.99)
                < r.ttft_batch.quantile_us(0.99));
    }
}
