//! Serving metrics: engine-level step timings and router-level per-request
//! latency/throughput summaries.

use std::collections::BTreeMap;

use crate::substrate::histogram::Histogram;
use crate::substrate::tensor::KvQuant;

/// Dtype-aware cache byte sizing (ISSUE 4): every byte counter the engine
/// reports goes through here instead of a hardcoded 4 bytes/element, so
/// `arena_bytes`/`row_sync_bytes`/`sync_upload_bytes` report true traffic
/// for both fp32 and int8 arenas. Payload (codes/values) and the q8
/// per-row fp32 scale planes are sized separately: `arena_bytes` is the
/// payload gauge (the 4x headline), `arena_scale_bytes` the scale-plane
/// gauge, and the traffic counters include both.
#[derive(Clone, Copy, Debug)]
pub struct ArenaSizing {
    pub n_layers: usize,
    pub k_dims: usize,
    pub v_dims: usize,
    pub quant: KvQuant,
}

impl ArenaSizing {
    /// Payload bytes of one K+V cache row across all layers.
    pub fn row_payload_bytes(&self) -> usize {
        self.n_layers * (self.k_dims + self.v_dims) * self.quant.elem_bytes()
    }

    /// Scale bytes of one K+V cache row across all layers (one fp32 per
    /// arena per row in q8 mode; 0 in fp32 mode).
    pub fn row_scale_bytes(&self) -> usize {
        self.n_layers * 2 * self.quant.scale_bytes_per_row()
    }

    /// Total host bytes that move when one full cache row moves.
    pub fn row_bytes(&self) -> usize {
        self.row_payload_bytes() + self.row_scale_bytes()
    }

    /// K+V payload bytes of a (bucket × tier) decode arena pair.
    pub fn arena_payload_bytes(&self, bucket: usize, tier: usize) -> usize {
        self.n_layers * bucket * tier * (self.k_dims + self.v_dims)
            * self.quant.elem_bytes()
    }

    /// K-arena payload bytes alone of a (bucket × tier) decode arena.
    /// The paper's composition claims (thin keys × GQA × q8) act on the
    /// KEY cache specifically — `k_dims` is `n_kv_heads · d_qk_head`, so
    /// this gauge shrinks with the group factor AND the thin rank AND the
    /// element width (ISSUE 5: the measured 16x headline reads off it).
    pub fn arena_k_payload_bytes(&self, bucket: usize, tier: usize) -> usize {
        self.n_layers * bucket * tier * self.k_dims * self.quant.elem_bytes()
    }

    /// K+V scale-plane bytes of a (bucket × tier) decode arena pair.
    pub fn arena_scale_bytes(&self, bucket: usize, tier: usize) -> usize {
        self.n_layers * bucket * tier * 2 * self.quant.scale_bytes_per_row()
    }

    /// K-arena scale-plane bytes alone (one fp32 per K row in q8 mode) —
    /// reported next to `arena_k_payload_bytes` so the composed key-cache
    /// ratios stay honest about scale overhead at thin grouped widths.
    pub fn arena_k_scale_bytes(&self, bucket: usize, tier: usize) -> usize {
        self.n_layers * bucket * tier * self.quant.scale_bytes_per_row()
    }
}

#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    pub prefill: Histogram,
    pub decode: Histogram,
    pub prefill_tokens: u64,
    /// Chunked-prefill invocations (each processes up to `--chunk-tokens`
    /// prompt positions; a monolithic prefill counts 0 here).
    pub prefill_chunks: u64,
    /// Scheduling rounds where an in-flight chunked prefill wanted to
    /// advance but the round's token budget was already spent by decode
    /// lanes — the backpressure signal for sizing `round_budget`.
    pub chunk_stall_steps: u64,
    pub decode_tokens: u64,
    pub decode_steps: u64,
    pub regroups: u64,
    /// Sequences that joined a decode lane (unparked into the arena).
    pub lane_joins: u64,
    /// Sequences that vacated a decode lane (retirement or parking).
    pub lane_leaves: u64,
    /// Host bytes the incremental lane-stable repack actually copied.
    pub copyback_bytes: u64,
    /// Host bytes the full park/unpark baseline would have copied for the
    /// same membership changes (every member out + every member back in).
    pub copyback_bytes_full: u64,
    /// Sum of (active/bucket) per decode step — mean = batch efficiency.
    pub occupancy_sum: f64,
    /// Host→device bytes uploaded into cache arenas: decode-arena uploads
    /// on membership changes (join / bucket resize / tier switch — never
    /// per step) plus the zero-arena initialization of each chunked
    /// prefill. Monolithic prefill uploads no arena (the artifact
    /// allocates its own), so chunked mode's extra traffic is visible
    /// here rather than hidden.
    pub sync_upload_bytes: u64,
    /// Device→host FULL-ARENA cache downloads. The delta-synced host
    /// mirror makes these unnecessary; the counter is the regression
    /// tripwire — it must stay 0 (asserted by the steady-churn e2e test
    /// and reported by bench_serving).
    pub sync_download_bytes: u64,
    /// Delta-row download bytes (`k_rows`/`v_rows` + q8 scales): the
    /// O(L·B) per decode step that replaced the O(L·B·max_seq) arena
    /// round trips, plus each prefill chunk's O(L·C) delta — so chunked
    /// mode's download traffic is charged here symmetrically with its
    /// `sync_upload_bytes` charge. Dtype-aware: ~4x smaller at q8.
    pub row_sync_bytes: u64,
    /// Current decode arena PAYLOAD allocation (K+V codes/values, bytes)
    /// — a gauge, sized by the active tier and bucket rather than max
    /// context, and by the KV quant mode's element width (4x smaller at
    /// q8). The paper's composition claim reads off this gauge.
    pub arena_bytes: u64,
    /// Current q8 scale-plane allocation (one fp32 per cache row per
    /// arena; 0 in fp32 mode) — reported next to `arena_bytes` so the
    /// quantized totals stay honest about the scale overhead.
    pub arena_scale_bytes: u64,
    /// K-arena share of `arena_bytes` (payload codes/values only) — the
    /// gauge the composed key-cache compression table reads (ISSUE 5):
    /// `k_dims = n_kv_heads · d_qk_head` makes it group-, rank-, and
    /// dtype-sized, so servegqathin-q8 vs servefull-fp32 is measured off
    /// the engine rather than recomputed analytically.
    pub arena_k_bytes: u64,
    /// K-arena share of `arena_scale_bytes` (q8 per-row scales; 0 at
    /// fp32) — the honest overhead line next to `arena_k_bytes`.
    pub arena_k_scale_bytes: u64,
    /// Context-tier switches (arena grow or shrink).
    pub tier_switches: u64,
    /// Decode steps executed per context tier — per-tier occupancy of the
    /// artifact grid (mixed-length workloads exercise several tiers).
    pub tier_steps: BTreeMap<usize, u64>,
    /// Scheduler steps the [`crate::analysis::auditor::EngineAuditor`]
    /// cross-checked (debug / `audit`-feature builds; stays 0 in plain
    /// release builds). The e2e churn suites assert this is > 0 so an
    /// accidentally compiled-out auditor cannot pass silently.
    pub audit_checks: u64,
    /// Faults the [`crate::runtime::FaultInjector`] injected (mirrored
    /// from the runtime by `Engine::sync_fault_metrics`; 0 in production
    /// where no fault plan is installed).
    pub faults_injected: u64,
    /// Engine-step retries the scheduler issued after retryable failures
    /// (each paid one exponential-backoff sleep, see `retry_backoff`).
    pub step_retries: u64,
    /// Steps that ultimately succeeded after at least one retry — the
    /// recovery headline next to `faults_injected`.
    pub recovered_steps: u64,
    /// Sequences quarantined (`FinishReason::Failed`) after a persistent
    /// sequence-local fault exhausted its retry budget.
    pub quarantined_seqs: u64,
    /// Steps whose failure escalated past the retry/quarantine policy
    /// (real runtime errors, or an exhausted whole-batch fault). The
    /// chaos suite asserts this stays 0 under bounded fault schedules.
    pub fatal_steps: u64,
    /// Backoff sleeps, in microseconds, across all step retries. Records
    /// the CLAMPED slot actually slept (capped by
    /// `SchedConfig::max_step_backoff_us`), not the raw exponential.
    pub retry_backoff: Histogram,
    /// Admissions whose prompt matched a registered shared prefix
    /// (ISSUE 8): the matched blocks were adopted refcount-only and
    /// their rows skipped prefill entirely.
    pub prefix_hits: u64,
    /// Prompt rows adopted from the shared block store instead of
    /// prefilled — the tokens the prefix-hit fast path never recomputed.
    pub prefix_hit_tokens: u64,
    /// Copy-on-write splits: forks whose write frontier split a block
    /// mid-way, copying the partial tail into private child storage.
    pub cow_splits: u64,
    /// Gauge: blocks currently referenced by 2+ sequences.
    pub shared_blocks: u64,
    /// Gauge: host bytes sharing saves vs one private copy per
    /// reference (`extra_refs × block_bytes`).
    pub dedup_bytes: f64,
    /// Gauge: block-pool occupancy, used out of `block_pool_total`.
    pub block_pool_used: u64,
    pub block_pool_total: u64,
    /// Delta-download bytes of the per-row `attn_mass` plane (one f32
    /// per lane·position per decode step). Charged separately from
    /// `row_sync_bytes` so existing delta-sync accounting is unchanged
    /// when the scorer plane rides along.
    pub mass_sync_bytes: u64,
    /// Bounded-cache eviction telemetry (ISSUE 10).
    pub eviction: EvictionStats,
}

impl EngineMetrics {
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.occupancy_sum / self.decode_steps as f64
        }
    }

    pub fn decode_tokens_per_sec(&self) -> f64 {
        let total_s = self.decode.mean_us() * self.decode.count() as f64 / 1e6;
        if total_s == 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / total_s
        }
    }

    /// How many times fewer bytes the incremental repack copied vs the
    /// full park/unpark baseline (None while nothing was copied).
    pub fn copyback_savings(&self) -> Option<f64> {
        if self.copyback_bytes_full == 0 {
            None
        } else if self.copyback_bytes == 0 {
            Some(f64::INFINITY)
        } else {
            Some(self.copyback_bytes_full as f64 / self.copyback_bytes as f64)
        }
    }

    /// Mean delta-sync bytes per sync event — a decode step (O(L·B·
    /// (KD+VD)) rows) or a prefill chunk (O(L·C) rows), both independent
    /// of max_seq. `row_sync_bytes` charges chunk deltas too, so the
    /// denominator must count chunks or chunked-mode runs would inflate
    /// the per-decode-step reading by the whole prefill volume; in
    /// monolithic mode `prefill_chunks` is 0 and this is exactly
    /// bytes per decode step.
    pub fn row_sync_bytes_per_step(&self) -> f64 {
        let events = self.decode_steps + self.prefill_chunks;
        if events == 0 {
            0.0
        } else {
            self.row_sync_bytes as f64 / events as f64
        }
    }

    pub fn report(&self) -> String {
        let savings = match self.copyback_savings() {
            Some(s) if s.is_finite() => format!("{s:.1}x saved"),
            Some(_) => "all saved".to_string(),
            None => "no churn".to_string(),
        };
        let tiers: Vec<String> = self
            .tier_steps
            .iter()
            .map(|(t, n)| format!("n{t}:{n}"))
            .collect();
        format!(
            "prefill: {} ({} tokens, {} chunks, {} stalled rounds)\n\
             decode:  {} ({} tokens, {} steps, \
             {:.2} occupancy, {} regroups)\n\
             lanes:   {} joins, {} leaves, copyback {} B vs {} B \
             full-repack baseline ({savings})\n\
             sync:    up {} B, down {} B (full-arena), delta {:.0} B/step, \
             arena {} B (+{} B scales) [K {} B +{} B], \
             {} tier switches [{}]\n\
             paged:   {} prefix hits ({} rows adopted), {} shared blocks, \
             dedup {:.0} B, {} CoW splits, pool {}/{} blocks\n\
             faults:  {} injected, {} retries (backoff {}), \
             {} recovered, {} quarantined, {} fatal\n\
             {}\n\
             decode throughput: {:.1} tok/s",
            self.prefill.summary(),
            self.prefill_tokens,
            self.prefill_chunks,
            self.chunk_stall_steps,
            self.decode.summary(),
            self.decode_tokens,
            self.decode_steps,
            self.mean_occupancy(),
            self.regroups,
            self.lane_joins,
            self.lane_leaves,
            self.copyback_bytes,
            self.copyback_bytes_full,
            self.sync_upload_bytes,
            self.sync_download_bytes,
            self.row_sync_bytes_per_step(),
            self.arena_bytes,
            self.arena_scale_bytes,
            self.arena_k_bytes,
            self.arena_k_scale_bytes,
            self.tier_switches,
            tiers.join(" "),
            self.prefix_hits,
            self.prefix_hit_tokens,
            self.shared_blocks,
            self.dedup_bytes,
            self.cow_splits,
            self.block_pool_used,
            self.block_pool_total,
            self.faults_injected,
            self.step_retries,
            self.retry_backoff.summary(),
            self.recovered_steps,
            self.quarantined_seqs,
            self.fatal_steps,
            self.eviction.report(self.mass_sync_bytes),
            self.decode_tokens_per_sec()
        )
    }
}

/// Bounded-cache eviction telemetry (ISSUE 10), kept inside
/// [`EngineMetrics`] so both halves of an eviction — the scheduler's
/// block-table trim and the engine's mirror zeroing — report into one
/// place. All zeros when `--eviction none`.
#[derive(Clone, Debug, Default)]
pub struct EvictionStats {
    /// 16-token blocks evicted whole back to the pool.
    pub evicted_blocks: u64,
    /// Cache rows zeroed in the engine mirror (ledger total across
    /// live + retired sequences).
    pub evicted_rows: u64,
    /// Eviction candidates refused because the block was shared
    /// (refcount > 1), registered in the prefix tree, or inside the
    /// copy-on-write shared region — the "never evict shared prefixes"
    /// guarantee, counted rather than silently skipped.
    pub refused_shared: u64,
    /// Decode steps whose `attn_mass` plane fed the scorer.
    pub score_steps: u64,
    /// Admissions that succeeded only under the eviction-capped
    /// reservation (the full `prompt + max_new` reservation would have
    /// overflowed the pool) — the bounded-cache admission headline.
    pub capped_admissions: u64,
    /// High-water mark of live (non-evicted) blocks held by any single
    /// sequence — the acceptance bound is `<= budget blocks`.
    pub peak_seq_blocks: u64,
    /// Configured per-sequence live-block budget (gauge; 0 = off).
    pub budget_blocks: u64,
}

impl EvictionStats {
    pub fn report(&self, mass_sync_bytes: u64) -> String {
        format!(
            "evict:   {} blocks ({} rows), {} refused shared, \
             {} scored steps (mass {} B), {} capped admissions, \
             peak {}/{} blocks/seq",
            self.evicted_blocks,
            self.evicted_rows,
            self.refused_shared,
            self.score_steps,
            mass_sync_bytes,
            self.capped_admissions,
            self.peak_seq_blocks,
            self.budget_blocks
        )
    }
}

/// Crash-recovery telemetry kept by the supervisor
/// (`coordinator/supervisor.rs`) and embedded in [`ServeReport`] — it
/// lives here so the report type need not depend on the supervisor
/// module. Restart work must be visible in the serving report, not
/// inferred: a recovered Fatal costs checkpoint bytes, backoff sleeps,
/// and replayed tokens, and all three are first-class numbers.
#[derive(Clone, Debug, Default)]
pub struct RecoveryStats {
    /// Engines dropped and rebuilt after a Fatal or a watchdog trip.
    pub engine_restarts: u64,
    /// Restarts triggered by the per-step wall-clock watchdog (a wedged
    /// execute that never errored) — a subset of `engine_restarts`.
    pub watchdog_trips: u64,
    /// Checkpoints taken (every K scheduler rounds).
    pub checkpoint_rounds: u64,
    /// Tokens that had been generated since the restored checkpoint and
    /// were therefore re-generated by deterministic replay.
    pub replayed_tokens: u64,
    /// Restart-budget exhaustions escalated to the router (which drains
    /// and sheds). The restart e2e asserts this stays 0 under bounded
    /// fault plans.
    pub escalations: u64,
    /// Gauge: host bytes pinned by the most recent checkpoint's arena
    /// mirrors (payload + scale planes).
    pub checkpoint_bytes: u64,
    /// High-water mark of `checkpoint_bytes` across the run.
    pub peak_checkpoint_bytes: u64,
    /// Pre-restart backoff sleeps, in microseconds (exponential in the
    /// consecutive-restart count, clamped).
    pub restart_backoff: Histogram,
}

impl RecoveryStats {
    pub fn report(&self) -> String {
        format!(
            "recovery: {} restarts ({} watchdog), {} checkpoints \
             ({} B, peak {} B), {} replayed tokens, {} escalations, \
             backoff {}",
            self.engine_restarts,
            self.watchdog_trips,
            self.checkpoint_rounds,
            self.checkpoint_bytes,
            self.peak_checkpoint_bytes,
            self.replayed_tokens,
            self.escalations,
            self.restart_backoff.summary()
        )
    }
}

/// Per-request latency summary produced by the router. Rejected requests
/// (cache overflow, prefill failure) are counted only in `rejected` —
/// they contribute neither tokens nor requests to the throughput rates.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Requests that completed generation (excludes `rejected`).
    pub n_requests: usize,
    pub total_s: f64,
    pub prompt_tokens: u64,
    pub gen_tokens: u64,
    pub ttft: Histogram,
    /// TTFT split by priority class — the chunked-prefill acceptance
    /// metric is `ttft_interactive.quantile_us(0.99)` under the mixed
    /// chat+doc trace (see `serving::chunked_prefill_table`).
    pub ttft_interactive: Histogram,
    pub ttft_batch: Histogram,
    pub e2e: Histogram,
    pub rejected: usize,
    /// Requests quarantined mid-service (`FinishReason::Failed`): partial
    /// work is discarded and contributes nothing to the rates above.
    pub failed: usize,
    /// Requests load-shed from the waiting queue (`FinishReason::Shed`)
    /// by the router's degradation policy.
    pub shed_requests: usize,
    /// Rounds the router observed itself degraded (fresh faults or KV
    /// pressure) — the satellite-2 observable: shedding decisions are
    /// explainable from the report instead of inferred.
    pub degraded_rounds: u64,
    /// Healthy→degraded transitions across the run.
    pub degraded_enters: u64,
    /// Degraded→healthy transitions across the run.
    pub degraded_exits: u64,
    /// Crash-recovery counters (all zero when no supervisor is attached).
    pub recovery: RecoveryStats,
}

impl ServeReport {
    pub fn gen_tokens_per_sec(&self) -> f64 {
        if self.total_s == 0.0 {
            0.0
        } else {
            self.gen_tokens as f64 / self.total_s
        }
    }

    pub fn requests_per_sec(&self) -> f64 {
        if self.total_s == 0.0 {
            0.0
        } else {
            self.n_requests as f64 / self.total_s
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{} requests in {:.2}s ({:.2} req/s, {:.1} gen tok/s, \
             {} rejected, {} failed, {} shed)\n\
             TTFT: {}\nE2E:  {}\n\
             degraded: {} rounds ({} enters, {} exits)\n{}",
            self.n_requests,
            self.total_s,
            self.requests_per_sec(),
            self.gen_tokens_per_sec(),
            self.rejected,
            self.failed,
            self.shed_requests,
            self.ttft.summary(),
            self.e2e.summary(),
            self.degraded_rounds,
            self.degraded_enters,
            self.degraded_exits,
            self.recovery.report()
        )
    }

    /// The per-class TTFT lines (only meaningful when the trace carries
    /// both priority classes; empty histograms render with n=0).
    pub fn report_by_class(&self) -> String {
        format!(
            "TTFT (interactive): {}\nTTFT (batch):       {}",
            self.ttft_interactive.summary(),
            self.ttft_batch.summary()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_mean() {
        let mut m = EngineMetrics::default();
        m.decode_steps = 2;
        m.occupancy_sum = 1.5;
        assert!((m.mean_occupancy() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn throughput_zero_when_empty() {
        let m = EngineMetrics::default();
        assert_eq!(m.decode_tokens_per_sec(), 0.0);
        let r = ServeReport::default();
        assert_eq!(r.gen_tokens_per_sec(), 0.0);
    }

    #[test]
    fn copyback_savings_ratio() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.copyback_savings(), None);
        m.copyback_bytes_full = 800;
        assert_eq!(m.copyback_savings(), Some(f64::INFINITY));
        m.copyback_bytes = 100;
        assert_eq!(m.copyback_savings(), Some(8.0));
    }

    #[test]
    fn row_sync_per_step() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.row_sync_bytes_per_step(), 0.0);
        m.decode_steps = 4;
        m.row_sync_bytes = 400;
        assert!((m.row_sync_bytes_per_step() - 100.0).abs() < 1e-12);
        // chunked mode: prefill chunks are sync events too — their delta
        // bytes are in the numerator, so they must be in the denominator
        // (or the per-decode-step reading inflates by the prefill volume)
        m.prefill_chunks = 4;
        m.row_sync_bytes = 800;
        assert!((m.row_sync_bytes_per_step() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn reports_render() {
        let mut m = EngineMetrics::default();
        m.tier_steps.insert(32, 5);
        m.tier_steps.insert(256, 1);
        m.prefill_chunks = 7;
        m.chunk_stall_steps = 2;
        assert!(m.report().contains("decode throughput"));
        assert!(m.report().contains("copyback"));
        assert!(m.report().contains("n32:5"));
        assert!(m.report().contains("tier switches"));
        assert!(m.report().contains("7 chunks"));
        assert!(m.report().contains("2 stalled rounds"));
        let r = ServeReport { n_requests: 3, total_s: 1.5, gen_tokens: 30,
                              ..Default::default() };
        assert!(r.report().contains("3 requests"));
        assert!((r.gen_tokens_per_sec() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn arena_sizing_fp32_matches_legacy_4_bytes() {
        // the pre-ISSUE-4 hardcoded sizing: 4 bytes per element, no scales
        let s = ArenaSizing {
            n_layers: 3,
            k_dims: 16,
            v_dims: 64,
            quant: KvQuant::Fp32,
        };
        assert_eq!(s.row_payload_bytes(), 3 * (16 + 64) * 4);
        assert_eq!(s.row_scale_bytes(), 0);
        assert_eq!(s.row_bytes(), 3 * (16 + 64) * 4);
        assert_eq!(s.arena_payload_bytes(8, 32), 3 * 8 * 32 * 80 * 4);
        assert_eq!(s.arena_scale_bytes(8, 32), 0);
    }

    #[test]
    fn arena_sizing_q8_is_4x_payload_plus_scales() {
        let q = ArenaSizing {
            n_layers: 3,
            k_dims: 16,
            v_dims: 64,
            quant: KvQuant::Q8,
        };
        let f = ArenaSizing { quant: KvQuant::Fp32, ..q };
        // payload shrinks exactly 4x
        assert_eq!(f.arena_payload_bytes(8, 32),
                   4 * q.arena_payload_bytes(8, 32));
        // one fp32 scale per row per arena (K and V)
        assert_eq!(q.row_scale_bytes(), 3 * 2 * 4);
        assert_eq!(q.arena_scale_bytes(8, 32), 3 * 8 * 32 * 2 * 4);
        // a moved row carries payload + scales
        assert_eq!(q.row_bytes(), 3 * 80 + 24);
        assert!(q.row_bytes() < f.row_bytes());
    }

    #[test]
    fn report_renders_scale_gauge() {
        let mut m = EngineMetrics::default();
        m.arena_bytes = 1000;
        m.arena_scale_bytes = 96;
        m.arena_k_bytes = 200;
        m.arena_k_scale_bytes = 48;
        assert!(m.report().contains("1000 B (+96 B scales)"));
        assert!(m.report().contains("[K 200 B +48 B]"));
    }

    /// The grouped composition, on the sizing math the engine gauges use
    /// (ISSUE 5): at the serving geometry (3 layers, d_model 64, 8q
    /// heads) the K-arena payload of servegqathin-q8 (2 kv heads, thin
    /// d_qk_head 2 → k_dims 4, int8) is exactly 64x below
    /// servefull-fp32 (k_dims 64, fp32) at the same (bucket, tier) —
    /// group 4x × rank 4x × width 4x; K scales reported separately.
    #[test]
    fn arena_sizing_grouped_thin_q8_key_composition() {
        let full = ArenaSizing {
            n_layers: 3,
            k_dims: 64, // 8 heads × d_qk_head 8
            v_dims: 64,
            quant: KvQuant::Fp32,
        };
        let gqa_thin_q8 = ArenaSizing {
            n_layers: 3,
            k_dims: 4, // 2 kv heads × thin d_qk_head 2
            v_dims: 16,
            quant: KvQuant::Q8,
        };
        let (b, n) = (4, 32);
        assert_eq!(full.arena_k_payload_bytes(b, n),
                   64 * gqa_thin_q8.arena_k_payload_bytes(b, n));
        assert_eq!(full.arena_k_scale_bytes(b, n), 0);
        // one fp32 scale per K row per (layer, lane, position)
        assert_eq!(gqa_thin_q8.arena_k_scale_bytes(b, n), 3 * b * n * 4);
        // K + V split is consistent with the combined payload gauge
        assert_eq!(
            full.arena_k_payload_bytes(b, n)
                + full.n_layers * b * n * full.v_dims
                    * full.quant.elem_bytes(),
            full.arena_payload_bytes(b, n)
        );
        // even payload + scales stays ≥ 15x — the acceptance floor
        let full_k = full.arena_k_payload_bytes(b, n) as f64;
        let q8_k = (gqa_thin_q8.arena_k_payload_bytes(b, n)
            + gqa_thin_q8.arena_k_scale_bytes(b, n)) as f64;
        assert!(full_k / q8_k >= 15.0, "{}", full_k / q8_k);
    }

    #[test]
    fn report_renders_fault_recovery_counters() {
        let mut m = EngineMetrics::default();
        m.faults_injected = 6;
        m.step_retries = 5;
        m.recovered_steps = 4;
        m.quarantined_seqs = 1;
        m.fatal_steps = 0;
        m.retry_backoff.record_us(200.0);
        let s = m.report();
        assert!(s.contains("6 injected"));
        assert!(s.contains("5 retries"));
        assert!(s.contains("4 recovered"));
        assert!(s.contains("1 quarantined"));
        assert!(s.contains("0 fatal"));
        let r = ServeReport { n_requests: 2, total_s: 1.0, rejected: 1,
                              failed: 3, shed_requests: 4,
                              ..Default::default() };
        assert!(r.report().contains("1 rejected"));
        assert!(r.report().contains("3 failed"));
        assert!(r.report().contains("4 shed"));
    }

    #[test]
    fn report_renders_recovery_and_degradation_counters() {
        let mut r = ServeReport::default();
        r.degraded_rounds = 9;
        r.degraded_enters = 2;
        r.degraded_exits = 1;
        r.recovery.engine_restarts = 3;
        r.recovery.watchdog_trips = 1;
        r.recovery.checkpoint_rounds = 12;
        r.recovery.replayed_tokens = 40;
        r.recovery.checkpoint_bytes = 2048;
        r.recovery.peak_checkpoint_bytes = 4096;
        r.recovery.restart_backoff.record_us(400.0);
        let s = r.report();
        assert!(s.contains("degraded: 9 rounds (2 enters, 1 exits)"));
        assert!(s.contains("3 restarts (1 watchdog)"));
        assert!(s.contains("12 checkpoints (2048 B, peak 4096 B)"));
        assert!(s.contains("40 replayed tokens"));
        assert!(s.contains("0 escalations"));
    }

    #[test]
    fn recovery_stats_default_is_all_zero() {
        let r = RecoveryStats::default();
        assert_eq!(r.engine_restarts, 0);
        assert_eq!(r.escalations, 0);
        assert_eq!(r.restart_backoff.count(), 0);
        assert!(r.report().contains("0 restarts (0 watchdog)"));
    }

    #[test]
    fn per_class_ttft_report() {
        let mut r = ServeReport::default();
        r.ttft_interactive.record_us(1000.0);
        r.ttft_batch.record_us(9000.0);
        let s = r.report_by_class();
        assert!(s.contains("interactive"));
        assert!(s.contains("batch"));
        assert!(r.ttft_interactive.quantile_us(0.99)
                < r.ttft_batch.quantile_us(0.99));
    }
}
