//! Concurrent-user capacity planning — the paper's headline serving claim:
//! "factored keys save 25 GB per user at 128K context, enabling ~60% more
//! concurrent users on identical hardware".
//!
//! Users are admitted with a full-context KV reservation (the same policy
//! `coordinator::scheduler` enforces), so capacity = free HBM after weights
//! divided by per-user KV bytes.

use crate::coordinator::roofline::{KvGeometry, GB};

#[derive(Clone, Copy, Debug)]
pub struct HardwareSpec {
    pub hbm_gb: f64,
    pub weights_gb: f64,
    /// Activations / fragmentation reserve.
    pub reserve_gb: f64,
}

/// An 8xH100 (80 GB each) node serving a 7B model in bf16, as in §1.
pub const H100_NODE_7B: HardwareSpec = HardwareSpec {
    hbm_gb: 640.0,
    weights_gb: 14.0,
    reserve_gb: 26.0,
};

pub fn kv_bytes_per_user(geom: KvGeometry, ctx: usize, layers: usize,
                         bytes_per_el: f64) -> f64 {
    geom.cache_bytes(ctx, layers, bytes_per_el)
}

pub fn concurrent_users(hw: HardwareSpec, geom: KvGeometry, ctx: usize,
                        layers: usize, bytes_per_el: f64) -> usize {
    let free = (hw.hbm_gb - hw.weights_gb - hw.reserve_gb) * GB;
    if free <= 0.0 {
        return 0;
    }
    (free / kv_bytes_per_user(geom, ctx, layers, bytes_per_el)) as usize
}

/// The paper's comparison: standard vs d/4 thin keys at 128K, fp16, 7B.
pub struct CapacityComparison {
    pub users_standard: usize,
    pub users_thin: usize,
    /// Continuous admission-capacity gain (bytes-per-user ratio − 1); the
    /// integer user counts additionally reflect flooring.
    pub gain_pct: f64,
    pub saved_gb_per_user: f64,
}

pub fn headline_comparison(hw: HardwareSpec) -> CapacityComparison {
    let (d, layers, ctx, b) = (4096usize, 32usize, 128_000usize, 2.0);
    let std = KvGeometry::mha(d);
    let thin = KvGeometry::thin(d, d / 4);
    let std_bytes = kv_bytes_per_user(std, ctx, layers, b);
    let thin_bytes = kv_bytes_per_user(thin, ctx, layers, b);
    CapacityComparison {
        users_standard: concurrent_users(hw, std, ctx, layers, b),
        users_thin: concurrent_users(hw, thin, ctx, layers, b),
        gain_pct: 100.0 * (std_bytes / thin_bytes - 1.0),
        saved_gb_per_user: (std_bytes - thin_bytes) / GB,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_sixty_percent_more_users() {
        let c = headline_comparison(H100_NODE_7B);
        // per-user KV: 67.2 GB -> 42.0 GB: exactly a 1.6x admission ratio
        // (the paper's "~60% more concurrent users"), 25.2 GB saved/user.
        assert!((c.saved_gb_per_user - 25.2).abs() < 0.1,
                "saved {}", c.saved_gb_per_user);
        assert!((c.gain_pct - 60.0).abs() < 0.5, "gain {}%", c.gain_pct);
        assert!(c.users_thin > c.users_standard);
    }

    #[test]
    fn integer_user_gain_tracks_ratio_at_scale() {
        // with many users, flooring noise vanishes and the realized integer
        // gain converges to the 1.6x byte ratio
        let hw = HardwareSpec { hbm_gb: 64_000.0, weights_gb: 14.0,
                                reserve_gb: 26.0 };
        let c = headline_comparison(hw);
        let realized =
            c.users_thin as f64 / c.users_standard.max(1) as f64;
        assert!((realized - 1.6).abs() < 0.01, "realized {realized}");
    }

    #[test]
    fn zero_when_weights_exceed_hbm() {
        let hw = HardwareSpec { hbm_gb: 10.0, weights_gb: 14.0, reserve_gb: 0.0 };
        assert_eq!(
            concurrent_users(hw, KvGeometry::mha(4096), 128_000, 32, 2.0),
            0
        );
    }
}
