//! Execution engine: runs the prefill/decode artifacts and owns the
//! physical cache storage.
//!
//! HLO executables are shape-specialized, so decode runs over a
//! two-axis artifact grid: *batch buckets* {1,2,4,8,16,32} × *context
//! tiers* (powers of two up to `max_seq`, see EXPERIMENTS.md). The engine
//! packs active sequences into a dense group arena `(L, B, N, KD/VD)`
//! where `B` is the current bucket and `N` the current tier — the
//! smallest exported arena length covering the longest live sequence
//! (with grow-on-demand / shrink-with-hysteresis, [`lanes::target_tier`]),
//! so arena memory and per-step attention work scale with live context,
//! not model max context.
//!
//! Lane assignment is an explicit [`LaneMap`] (`SeqId → lane`) — the
//! single source of truth for where a sequence's cache rows live — and
//! regroup is *incremental and lane-stable*: a retirement just vacates
//! its lane (zero copies; the hole is fed a dummy token until reused), a
//! join writes only the joining lane, and lanes move only when the bucket
//! or tier itself changes. `EngineMetrics::copyback_bytes` counts the
//! host bytes actually moved, next to the bytes the old full park/unpark
//! design would have moved for the same membership changes.
//!
//! Host↔device sync contract (EXPERIMENTS.md §Sync): the decode
//! artifacts return, besides the updated arenas, the per-step written
//! rows `(L, B, KD)`/`(L, B, VD)`. The engine scatters those into
//! `k_group`/`v_group`, keeping an **always-current host mirror** at
//! O(L·B·(KD+VD)) per step — so membership changes repack the mirror
//! directly and *never* download the full arenas
//! (`EngineMetrics::sync_download_bytes` stays 0). Uploads happen only on
//! join / bucket resize / tier switch (`sync_upload_bytes`); per-step
//! host traffic is independent of `max_seq`.
//!
//! Accounting contract with the scheduler: `rows(id)` reports the cache
//! rows physically written per sequence; the scheduler mirrors it into
//! `KvCacheManager::commit_rows` so the logical block tables and the
//! physical arena always agree, and both are freed on the same
//! retirement event (`Scheduler::free_seq` → `kv.release` +
//! `engine.drop_seq`).
//!
//! Prefill runs either monolithically ([`Engine::prefill`], one
//! `prefill_{cfg}_s{S}` call for the whole prompt) or **chunked**
//! ([`Engine::prefill_chunk`], resumable `prefill_{cfg}_c{C}` calls of C
//! prompt positions each, ISSUE 3): between chunks the partially filled
//! arenas stay parked as device literals and the host mirror accumulates
//! only the per-chunk delta rows, so the scheduler can interleave decode
//! rounds — and preempt a long document's ingestion at a chunk boundary —
//! without a long prompt ever stalling interactive lanes for its whole
//! length. Both paths park bit-identical rows (the parity tests in
//! rust/tests/serving_e2e.rs and python/tests/test_model.py).
//!
//! The *thin* K arena is the paper's saving made concrete: `KD =
//! n_kv_heads · d_qk_head` is 4x smaller for `servethin` than `servefull`
//! while `VD` is identical. The engine is head-geometry-aware through
//! exactly that contract (ISSUE 5): every arena, mirror, parked row,
//! delta scatter, repack, and byte gauge is sized by the manifest's
//! `k_cache_dims`/`v_cache_dims` — KV-head widths, never query-head
//! widths — so the GQA configs (`servegqa*`, 8q/2kv) shrink every cache
//! surface by the group factor with no engine special-casing, and the
//! group × rank × q8 composition reads off `arena_k_bytes` measured.
//!
//! KV quantization (ISSUE 4): at `KvQuant::Q8` every cache surface —
//! device arenas, cross-chunk carried literals, the delta-synced host
//! mirror, and parked rows — holds int8 codes plus one fp32 scale per
//! (layer, lane, position) row. Rows are quantized on write *inside* the
//! `_q8` artifacts (decode, prefill chunks) and host-side only when the
//! fp32 monolithic-prefill output parks
//! ([`crate::substrate::tensor::quantize_rows_q8`], same rounding as the
//! artifacts). Attention dequant is fused into the artifacts'
//! online-softmax loop, so the fp32 arena never exists anywhere. All the
//! repack/unpark/tier-switch machinery moves int8 bytes through
//! [`RowArena`] row copies, and every byte counter sizes by
//! [`ArenaSizing`] — 4x less arena payload, 4x less per-step row sync,
//! bounded logit error (asserted in rust/tests/serving_e2e.rs).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::coordinator::errors::EngineError;
use crate::coordinator::kvcache::{BlockId, ForkGrant};
use crate::coordinator::lanes::{self, LaneMap};
use crate::coordinator::metrics::{ArenaSizing, EngineMetrics};
use crate::coordinator::sampling::Sampler;
use crate::coordinator::sequence::{SeqId, Sequence};
use crate::runtime::client::{f32_slice_to_literal, i8_slice_to_literal,
                             literal_to_tensor, literal_to_vec_f32,
                             literal_to_vec_i8, Arg, Runtime};
use crate::runtime::manifest::{ConfigEntry, KvQuant};
use crate::runtime::params::ParamStore;
use crate::substrate::rng::Rng;
use crate::substrate::tensor::{RowArena, Tensor, TensorI32};

/// Per-sequence parked cache rows — stored at the engine's KV quant
/// (fp32 values, or int8 codes + per-row scales). The arenas hold only
/// the sequence's PRIVATE rows `[shared_rows, len)` as `(L, len -
/// shared_rows, D)` row-major; rows `[0, shared_rows)` live in the
/// shared prefix store ([`Engine::prefix_store`], ISSUE 8) and are
/// addressed through the sequence's [`PrefixRef`]. `shared_rows == 0`
/// (every sequence outside the sharing path) reduces to the legacy
/// full-copy park.
#[derive(Clone, Debug)]
struct Parked {
    len: usize,
    /// Rows held by shared prefix blocks, never by these arenas.
    shared_rows: usize,
    k: RowArena,
    v: RowArena,
}

/// One shared prefix block resident host-side: `block_tokens` rows per
/// layer, `(L, block_tokens, D)` row-major, at the engine's quant.
/// Immutable once published — CoW guarantees no sequence ever writes a
/// shared row again, so unpark can scatter these bytes into any lane of
/// any consumer without copies back.
#[derive(Clone, Debug)]
struct KvBlock {
    k: RowArena,
    v: RowArena,
}

/// A sequence's view into the shared prefix store: `blocks[f]` holds its
/// rows `[f·block_tokens, (f+1)·block_tokens)`; `rows` = `blocks.len() ·
/// block_tokens`. Mirrors the shared region of the sequence's
/// `KvCacheManager` block table (auditor-cross-checked).
#[derive(Clone, Debug)]
struct PrefixRef {
    blocks: Vec<BlockId>,
    rows: usize,
}

/// In-flight chunked prefill (ISSUE 3): the sequence's prompt has been
/// ingested up to `done` tokens. The partially filled `(L, S, KD/VD)`
/// arenas are carried across chunks as device literals (fed straight back
/// via `Arg::L`, never round-tripped through host tensors), and the host
/// mirror accumulates only the per-chunk delta rows `k_rows`/`v_rows` —
/// the prefill twin of the decode delta-sync contract, so chunked prefill
/// never downloads a full arena between chunks either. In q8 mode the
/// payload literals are int8 and each arena carries a second `(L, S)`
/// fp32 scale-plane literal (ISSUE 4).
struct ChunkProgress {
    done: usize,
    k_lit: xla::Literal,
    v_lit: xla::Literal,
    /// Scale-plane literals (q8 mode only).
    k_scale_lit: Option<xla::Literal>,
    v_scale_lit: Option<xla::Literal>,
    /// Host mirror of the prefill arenas, `L·S` rows of KD / VD,
    /// current up to row `done`; compacted into [`Parked`] on completion.
    k: RowArena,
    v: RowArena,
}

/// Everything a decode step can mutate before its execute call (a
/// regroup moves lanes, switches tiers, parks/unparks rows, and bumps
/// gauges). Cloned by `Engine::step_snapshot` only while a fault plan is
/// installed; `Engine::rollback_step` restores it wholesale, so a failed
/// step leaves no trace in the host mirror, `LaneMap`, or row accounting
/// (auditor-verified, see rust/tests/fault_props.rs).
struct StepSnapshot {
    lanes: LaneMap,
    tier: usize,
    k_group: RowArena,
    v_group: RowArena,
    parked: HashMap<SeqId, Parked>,
    rows: HashMap<SeqId, usize>,
    step_mass: HashMap<SeqId, Vec<f32>>,
    metrics: EngineMetrics,
}

/// Host-side image of an in-flight chunked prefill inside an
/// [`EngineCheckpoint`]: the progress counter plus the host mirrors.
/// The carried DEVICE literals are deliberately absent — the mirror is
/// current up to `done` (the delta-sync contract), so a restore rebuilds
/// them with one upload per arena, exactly like the first chunk did.
#[derive(Clone, Debug)]
struct ChunkCheckpoint {
    done: usize,
    k: RowArena,
    v: RowArena,
}

/// Everything needed to rebuild an [`Engine`]'s serving state from
/// nothing (ISSUE 9): the full-restore generalization of
/// [`StepSnapshot`]'s one-step rollback. Because the delta-synced host
/// mirror is always current, the checkpoint is a pure host-memory clone
/// — no device traffic to take one — and a restore re-uploads device
/// literals from the mirrors through the same paths a join/tier-switch
/// already uses. Sampler RNG state rides along, so replaying the rounds
/// after the checkpoint regenerates bit-exact tokens.
///
/// The checkpoint is engine-private state only; the scheduler pairs it
/// with its own queue/block-table image (`Scheduler::checkpoint`).
pub struct EngineCheckpoint {
    tier: usize,
    lanes: LaneMap,
    k_group: RowArena,
    v_group: RowArena,
    parked: HashMap<SeqId, Parked>,
    prefix_store: HashMap<BlockId, KvBlock>,
    prefix_of: HashMap<SeqId, PrefixRef>,
    block_tokens: usize,
    chunking: HashMap<SeqId, ChunkCheckpoint>,
    rows: HashMap<SeqId, usize>,
    evicted: HashMap<SeqId, usize>,
    rng: Rng,
    metrics: EngineMetrics,
}

impl EngineCheckpoint {
    /// Host bytes this checkpoint holds across every cache surface
    /// (group mirrors + parked rows + chunk mirrors + shared prefix
    /// blocks, payload + scale planes) — the `checkpoint_bytes` gauge.
    pub fn host_bytes(&self) -> usize {
        let arena = |k: &RowArena, v: &RowArena| {
            k.payload_bytes() + k.scale_bytes() + v.payload_bytes()
                + v.scale_bytes()
        };
        arena(&self.k_group, &self.v_group)
            + self.parked.values().map(|p| arena(&p.k, &p.v)).sum::<usize>()
            + self.chunking.values().map(|c| arena(&c.k, &c.v)).sum::<usize>()
            + self
                .prefix_store
                .values()
                .map(|b| arena(&b.k, &b.v))
                .sum::<usize>()
    }

    /// Sequences with in-flight chunked prefills at checkpoint time (the
    /// supervisor requeues these for resumption after a restore).
    pub fn chunking_ids(&self) -> Vec<SeqId> {
        let mut v: Vec<SeqId> = self.chunking.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Total generated-token rows accounted at checkpoint time — the
    /// baseline `replayed_tokens` is measured against.
    pub fn tracked_row_total(&self) -> usize {
        self.rows.values().sum()
    }
}

pub struct Engine<'rt> {
    rt: &'rt Runtime,
    pub cfg: ConfigEntry,
    /// Model weights (read-only once the engine is built — the param
    /// literals below are converted a single time; see EXPERIMENTS.md
    /// §Perf).
    pub params: ParamStore,
    pub pallas: bool,
    /// KV-cache element format (ISSUE 4): `Q8` serves from int8 arenas
    /// with per-row fp32 scales through the `_q8` artifact grid; `Fp32`
    /// is the legacy full-precision path. Fixed at construction — the
    /// arenas, parked rows, host mirrors, and device literals all carry
    /// this dtype.
    pub quant: KvQuant,
    pub sampler: Sampler,
    /// Force a fixed arena tier instead of auto-selecting the smallest
    /// covering one. `Some(cfg.max_seq)` reproduces the pre-tiering
    /// engine (every arena sized at max context) — the benchmark
    /// baseline.
    pub pin_tier: Option<usize>,
    rng: Rng,
    /// Pre-converted parameter literals (L3-opt-1: params never change at
    /// serve time, so the host->literal conversion happens once, not per
    /// step).
    param_lits: Vec<xla::Literal>,
    /// Steady-state cache literals (L3-opt-2: while lane assignment and
    /// tier cover the active set, the previous step's output caches are
    /// fed straight back without literal<->tensor round trips — including
    /// across zero-copy retirements). In q8 mode the payload literals are
    /// int8 and each arena carries a scale-plane literal alongside.
    k_lit: Option<xla::Literal>,
    v_lit: Option<xla::Literal>,
    k_scale_lit: Option<xla::Literal>,
    v_scale_lit: Option<xla::Literal>,
    // group state
    lanes: LaneMap,
    /// Current arena length N (context tier); 0 before the first group.
    tier: usize,
    /// Always-current host mirrors of the decode arenas (`L·B·N` rows of
    /// KD / VD at the engine's quant), delta-synced from the per-step
    /// `k_rows`/`v_rows` (+ scale) outputs.
    k_group: RowArena,
    v_group: RowArena,
    parked: HashMap<SeqId, Parked>,
    /// Shared prefix blocks resident host-side (ISSUE 8), keyed by the
    /// `KvCacheManager` block id. Populated by
    /// [`Engine::publish_prefix`] / [`Engine::fork_seq`] when a block
    /// becomes shared, drained by [`Engine::drop_blocks`] when the pool
    /// frees it — the physical twin of the refcounted block table.
    prefix_store: HashMap<BlockId, KvBlock>,
    /// Per-sequence shared-prefix view: which store blocks hold the
    /// sequence's leading rows.
    prefix_of: HashMap<SeqId, PrefixRef>,
    /// Rows per shared block — mirrors `KvCacheConfig::block_tokens`,
    /// installed by the scheduler ([`Engine::set_block_tokens`]); 0 means
    /// the sharing machinery is unused (standalone-engine paths).
    block_tokens: usize,
    /// In-flight chunked prefills (prompt partially ingested).
    chunking: HashMap<SeqId, ChunkProgress>,
    /// Cache rows actually written per live sequence (= tokens fed so
    /// far; for an in-flight chunked prefill, the chunked progress).
    /// Physical-side half of the unified accounting contract.
    rows: HashMap<SeqId, usize>,
    /// Per-sequence post-softmax attention mass over positions
    /// `0..len`, from the most recent decode step (the `attn_mass`
    /// output plane, mean over layers and heads). Feeds the eviction
    /// scorer (ISSUE 10). Absent until the sequence decodes once, or
    /// when the manifest predates the plane.
    step_mass: HashMap<SeqId, Vec<f32>>,
    /// Evicted-rows ledger: cache rows per sequence whose mirror K/V
    /// were zeroed by [`Engine::evict_rows`]. Rows stay "written" in
    /// `rows` accounting — the ledger is what lets the auditor accept
    /// committed rows whose blocks were legally evicted.
    evicted: HashMap<SeqId, usize>,
    /// Logits of the most recent completed prefill (monolithic or final
    /// chunk) — exposed for the chunked-vs-monolithic parity tests.
    last_prefill_logits: Option<Tensor>,
    /// Logits of the most recent decode step, `(B, vocab)` in LANE order
    /// — the quantized-vs-fp32 parity surface (serving_e2e, the
    /// quantized_decode_table error column). Stored by move, no extra
    /// copy.
    last_decode_logits: Option<Tensor>,
    pub metrics: EngineMetrics,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, cfg_name: &str, params: ParamStore,
               pallas: bool, sampler: Sampler, seed: u64) -> Result<Engine<'rt>> {
        Self::with_kv_quant(rt, cfg_name, params, pallas, sampler, seed,
                            KvQuant::Fp32)
    }

    /// Build an engine serving at the given KV quant mode. `Q8` requires
    /// the manifest's `kv_quant` axis to include it for this config (set
    /// by aot.py; legacy manifests are fp32-only and fail fast here).
    pub fn with_kv_quant(rt: &'rt Runtime, cfg_name: &str, params: ParamStore,
                         pallas: bool, sampler: Sampler, seed: u64,
                         quant: KvQuant) -> Result<Engine<'rt>> {
        let cfg = rt.manifest().config(cfg_name)?.clone();
        params.check_matches(&cfg)?;
        let exported = rt.manifest().kv_quants_for(cfg_name);
        if !exported.contains(&quant) {
            bail!(
                "kv quant {:?} not exported for {cfg_name} (available: \
                 {:?}) — re-run `make artifacts`",
                quant.name(),
                exported.iter().map(|q| q.name()).collect::<Vec<_>>()
            );
        }
        let param_lits = params
            .tensors
            .iter()
            .map(crate::runtime::client::tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        let (kd, vd) = (cfg.k_cache_dims, cfg.v_cache_dims);
        Ok(Engine {
            rt,
            cfg,
            params,
            pallas,
            quant,
            sampler,
            pin_tier: None,
            rng: Rng::new(seed),
            param_lits,
            k_lit: None,
            v_lit: None,
            k_scale_lit: None,
            v_scale_lit: None,
            lanes: LaneMap::new(),
            tier: 0,
            k_group: RowArena::zeros(quant, kd, 0),
            v_group: RowArena::zeros(quant, vd, 0),
            parked: HashMap::new(),
            prefix_store: HashMap::new(),
            prefix_of: HashMap::new(),
            block_tokens: 0,
            chunking: HashMap::new(),
            rows: HashMap::new(),
            step_mass: HashMap::new(),
            evicted: HashMap::new(),
            last_prefill_logits: None,
            last_decode_logits: None,
            metrics: EngineMetrics::default(),
        })
    }

    pub fn max_context(&self) -> usize {
        self.cfg.max_seq
    }

    pub fn max_prompt(&self) -> usize {
        self.rt.manifest().prefill_seq
    }

    /// Current arena length N (0 before the first decode group).
    pub fn current_tier(&self) -> usize {
        self.tier
    }

    /// Current decode bucket B / lane count (0 before the first group).
    pub fn current_bucket(&self) -> usize {
        self.lanes.bucket()
    }

    /// Cache rows physically written for `id` (0 if unknown). The
    /// scheduler mirrors this into the KV block accounting.
    pub fn rows(&self, id: SeqId) -> usize {
        self.rows.get(&id).copied().unwrap_or(0)
    }

    /// The lane a sequence currently decodes in, if it is grouped.
    pub fn lane_of(&self, id: SeqId) -> Option<usize> {
        self.lanes.lane_of(id)
    }

    /// Prompt tokens ingested so far by an in-flight chunked prefill
    /// (None once complete, or if never chunk-prefilled).
    pub fn prefill_progress(&self, id: SeqId) -> Option<usize> {
        self.chunking.get(&id).map(|p| p.done)
    }

    /// Chunk lengths available for this config (empty on pre-chunking
    /// manifests — chunked mode is then unavailable).
    pub fn chunk_sizes(&self) -> Vec<usize> {
        self.rt.manifest().chunks_for(&self.cfg.name)
    }

    /// Logits of the most recent completed prefill (monolithic or final
    /// chunk) — the chunked-vs-monolithic parity oracle.
    pub fn last_prefill_logits(&self) -> Option<&Tensor> {
        self.last_prefill_logits.as_ref()
    }

    /// Logits of the most recent decode step, `(B, vocab)` in lane order
    /// — the q8-vs-fp32 parity oracle (teacher-forced comparisons read
    /// this instead of re-deriving logits from sampled tokens).
    pub fn last_decode_logits(&self) -> Option<&Tensor> {
        self.last_decode_logits.as_ref()
    }

    /// The parked cache rows of a sequence that finished prefill but has
    /// not joined a decode lane yet, as fp32 VALUES: `(len, k, v)` with k
    /// `(L, len, KD)` and v `(L, len, VD)` row-major (dequantized in q8
    /// mode). Parity-test surface: chunked and monolithic prefill must
    /// park bit-identical rows in fp32 mode.
    pub fn parked_snapshot(&self, id: SeqId)
        -> Option<(usize, Vec<f32>, Vec<f32>)> {
        let p = self.parked.get(&id)?;
        if p.shared_rows == 0 {
            return Some((p.len, p.k.to_f32(), p.v.to_f32()));
        }
        // shared-prefix sequence: reassemble the full (L, len, D) view
        // from the store blocks + the private tail, so the parity oracle
        // is indifferent to where the rows physically live
        let (l, kd, vd) = (self.cfg.n_layers, self.cfg.k_cache_dims,
                           self.cfg.v_cache_dims);
        let bt = self.block_tokens;
        let priv_len = p.len - p.shared_rows;
        let mut k = RowArena::zeros(self.quant, kd, l * p.len);
        let mut v = RowArena::zeros(self.quant, vd, l * p.len);
        if let Some(pref) = self.prefix_of.get(&id) {
            for (f, bid) in pref.blocks.iter().enumerate() {
                let blk = self.prefix_store.get(bid)
                    .expect("prefix block of a parked sequence is resident");
                for li in 0..l {
                    k.copy_rows(li * p.len + f * bt, &blk.k, li * bt, bt);
                    v.copy_rows(li * p.len + f * bt, &blk.v, li * bt, bt);
                }
            }
        }
        for li in 0..l {
            k.copy_rows(li * p.len + p.shared_rows, &p.k, li * priv_len,
                        priv_len);
            v.copy_rows(li * p.len + p.shared_rows, &p.v, li * priv_len,
                        priv_len);
        }
        Some((p.len, k.to_f32(), v.to_f32()))
    }

    fn param_args(&self) -> Vec<Arg<'_>> {
        self.param_lits.iter().map(Arg::L).collect()
    }

    /// Dtype-aware byte sizing for every cache counter this engine
    /// reports (ISSUE 4 satellite: no hardcoded 4 bytes/element).
    fn sizing(&self) -> ArenaSizing {
        ArenaSizing {
            n_layers: self.cfg.n_layers,
            k_dims: self.cfg.k_cache_dims,
            v_dims: self.cfg.v_cache_dims,
            quant: self.quant,
        }
    }

    /// Host bytes that move when one cache row (K + V, all layers) moves
    /// — payload plus, in q8 mode, the per-row scales.
    fn row_bytes(&self) -> usize {
        self.sizing().row_bytes()
    }

    /// Upload a host arena as device literal(s): the payload literal and,
    /// in q8 mode, the fp32 scale-plane literal. `shape` is the payload's
    /// logical shape (its product must equal rows·d); the scale plane has
    /// the same shape minus the trailing dim.
    fn arena_literals(buf: &RowArena, shape: &[usize])
        -> Result<(xla::Literal, Option<xla::Literal>)> {
        debug_assert_eq!(shape.iter().product::<usize>(), buf.rows * buf.d);
        match buf.quant {
            KvQuant::Fp32 => Ok((f32_slice_to_literal(&buf.f, shape)?, None)),
            KvQuant::Q8 => {
                let payload = i8_slice_to_literal(&buf.q, shape)?;
                let scales = f32_slice_to_literal(
                    &buf.s, &shape[..shape.len() - 1])?;
                Ok((payload, Some(scales)))
            }
        }
    }

    /// THE designated path for downloading a full cache arena literal to
    /// host — it counts the bytes into `sync_download_bytes`, which the
    /// steady-churn regression test and bench_serving assert is 0. The
    /// delta-synced mirror removed every caller; if a future change needs
    /// an arena download again it must go through here (a bare
    /// `literal_to_tensor` on an arena is a review error), making the
    /// regression visible in the metric instead of silent.
    #[allow(dead_code)]
    fn download_arena(&mut self, lit: &xla::Literal) -> Result<Tensor> {
        let t = literal_to_tensor(lit)?;
        self.metrics.sync_download_bytes +=
            (t.data.len() * std::mem::size_of::<f32>()) as u64;
        Ok(t)
    }

    /// Bytes a delta-row download moved host-side: payload elements at
    /// the engine's quant width plus fp32 scale elements — the
    /// dtype-aware charge for `row_sync_bytes` (no hardcoded element
    /// sizes in the hot path; widths come from [`KvQuant`]).
    fn delta_sync_bytes(&self, payload_elems: usize, scale_elems: usize)
        -> u64 {
        (payload_elems * self.quant.elem_bytes()
         + scale_elems * std::mem::size_of::<f32>()) as u64
    }

    /// Per-request feasibility validation shared by both prefill paths.
    /// Failures are SequenceLocal and carry no injected payload, so the
    /// scheduler never retries them (the request is infeasible forever)
    /// and reports them as rejected, not quarantined.
    fn validate_prompt(&self, seq: &Sequence, op: &'static str)
        -> Result<(), EngineError> {
        let s = self.max_prompt();
        let p = seq.prompt.len();
        if p > s {
            return Err(EngineError::sequence_local(
                seq.id, op,
                anyhow::anyhow!("prompt {p} exceeds prefill bucket {s}")));
        }
        if p + seq.max_new > self.cfg.max_seq {
            return Err(EngineError::sequence_local(
                seq.id, op,
                anyhow::anyhow!(
                    "prompt {p} + max_new {} exceeds context {}",
                    seq.max_new, self.cfg.max_seq)));
        }
        Ok(())
    }

    /// Prefill a queued sequence: fill its cache rows, sample the first
    /// token. The sequence transitions to Decoding (or Finished if the
    /// first token ends it).
    ///
    /// Failure classification: the monolithic path mutates no engine
    /// state before its execute call (parking and sampling are
    /// post-execute), so a failed prefill is naturally transactional — no
    /// rollback needed. Injected faults classify per
    /// [`EngineError::from_runtime`], with corrupt output attributed to
    /// this sequence (its rows are the only ones the call writes).
    pub fn prefill(&mut self, seq: &mut Sequence)
        -> Result<(), EngineError> {
        self.validate_prompt(seq, "prefill")?;
        let id = seq.id;
        // A prefix hit (ISSUE 8) makes the adopted rows free: ingest only
        // the suffix through the resumable chunk artifacts (the chunk
        // path seeds its arenas from the shared blocks and starts at the
        // adopted row). The monolithic artifact computes every position
        // unconditionally, so it would throw the hit away.
        let adopted =
            self.prefix_of.get(&id).map(|p| p.rows).unwrap_or(0);
        if adopted > 0 && !self.pallas {
            if let Some(chunk) =
                self.chunk_sizes().iter().copied().max()
            {
                loop {
                    match self.prefill_chunk(seq, chunk) {
                        Ok(true) => return Ok(()),
                        Ok(false) => {}
                        Err(e) => return Err(e),
                    }
                }
            }
        }
        // no chunk artifacts exported (or pallas): full monolithic
        // compute; park_prefilled still stores only the private suffix
        self.prefill_inner(seq)
            .map_err(|e| EngineError::from_runtime("prefill", e, |_| Some(id)))
    }

    fn prefill_inner(&mut self, seq: &mut Sequence) -> Result<()> {
        let s = self.max_prompt();
        let p = seq.prompt.len();
        let mut toks = vec![0i32; s];
        toks[..p].copy_from_slice(&seq.prompt);
        let tokens = TensorI32::new(&[1, s], toks);
        let artifact = self.rt.manifest().prefill_name(&self.cfg.name, self.pallas);
        let t0 = std::time::Instant::now();
        let mut args = self.param_args();
        args.push(Arg::I(&tokens));
        args.push(Arg::ScalarI(p as i32));
        let outs = self.rt.execute(&artifact, &args)?;
        self.metrics.prefill.record(t0.elapsed());
        self.metrics.prefill_tokens += p as u64;
        let logits = literal_to_tensor(&outs[0])?; // (1, V)

        // Park rows 0..p straight from the output literals (L, S, KD/VD):
        // park_prefilled compacts each layer's first p rows in place and
        // truncates — no intermediate full-S Tensor and no second
        // full-arena copy.
        let k = outs[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("download k_cache: {e}"))?;
        let v = outs[2]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("download v_cache: {e}"))?;
        self.park_prefilled(seq, k, v, logits);
        Ok(())
    }

    /// Shared prefill epilogue for the MONOLITHIC (fp32-artifact) path:
    /// compact the `(L, S, D)` fp32 buffers' first `p` rows into parked
    /// row arenas — quantizing on write in q8 mode (the host-side twin of
    /// the q8 artifacts' quantize-on-write; same rounding, see
    /// `substrate::tensor::quantize_rows_q8`) — then finish through
    /// [`Engine::park_arenas`].
    fn park_prefilled(&mut self, seq: &mut Sequence, k: Vec<f32>,
                      v: Vec<f32>, logits: Tensor) {
        let s = self.max_prompt();
        let p = seq.prompt.len();
        let (l, kd, vd) = (self.cfg.n_layers, self.cfg.k_cache_dims,
                           self.cfg.v_cache_dims);
        // adopted prefix rows already live in the shared store — park
        // only the private suffix (identical bytes either way: the
        // monolithic compute of a shared prefix is bit-equal to the
        // donor's, but the shared copy is the addressable one)
        let shared = self.prefix_of.get(&seq.id).map(|pr| pr.rows)
            .unwrap_or(0);
        let priv_len = p - shared;
        let mut pk = RowArena::zeros(self.quant, kd, l * priv_len);
        let mut pv = RowArena::zeros(self.quant, vd, l * priv_len);
        for li in 0..l {
            pk.write_f32_rows(li * priv_len,
                              &k[(li * s + shared) * kd..(li * s + p) * kd],
                              priv_len);
            pv.write_f32_rows(li * priv_len,
                              &v[(li * s + shared) * vd..(li * s + p) * vd],
                              priv_len);
        }
        self.park_arenas(seq, pk, pv, logits);
    }

    /// THE single definition of how a finished prefill parks its rows and
    /// samples the first token, so the monolithic and chunked paths
    /// cannot drift apart (their bit-parity in fp32 mode is a tested
    /// contract): park the `L·p`-row arenas, record the physical rows,
    /// sample from `logits`, and transition the sequence to Decoding.
    fn park_arenas(&mut self, seq: &mut Sequence, pk: RowArena,
                   pv: RowArena, logits: Tensor) {
        let p = seq.prompt.len();
        let shared = self.prefix_of.get(&seq.id).map(|pr| pr.rows)
            .unwrap_or(0);
        debug_assert_eq!(pk.rows, self.cfg.n_layers * (p - shared));
        self.parked.insert(seq.id,
                           Parked { len: p, shared_rows: shared, k: pk,
                                    v: pv });
        self.rows.insert(seq.id, p);
        let tok = self.sampler.sample(&logits.data, &mut self.rng);
        self.last_prefill_logits = Some(logits);
        seq.state = crate::coordinator::sequence::SeqState::Decoding;
        seq.push_token(tok);
    }

    /// Advance a sequence's prefill by ONE chunk of `chunk` prompt
    /// positions (resumable; ISSUE 3). Returns `Ok(true)` when the whole
    /// prompt has been ingested — the first token is then sampled and the
    /// rows parked exactly as [`Engine::prefill`] would have parked them
    /// (bit-identical, see the parity tests). `Ok(false)` means the
    /// prompt is partially ingested: the arenas stay parked as device
    /// literals in [`ChunkProgress`] and the scheduler may interleave
    /// decode rounds (or higher-priority prefills) before the next chunk.
    ///
    /// `rows(id)` tracks the chunked progress, so the scheduler's
    /// `commit_rows` mirror stays exact mid-prefill too.
    ///
    /// Transactional contract: the only pre-execute mutations are the
    /// FIRST chunk's bookkeeping (fresh zero arenas + upload charge); a
    /// resumed chunk mutates nothing until its execute has succeeded and
    /// its outputs downloaded. Rollback is therefore exact and cheap —
    /// drop a freshly inserted progress entry and restore the upload
    /// counter — and a failed chunk leaves `rows(id)` / the host mirror
    /// exactly at the previous chunk boundary.
    pub fn prefill_chunk(&mut self, seq: &mut Sequence, chunk: usize)
        -> Result<bool, EngineError> {
        self.validate_prompt(seq, "prefill_chunk")?;
        if self.pallas {
            // the chunk artifacts are ref-only (aot.py exports no _pallas
            // chunk column); mixing ref chunked prefill with pallas decode
            // would silently break the chunked==monolithic parity
            // contract. A config error, not the request's fault — every
            // sequence would fail identically, so this is Fatal.
            return Err(EngineError::fatal(
                "prefill_chunk",
                anyhow::anyhow!(
                    "chunked prefill has no pallas artifact path — serve \
                     with --chunk-tokens 0 or without --pallas")));
        }
        let chunks = self.chunk_sizes();
        if !chunks.contains(&chunk) {
            return Err(EngineError::fatal(
                "prefill_chunk",
                anyhow::anyhow!(
                    "chunk {chunk} not exported (available: {chunks:?})")));
        }
        let id = seq.id;
        let fresh = !self.chunking.contains_key(&id);
        let upload_before = self.metrics.sync_upload_bytes;
        match self.prefill_chunk_inner(seq, chunk) {
            Ok(done) => Ok(done),
            Err(e) => {
                if fresh {
                    self.chunking.remove(&id);
                    self.rows.remove(&id);
                    self.metrics.sync_upload_bytes = upload_before;
                }
                Err(EngineError::from_runtime("prefill_chunk", e,
                                              |_| Some(id)))
            }
        }
    }

    fn prefill_chunk_inner(&mut self, seq: &mut Sequence, chunk: usize)
        -> Result<bool> {
        let s = self.max_prompt();
        let p = seq.prompt.len();
        let (l, kd, vd) = (self.cfg.n_layers, self.cfg.k_cache_dims,
                           self.cfg.v_cache_dims);
        if !self.chunking.contains_key(&seq.id) {
            // first chunk: fresh zero arenas, uploaded once as literals —
            // counted against the sync contract like any arena upload.
            // An adopted prefix (ISSUE 8) seeds rows [0, adopted) from
            // the shared store before the upload, and ingestion resumes
            // at the adopted row: the hit's rows are never recomputed,
            // never re-downloaded, and the chunk artifact's causal mask
            // attends to them like any previously ingested rows.
            let mut k = RowArena::zeros(self.quant, kd, l * s);
            let mut v = RowArena::zeros(self.quant, vd, l * s);
            let mut adopted = 0;
            if let Some(pref) = self.prefix_of.get(&seq.id) {
                let bt = self.block_tokens;
                for (f, bid) in pref.blocks.iter().enumerate() {
                    let blk = self.prefix_store.get(bid).ok_or_else(|| {
                        anyhow::anyhow!(
                            "seq {}: adopted prefix block {bid} is not \
                             resident in the prefix store",
                            seq.id)
                    })?;
                    for li in 0..l {
                        k.copy_rows(li * s + f * bt, &blk.k, li * bt, bt);
                        v.copy_rows(li * s + f * bt, &blk.v, li * bt, bt);
                    }
                }
                adopted = pref.rows;
            }
            let (k_lit, k_scale_lit) = Self::arena_literals(&k, &[l, s, kd])?;
            let (v_lit, v_scale_lit) = Self::arena_literals(&v, &[l, s, vd])?;
            self.metrics.sync_upload_bytes +=
                (k.payload_bytes() + k.scale_bytes() + v.payload_bytes()
                 + v.scale_bytes()) as u64;
            let prog = ChunkProgress {
                done: adopted, k_lit, v_lit, k_scale_lit, v_scale_lit, k, v,
            };
            self.chunking.insert(seq.id, prog);
            self.rows.insert(seq.id, adopted);
        }
        let start = self.chunking[&seq.id].done;
        debug_assert!(start < p, "chunk past end of prompt");
        let n_valid = chunk.min(p - start);
        let mut toks = vec![0i32; chunk];
        toks[..n_valid].copy_from_slice(&seq.prompt[start..start + n_valid]);
        let tokens = TensorI32::new(&[1, chunk], toks);
        let artifact = self.rt.manifest().prefill_chunk_name(
            &self.cfg.name, chunk, self.quant);
        let t0 = std::time::Instant::now();
        let outs = {
            let prog = &self.chunking[&seq.id];
            let mut args = self.param_args();
            args.push(Arg::L(&prog.k_lit));
            if let Some(ksl) = &prog.k_scale_lit {
                args.push(Arg::L(ksl));
            }
            args.push(Arg::L(&prog.v_lit));
            if let Some(vsl) = &prog.v_scale_lit {
                args.push(Arg::L(vsl));
            }
            args.push(Arg::I(&tokens));
            args.push(Arg::ScalarI(start as i32));
            args.push(Arg::ScalarI(p as i32));
            self.rt.execute(&artifact, &args)?
        };
        self.metrics.prefill.record(t0.elapsed());
        self.metrics.prefill_chunks += 1;
        self.metrics.prefill_tokens += n_valid as u64;
        let logits = literal_to_tensor(&outs[0])?; // (1, V)
        // download this chunk's delta rows, scatter them into the host
        // mirror at [start, start+n_valid), and keep the updated arena
        // literals for the next chunk. Output layouts:
        //   fp32: [logits, k_cache, v_cache, k_rows, v_rows]
        //   q8:   [logits, k_cache, k_scale, v_cache, v_scale,
        //          k_rows, k_row_scale, v_rows, v_row_scale]
        let mut outs = outs;
        match self.quant {
            KvQuant::Fp32 => {
                let k_rows = literal_to_vec_f32(&outs[3])?;
                let v_rows = literal_to_vec_f32(&outs[4])?;
                self.metrics.row_sync_bytes +=
                    self.delta_sync_bytes(k_rows.len() + v_rows.len(), 0);
                let v_lit = outs.remove(2);
                let k_lit = outs.remove(1);
                let prog =
                    self.chunking.get_mut(&seq.id).expect("chunk progress");
                prog.k_lit = k_lit;
                prog.v_lit = v_lit;
                for li in 0..l {
                    prog.k.write_f32_rows(
                        li * s + start,
                        &k_rows[li * chunk * kd..(li * chunk + n_valid) * kd],
                        n_valid);
                    prog.v.write_f32_rows(
                        li * s + start,
                        &v_rows[li * chunk * vd..(li * chunk + n_valid) * vd],
                        n_valid);
                }
            }
            KvQuant::Q8 => {
                let k_rows = literal_to_vec_i8(&outs[5])?;
                let k_row_s = literal_to_vec_f32(&outs[6])?;
                let v_rows = literal_to_vec_i8(&outs[7])?;
                let v_row_s = literal_to_vec_f32(&outs[8])?;
                self.metrics.row_sync_bytes += self.delta_sync_bytes(
                    k_rows.len() + v_rows.len(),
                    k_row_s.len() + v_row_s.len());
                let v_scale_lit = outs.remove(4);
                let v_lit = outs.remove(3);
                let k_scale_lit = outs.remove(2);
                let k_lit = outs.remove(1);
                let prog =
                    self.chunking.get_mut(&seq.id).expect("chunk progress");
                prog.k_lit = k_lit;
                prog.k_scale_lit = Some(k_scale_lit);
                prog.v_lit = v_lit;
                prog.v_scale_lit = Some(v_scale_lit);
                for li in 0..l {
                    prog.k.write_q8_rows(
                        li * s + start,
                        &k_rows[li * chunk * kd..(li * chunk + n_valid) * kd],
                        &k_row_s[li * chunk..li * chunk + n_valid],
                        n_valid);
                    prog.v.write_q8_rows(
                        li * s + start,
                        &v_rows[li * chunk * vd..(li * chunk + n_valid) * vd],
                        &v_row_s[li * chunk..li * chunk + n_valid],
                        n_valid);
                }
            }
        }
        let prog = self.chunking.get_mut(&seq.id).expect("chunk progress");
        prog.done = start + n_valid;
        self.rows.insert(seq.id, prog.done);
        if prog.done < p {
            return Ok(false);
        }
        // final chunk: the host mirror holds every prompt row — compact
        // the private rows per layer and park through the same epilogue
        // the monolithic prefill uses (adopted prefix rows stay in the
        // shared store; the parked arenas never duplicate them)
        let prog = self.chunking.remove(&seq.id).expect("chunk progress");
        let shared = self.prefix_of.get(&seq.id).map(|pr| pr.rows)
            .unwrap_or(0);
        let priv_len = p - shared;
        let mut pk = RowArena::zeros(self.quant, kd, l * priv_len);
        let mut pv = RowArena::zeros(self.quant, vd, l * priv_len);
        for li in 0..l {
            pk.copy_rows(li * priv_len, &prog.k, li * s + shared, priv_len);
            pv.copy_rows(li * priv_len, &prog.v, li * s + shared, priv_len);
        }
        self.park_arenas(seq, pk, pv, logits);
        Ok(true)
    }

    /// Bucket to repack into for `n` active lanes: minimal on first group
    /// and growth, sticky on shrink (see [`lanes::target_bucket`]).
    fn target_bucket(&self, n: usize) -> Result<usize> {
        lanes::target_bucket(
            &self.rt.manifest().decode_batches,
            n,
            self.lanes.bucket(),
        )
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no decode bucket >= {n} (max {:?})",
                self.rt.manifest().decode_batches.last()
            )
        })
    }

    /// Arena tier for the longest active sequence needing `need` rows:
    /// smallest covering tier on growth, sticky shrink with ~2x headroom
    /// (see [`lanes::target_tier`]); `pin_tier` overrides.
    fn target_tier(&self, need: usize) -> Result<usize> {
        if let Some(t) = self.pin_tier {
            if t < need {
                bail!("pinned tier {t} < required rows {need}");
            }
            return Ok(t);
        }
        let tiers = self.rt.manifest().tiers_for(&self.cfg.name);
        lanes::target_tier(&tiers, need, self.tier).ok_or_else(|| {
            anyhow::anyhow!("no decode tier >= {need} (tiers {tiers:?})")
        })
    }

    /// Write a parked sequence's rows into group lane `lane` (one
    /// contiguous row-range copy per layer per arena; dtype-preserving —
    /// q8 codes and scales move together).
    fn unpark_into(&mut self, id: SeqId, lane: usize) {
        let (l, n) = (self.cfg.n_layers, self.tier);
        let b = self.lanes.bucket();
        // shared prefix rows come from the store blocks; the lane mirror
        // gets a full private copy (decode artifacts address one dense
        // arena), but the parked/host dedup is preserved — the arena is
        // transient working state, freed rows move back private-only
        if let Some(pref) = self.prefix_of.get(&id) {
            let bt = self.block_tokens;
            for (f, bid) in pref.blocks.iter().enumerate() {
                let blk = self.prefix_store.get(bid)
                    .expect("unpark: adopted prefix block is resident");
                for li in 0..l {
                    self.k_group.copy_rows((li * b + lane) * n + f * bt,
                                           &blk.k, li * bt, bt);
                    self.v_group.copy_rows((li * b + lane) * n + f * bt,
                                           &blk.v, li * bt, bt);
                }
            }
        }
        let p = self.parked.get(&id).expect("unpark of unknown seq");
        let priv_len = p.len - p.shared_rows;
        for li in 0..l {
            self.k_group.copy_rows((li * b + lane) * n + p.shared_rows,
                                   &p.k, li * priv_len, priv_len);
            self.v_group.copy_rows((li * b + lane) * n + p.shared_rows,
                                   &p.v, li * priv_len, priv_len);
        }
    }

    /// Copy a lane's live rows from the (always-current) mirror back into
    /// the parked store.
    fn park_from(&mut self, id: SeqId, lane: usize, len: usize) {
        let (l, n) = (self.cfg.n_layers, self.tier);
        let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
        let b = self.lanes.bucket();
        // shared prefix rows are immutable (CoW) and still live in the
        // store — only the private tail copies back
        let shared = self.prefix_of.get(&id).map(|pr| pr.rows).unwrap_or(0);
        let priv_len = len - shared;
        let mut parked = Parked {
            len,
            shared_rows: shared,
            k: RowArena::zeros(self.quant, kd, l * priv_len),
            v: RowArena::zeros(self.quant, vd, l * priv_len),
        };
        for li in 0..l {
            parked.k.copy_rows(li * priv_len, &self.k_group,
                               (li * b + lane) * n + shared, priv_len);
            parked.v.copy_rows(li * priv_len, &self.v_group,
                               (li * b + lane) * n + shared, priv_len);
        }
        self.parked.insert(id, parked);
    }

    /// Incrementally repack the decode group to cover the `active`
    /// sequence ids at arena tier `tier`: stable sequences keep their
    /// lanes (zero copies), live leavers are parked, joiners are unparked
    /// into holes, and kept lanes move only on a bucket resize or tier
    /// switch (each copied once, directly between arenas — not the old
    /// park+unpark double copy). Operates entirely on the host mirror —
    /// no device downloads.
    fn regroup(&mut self, active: &[SeqId], tier: usize) -> Result<()> {
        let bucket = self.target_bucket(active.len())?;
        let plan = self.lanes.plan(active, bucket);
        let mut cost = lanes::copy_cost(
            &plan,
            |id| self.rows.get(&id).copied().unwrap_or(0),
            self.row_bytes(),
        );
        if tier != self.tier && !plan.resize {
            // a tier-only switch still copies every kept lane into the
            // newly sized arena
            let kept: u64 = plan
                .keep
                .iter()
                .map(|&(id, _, _)| {
                    self.rows.get(&id).copied().unwrap_or(0) as u64
                })
                .sum();
            cost.actual += kept * self.row_bytes() as u64;
        }
        // park live leavers while the mirror still holds their rows at
        // the old bucket/tier strides
        for &(id, lane) in &plan.leave {
            if let Some(&len) = self.rows.get(&id) {
                self.park_from(id, lane, len);
            }
            self.metrics.lane_leaves += 1;
        }
        if plan.resize || tier != self.tier {
            let l = self.cfg.n_layers;
            let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
            let (old_b, old_n) = (self.lanes.bucket(), self.tier);
            let old_k = std::mem::replace(
                &mut self.k_group,
                RowArena::zeros(self.quant, kd, l * bucket * tier));
            let old_v = std::mem::replace(
                &mut self.v_group,
                RowArena::zeros(self.quant, vd, l * bucket * tier));
            for &(id, from, to) in &plan.keep {
                let len = self.rows.get(&id).copied().unwrap_or(0);
                for li in 0..l {
                    self.k_group.copy_rows((li * bucket + to) * tier,
                                           &old_k,
                                           (li * old_b + from) * old_n,
                                           len);
                    self.v_group.copy_rows((li * bucket + to) * tier,
                                           &old_v,
                                           (li * old_b + from) * old_n,
                                           len);
                }
            }
            if tier != self.tier {
                self.metrics.tier_switches += 1;
            }
            self.tier = tier;
            let sizing = self.sizing();
            self.metrics.arena_bytes =
                sizing.arena_payload_bytes(bucket, tier) as u64;
            self.metrics.arena_scale_bytes =
                sizing.arena_scale_bytes(bucket, tier) as u64;
            self.metrics.arena_k_bytes =
                sizing.arena_k_payload_bytes(bucket, tier) as u64;
            self.metrics.arena_k_scale_bytes =
                sizing.arena_k_scale_bytes(bucket, tier) as u64;
            debug_assert_eq!(
                self.metrics.arena_bytes as usize,
                self.k_group.payload_bytes() + self.v_group.payload_bytes(),
                "ArenaSizing and RowArena disagree on arena payload"
            );
            debug_assert_eq!(
                self.metrics.arena_k_bytes as usize,
                self.k_group.payload_bytes(),
                "ArenaSizing and RowArena disagree on K payload"
            );
        }
        self.lanes.apply(&plan);
        for &(id, lane) in &plan.join {
            self.unpark_into(id, lane);
            // the arena is now the live copy; drop the parked snapshot
            self.parked.remove(&id);
            self.metrics.lane_joins += 1;
        }
        self.metrics.regroups += 1;
        self.metrics.copyback_bytes += cost.actual;
        self.metrics.copyback_bytes_full += cost.full_equiv;
        Ok(())
    }

    /// One continuous-batching decode step over the given active
    /// sequences. Samples and records one token per sequence, feeding
    /// each lane from the lane map (never from enumeration order — see
    /// the lane-misalignment regression tests).
    ///
    /// Transactional contract: while a fault plan is installed, the
    /// step-mutable bookkeeping (regroup can move lanes, switch tiers,
    /// park/unpark rows, and bump counters before the execute call) is
    /// snapshotted and rolled back wholesale on failure, so a failed step
    /// never leaves the host mirror, `LaneMap`, or row accounting
    /// divergent. The sampling RNG is consumed only AFTER a successful
    /// execute, so a rolled-back step leaves the token stream untouched
    /// and a retry reproduces the fault-free outputs bit-exactly.
    ///
    /// Failure classification ([`EngineError::from_runtime`]): injected
    /// corrupt output attributes to the sequence whose lane the fault
    /// hint names (SequenceLocal); injected exec/load faults are
    /// Transient; real runtime errors are Fatal.
    pub fn decode_step(&mut self, seqs: &mut [&mut Sequence])
        -> Result<(), EngineError> {
        if seqs.is_empty() {
            return Ok(());
        }
        for s in seqs.iter() {
            if s.len() >= self.cfg.max_seq {
                return Err(EngineError::sequence_local(
                    s.id, "decode_step",
                    anyhow::anyhow!("sequence {} exceeds context arena",
                                    s.id)));
            }
        }
        // Snapshot only while an injector is installed: without one, a
        // real execute failure escalates Fatal and aborts the trace, so
        // the per-step arena clone would be pure production overhead.
        let ids: Vec<SeqId> = seqs.iter().map(|s| s.id).collect();
        let snapshot = if self.rt.fault_injection_active() {
            Some(self.step_snapshot())
        } else {
            None
        };
        match self.decode_step_inner(seqs) {
            Ok(()) => Ok(()),
            Err(e) => {
                if let Some(snap) = snapshot {
                    self.rollback_step(snap);
                }
                Err(EngineError::from_runtime("decode_step", e, |hint| {
                    ids.get(hint as usize % ids.len().max(1)).copied()
                }))
            }
        }
    }

    /// The fallible body of [`Engine::decode_step`]: plain anyhow
    /// internals; rollback and classification live in the wrapper.
    fn decode_step_inner(&mut self, seqs: &mut [&mut Sequence])
        -> Result<()> {
        let active: Vec<SeqId> = seqs.iter().map(|s| s.id).collect();
        // rows the arena must hold: the longest sequence writes row
        // len-1 this step and attends to rows 0..len
        let need = seqs.iter().map(|s| s.len()).max()
            .expect("decode_step requires a non-empty active set");
        let tier = self.target_tier(need)?;
        let in_sync = self.k_lit.is_some()
            && tier == self.tier
            && self.lanes.live() == active.len()
            && active.iter().all(|&id| self.lanes.lane_of(id).is_some());
        if !in_sync {
            // the host mirror is always current (delta-synced every
            // step), so a membership change or tier switch repacks it
            // directly — there is no full-arena download here, only the
            // upload of the repacked arenas (payload + q8 scale planes)
            self.regroup(&active, tier)?;
            let l = self.cfg.n_layers;
            let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
            let (b, n) = (self.lanes.bucket(), self.tier);
            let (k_lit, k_scale_lit) =
                Self::arena_literals(&self.k_group, &[l, b, n, kd])?;
            let (v_lit, v_scale_lit) =
                Self::arena_literals(&self.v_group, &[l, b, n, vd])?;
            self.k_lit = Some(k_lit);
            self.k_scale_lit = k_scale_lit;
            self.v_lit = Some(v_lit);
            self.v_scale_lit = v_scale_lit;
            self.metrics.sync_upload_bytes +=
                (self.k_group.payload_bytes() + self.k_group.scale_bytes()
                 + self.v_group.payload_bytes() + self.v_group.scale_bytes())
                    as u64;
        }
        let b = self.lanes.bucket();
        let n = self.tier;

        // holes (vacated lanes) decode a dummy token at position 0; the
        // row they write is overwritten when a joiner reuses the lane
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for s in seqs.iter() {
            let lane = self.lanes.lane_of(s.id).expect("active seq has a lane");
            toks[lane] = s.last_token();
            pos[lane] = (s.len() - 1) as i32;
        }
        let tokens = TensorI32::new(&[b], toks);
        let positions = TensorI32::new(&[b], pos);
        let artifact = self.rt.manifest().decode_name(
            &self.cfg.name, b, n, self.pallas, self.quant);
        let t0 = std::time::Instant::now();
        let outs = {
            let mut args = self.param_args();
            args.push(Arg::L(self.k_lit.as_ref()
                .expect("decode arena literal uploaded before execution")));
            if let Some(ksl) = &self.k_scale_lit {
                args.push(Arg::L(ksl));
            }
            args.push(Arg::L(self.v_lit.as_ref()
                .expect("decode arena literal uploaded before execution")));
            if let Some(vsl) = &self.v_scale_lit {
                args.push(Arg::L(vsl));
            }
            args.push(Arg::I(&tokens));
            args.push(Arg::I(&positions));
            self.rt.execute(&artifact, &args)?
        };
        self.metrics.decode.record(t0.elapsed());
        self.metrics.decode_steps += 1;
        self.metrics.decode_tokens += seqs.len() as u64;
        self.metrics.occupancy_sum += seqs.len() as f64 / b as f64;
        *self.metrics.tier_steps.entry(n).or_insert(0) += 1;

        let logits = literal_to_tensor(&outs[0])?; // (B, V)
        let l = self.cfg.n_layers;
        let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
        // download this step's delta rows, keep the updated arena
        // literals for the next step, scatter into the host mirror.
        // Output layouts:
        //   fp32: [logits, k_cache, v_cache, k_rows, v_rows, attn_mass]
        //   q8:   [logits, k_cache, k_scale, v_cache, v_scale,
        //          k_rows, k_row_scale, v_rows, v_row_scale, attn_mass]
        // attn_mass (B, N) is the per-row post-softmax weight plane the
        // eviction scorer consumes; legacy manifests lack it, so its
        // parse is gated on the artifact's declared outputs.
        let has_mass = self
            .rt
            .manifest()
            .artifact(&artifact)
            .map(|a| a.has_output("attn_mass"))
            .unwrap_or(false);
        let mut mass: Option<Vec<f32>> = None;
        let mut outs = outs;
        match self.quant {
            KvQuant::Fp32 => {
                let k_rows = literal_to_vec_f32(&outs[3])?; // (L, B, KD)
                let v_rows = literal_to_vec_f32(&outs[4])?; // (L, B, VD)
                if has_mass {
                    mass = Some(literal_to_vec_f32(&outs[5])?); // (B, N)
                }
                self.v_lit = Some(outs.remove(2));
                self.k_lit = Some(outs.remove(1));
                self.metrics.row_sync_bytes +=
                    self.delta_sync_bytes(k_rows.len() + v_rows.len(), 0);
                for s in seqs.iter() {
                    let lane =
                        self.lanes.lane_of(s.id).expect("active seq lane");
                    let row = s.len() - 1;
                    for li in 0..l {
                        let src = li * b + lane;
                        self.k_group.write_f32_rows(
                            (li * b + lane) * n + row,
                            &k_rows[src * kd..(src + 1) * kd], 1);
                        self.v_group.write_f32_rows(
                            (li * b + lane) * n + row,
                            &v_rows[src * vd..(src + 1) * vd], 1);
                    }
                }
            }
            KvQuant::Q8 => {
                let k_rows = literal_to_vec_i8(&outs[5])?; // (L, B, KD)
                let k_row_s = literal_to_vec_f32(&outs[6])?; // (L, B)
                let v_rows = literal_to_vec_i8(&outs[7])?; // (L, B, VD)
                let v_row_s = literal_to_vec_f32(&outs[8])?; // (L, B)
                if has_mass {
                    mass = Some(literal_to_vec_f32(&outs[9])?); // (B, N)
                }
                self.v_scale_lit = Some(outs.remove(4));
                self.v_lit = Some(outs.remove(3));
                self.k_scale_lit = Some(outs.remove(2));
                self.k_lit = Some(outs.remove(1));
                self.metrics.row_sync_bytes += self.delta_sync_bytes(
                    k_rows.len() + v_rows.len(),
                    k_row_s.len() + v_row_s.len());
                for s in seqs.iter() {
                    let lane =
                        self.lanes.lane_of(s.id).expect("active seq lane");
                    let row = s.len() - 1;
                    for li in 0..l {
                        let src = li * b + lane;
                        self.k_group.write_q8_rows(
                            (li * b + lane) * n + row,
                            &k_rows[src * kd..(src + 1) * kd],
                            &k_row_s[src..src + 1], 1);
                        self.v_group.write_q8_rows(
                            (li * b + lane) * n + row,
                            &v_rows[src * vd..(src + 1) * vd],
                            &v_row_s[src..src + 1], 1);
                    }
                }
            }
        }
        if let Some(m) = &mass {
            self.metrics.mass_sync_bytes += (m.len() * 4) as u64;
        }
        let v = self.cfg.vocab;
        for s in seqs.iter_mut() {
            let lane = self.lanes.lane_of(s.id).expect("active seq has a lane");
            // this step wrote the row for the token we just fed
            self.rows.insert(s.id, s.len());
            if let Some(m) = &mass {
                // positions past len are exactly zero in the plane; keep
                // only the sequence's own prefix so the scorer never sees
                // another lane's mass
                self.step_mass
                    .insert(s.id, m[lane * n..lane * n + s.len()].to_vec());
            }
            let row = &logits.data[lane * v..(lane + 1) * v];
            let tok = self.sampler.sample(row, &mut self.rng);
            s.push_token(tok);
        }
        self.last_decode_logits = Some(logits);
        // finished sequences vacate their lanes via drop_seq (zero-copy)
        Ok(())
    }

    /// Install the shared-prefix block geometry (rows per block). Set by
    /// the scheduler from its `KvCacheConfig` before any sharing call.
    pub fn set_block_tokens(&mut self, block_tokens: usize) {
        debug_assert!(self.prefix_store.is_empty(),
                      "block geometry change with resident prefix blocks");
        self.block_tokens = block_tokens;
    }

    /// Tokens per shared prefix block (0 = sharing unused).
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Rows sequence `id` addresses through shared prefix blocks.
    pub fn prefix_rows(&self, id: SeqId) -> usize {
        self.prefix_of.get(&id).map(|p| p.rows).unwrap_or(0)
    }

    /// Shared prefix blocks currently resident host-side, in id order
    /// (auditor surface: must equal the refcounted pool's shared set).
    pub fn resident_prefix_blocks(&self) -> Vec<BlockId> {
        let mut v: Vec<BlockId> = self.prefix_store.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Point a not-yet-prefilled sequence at the shared blocks its
    /// admission matched (`KvCacheManager::allocate_prompt`): rows `[0,
    /// rows)` are adopted from the store and skipped by both prefill
    /// paths. Fails if a matched block is not resident — that would mean
    /// the logical block table and the physical store diverged.
    pub fn adopt_prefix(&mut self, id: SeqId, blocks: &[BlockId],
                        rows: usize) -> Result<()> {
        if rows == 0 {
            return Ok(());
        }
        if self.block_tokens == 0
            || blocks.len() * self.block_tokens != rows
        {
            bail!(
                "adopt_prefix: {} blocks x {} tokens != {rows} rows",
                blocks.len(),
                self.block_tokens
            );
        }
        for bid in blocks {
            if !self.prefix_store.contains_key(bid) {
                bail!("adopt_prefix: block {bid} is not resident");
            }
        }
        self.prefix_of.insert(
            id,
            PrefixRef { blocks: blocks.to_vec(), rows });
        Ok(())
    }

    /// Publish a freshly sealed prefix (`KvCacheManager::seal_prefix`)
    /// while the donor is still parked: move the newly registered blocks'
    /// rows out of the donor's private arenas into the shared store and
    /// shrink the parked copy to the private tail — from here on those
    /// rows exist host-side exactly once, however many sequences adopt
    /// them.
    pub fn publish_prefix(&mut self, id: SeqId,
                          newly: &[(usize, BlockId)], blocks: &[BlockId],
                          shared_rows: usize) -> Result<()> {
        if shared_rows == 0 {
            return Ok(());
        }
        let (l, kd, vd, bt) = (self.cfg.n_layers, self.cfg.k_cache_dims,
                               self.cfg.v_cache_dims, self.block_tokens);
        let p = self.parked.get(&id).ok_or_else(|| {
            anyhow::anyhow!("publish_prefix: seq {id} is not parked")
        })?;
        let r0 = p.shared_rows;
        if shared_rows < r0 || shared_rows > p.len || bt == 0
            || shared_rows % bt != 0
        {
            bail!(
                "publish_prefix: shared rows {shared_rows} invalid (had \
                 {r0}, len {}, block {bt})",
                p.len
            );
        }
        let priv_old = p.len - r0;
        for &(f, bid) in newly {
            if f * bt < r0 || (f + 1) * bt > shared_rows {
                bail!("publish_prefix: block index {f} outside ({r0}..\
                       {shared_rows})");
            }
            let mut blk = KvBlock {
                k: RowArena::zeros(self.quant, kd, l * bt),
                v: RowArena::zeros(self.quant, vd, l * bt),
            };
            for li in 0..l {
                blk.k.copy_rows(li * bt, &p.k,
                                li * priv_old + (f * bt - r0), bt);
                blk.v.copy_rows(li * bt, &p.v,
                                li * priv_old + (f * bt - r0), bt);
            }
            self.prefix_store.insert(bid, blk);
        }
        // shrink the parked copy: rows [r0, shared_rows) now live in the
        // store, only [shared_rows, len) stays private
        if shared_rows > r0 {
            let p = self.parked.get(&id).expect("parked checked above");
            let priv_new = p.len - shared_rows;
            let mut pk = RowArena::zeros(self.quant, kd, l * priv_new);
            let mut pv = RowArena::zeros(self.quant, vd, l * priv_new);
            for li in 0..l {
                pk.copy_rows(li * priv_new, &p.k,
                             li * priv_old + (shared_rows - r0), priv_new);
                pv.copy_rows(li * priv_new, &p.v,
                             li * priv_old + (shared_rows - r0), priv_new);
            }
            let len = p.len;
            self.parked.insert(
                id, Parked { len, shared_rows, k: pk, v: pv });
        }
        self.prefix_of.insert(
            id,
            PrefixRef { blocks: blocks.to_vec(), rows: shared_rows });
        Ok(())
    }

    /// Materialize a copy-on-write fork (`KvCacheManager::fork`): publish
    /// the parent's newly shared full blocks, point both sequences at
    /// them, and copy ONLY the parent's partial tail rows into the
    /// child's private parked storage (the `cow_split`). The child parks
    /// with the parent's full written history and decodes independently
    /// from its next step on.
    pub fn fork_seq(&mut self, parent: SeqId, child: SeqId,
                    grant: &ForkGrant) -> Result<()> {
        let (l, kd, vd, bt) = (self.cfg.n_layers, self.cfg.k_cache_dims,
                               self.cfg.v_cache_dims, self.block_tokens);
        let w = self.rows(parent);
        if bt == 0 || grant.shared_rows > w || grant.shared_rows % bt != 0 {
            bail!(
                "fork_seq: grant rows {} invalid for parent rows {w} \
                 (block {bt})",
                grant.shared_rows
            );
        }
        let priv_len = w - grant.shared_rows;
        let mut pk = RowArena::zeros(self.quant, kd, l * priv_len);
        let mut pv = RowArena::zeros(self.quant, vd, l * priv_len);
        if let Some(lane) = self.lanes.lane_of(parent) {
            // parent decodes in a lane: the mirror holds all its rows
            let (b, n) = (self.lanes.bucket(), self.tier);
            for &(f, bid) in &grant.published {
                let mut blk = KvBlock {
                    k: RowArena::zeros(self.quant, kd, l * bt),
                    v: RowArena::zeros(self.quant, vd, l * bt),
                };
                for li in 0..l {
                    blk.k.copy_rows(li * bt, &self.k_group,
                                    (li * b + lane) * n + f * bt, bt);
                    blk.v.copy_rows(li * bt, &self.v_group,
                                    (li * b + lane) * n + f * bt, bt);
                }
                self.prefix_store.insert(bid, blk);
            }
            for li in 0..l {
                pk.copy_rows(li * priv_len, &self.k_group,
                             (li * b + lane) * n + grant.shared_rows,
                             priv_len);
                pv.copy_rows(li * priv_len, &self.v_group,
                             (li * b + lane) * n + grant.shared_rows,
                             priv_len);
            }
        } else {
            // parked parent: its arenas hold rows [r0, w)
            let pp = self.parked.get(&parent).ok_or_else(|| {
                anyhow::anyhow!(
                    "fork_seq: parent {parent} has neither lane nor park")
            })?;
            let r0 = pp.shared_rows;
            let priv_old = pp.len - r0;
            for &(f, bid) in &grant.published {
                if f * bt < r0 {
                    bail!("fork_seq: published block {f} already shared");
                }
                let mut blk = KvBlock {
                    k: RowArena::zeros(self.quant, kd, l * bt),
                    v: RowArena::zeros(self.quant, vd, l * bt),
                };
                for li in 0..l {
                    blk.k.copy_rows(li * bt, &pp.k,
                                    li * priv_old + (f * bt - r0), bt);
                    blk.v.copy_rows(li * bt, &pp.v,
                                    li * priv_old + (f * bt - r0), bt);
                }
                self.prefix_store.insert(bid, blk);
            }
            let pp = self.parked.get(&parent).expect("parked checked");
            for li in 0..l {
                pk.copy_rows(li * priv_len, &pp.k,
                             li * priv_old + (grant.shared_rows - r0),
                             priv_len);
                pv.copy_rows(li * priv_len, &pp.v,
                             li * priv_old + (grant.shared_rows - r0),
                             priv_len);
            }
            // the parent's parked copy shrinks to its new private tail
            if grant.shared_rows > r0 {
                let len = pp.len;
                let priv_new = len - grant.shared_rows;
                let mut nk = RowArena::zeros(self.quant, kd, l * priv_new);
                let mut nv = RowArena::zeros(self.quant, vd, l * priv_new);
                for li in 0..l {
                    nk.copy_rows(li * priv_new, &pp.k,
                                 li * priv_old + (grant.shared_rows - r0),
                                 priv_new);
                    nv.copy_rows(li * priv_new, &pp.v,
                                 li * priv_old + (grant.shared_rows - r0),
                                 priv_new);
                }
                self.parked.insert(
                    parent,
                    Parked { len, shared_rows: grant.shared_rows, k: nk,
                             v: nv });
            }
        }
        let pref = PrefixRef {
            blocks: grant.shared_blocks.clone(),
            rows: grant.shared_rows,
        };
        self.prefix_of.insert(parent, pref.clone());
        self.prefix_of.insert(child, pref);
        self.parked.insert(
            child,
            Parked { len: w, shared_rows: grant.shared_rows, k: pk, v: pv });
        self.rows.insert(child, w);
        Ok(())
    }

    /// Drop freed blocks from the shared prefix store. Fed by the
    /// scheduler with `KvCacheManager::release`'s freed list, so a block
    /// leaves the store on exactly the event that frees it in the pool.
    pub fn drop_blocks(&mut self, blocks: &[BlockId]) {
        for bid in blocks {
            self.prefix_store.remove(bid);
        }
    }

    /// Does the loaded artifact grid export the per-row `attn_mass`
    /// plane on its decode artifacts? Probed on the smallest decode
    /// artifact of the active config/quant — the grid auditor keeps the
    /// plane all-or-nothing across the grid. Score-based eviction
    /// policies (a2sf/tova) refuse to start without it.
    pub fn supports_attn_mass(&self) -> bool {
        let m = self.rt.manifest();
        let b = match m.decode_batches.first() {
            Some(&b) => b,
            None => return false,
        };
        let n = match m.tiers_for(&self.cfg.name).first() {
            Some(&n) => n,
            None => return false,
        };
        let name = m.decode_name(&self.cfg.name, b, n, self.pallas,
                                 self.quant);
        m.artifact(&name)
            .map(|a| a.has_output("attn_mass"))
            .unwrap_or(false)
    }

    /// Post-softmax attention mass over positions `0..len` from the
    /// most recent decode step of `id` (mean over layers and heads), or
    /// `None` before the first decode step / on a legacy manifest.
    pub fn step_attn_mass(&self, id: SeqId) -> Option<&[f32]> {
        self.step_mass.get(&id).map(|v| v.as_slice())
    }

    /// Rows of `id` evicted so far (the evicted-rows ledger).
    pub fn evicted_rows_of(&self, id: SeqId) -> usize {
        self.evicted.get(&id).copied().unwrap_or(0)
    }

    /// Physically evict `count` cache rows of `id` starting at position
    /// `start`: the host-mirror K/V rows are zeroed in place and the
    /// carried device literals are dropped, so the next decode step
    /// re-uploads the edited arenas (charged to `sync_upload_bytes` via
    /// the regroup path — nothing is ever downloaded). A zeroed key
    /// scores 0 pre-softmax and a zeroed value contributes nothing to
    /// the output: the positions stay addressable (the one `pos` input
    /// drives rope, write index, and causal mask together, so rows
    /// cannot be masked out or compacted away) but carry no content.
    ///
    /// Row accounting is untouched — the rows remain "written"; the
    /// evicted-rows ledger records them so the auditor can reconcile
    /// committed rows against live blocks.
    pub fn evict_rows(&mut self, id: SeqId, start: usize, count: usize)
        -> Result<()> {
        if count == 0 {
            return Ok(());
        }
        let rows = *self.rows.get(&id).ok_or_else(|| {
            anyhow::anyhow!("evict_rows: seq {id} has no row accounting")
        })?;
        anyhow::ensure!(
            start + count <= rows,
            "evict_rows: seq {id} rows [{start}, {}) exceed written {rows}",
            start + count
        );
        let lane = self.lanes.lane_of(id).ok_or_else(|| {
            anyhow::anyhow!("evict_rows: seq {id} holds no decode lane")
        })?;
        let (b, n) = (self.lanes.bucket(), self.tier);
        let (l, kd, vd) = (self.cfg.n_layers, self.cfg.k_cache_dims,
                           self.cfg.v_cache_dims);
        let zk = vec![0f32; count * kd];
        let zv = vec![0f32; count * vd];
        for li in 0..l {
            let base = (li * b + lane) * n + start;
            self.k_group.write_f32_rows(base, &zk, count);
            self.v_group.write_f32_rows(base, &zv, count);
        }
        self.k_lit = None;
        self.k_scale_lit = None;
        self.v_lit = None;
        self.v_scale_lit = None;
        *self.evicted.entry(id).or_insert(0) += count;
        self.metrics.eviction.evicted_rows += count as u64;
        Ok(())
    }

    /// Forget a sequence's cache storage. If it held a lane, the lane
    /// becomes a hole — no bytes move, no regroup is scheduled; survivors
    /// keep decoding from their existing lanes.
    pub fn drop_seq(&mut self, id: SeqId) {
        self.parked.remove(&id);
        self.chunking.remove(&id); // cancel an in-flight chunked prefill
        self.prefix_of.remove(&id);
        self.rows.remove(&id);
        self.step_mass.remove(&id);
        self.evicted.remove(&id);
        if self.lanes.remove(id) {
            self.metrics.lane_leaves += 1;
            // what the old full park/unpark design would have copied for
            // this retirement: every survivor out and back in
            let survivors: u64 = self
                .lanes
                .ids()
                .map(|sid| self.rows.get(&sid).copied().unwrap_or(0) as u64)
                .sum();
            let full = 2 * survivors * self.row_bytes() as u64;
            self.metrics.copyback_bytes_full += full;
        }
    }

    /// Bytes of host cache storage currently parked (diagnostics) —
    /// completed-prefill rows, in-flight chunked-prefill mirrors, and
    /// shared prefix blocks (each counted ONCE however many sequences
    /// adopt it — the dedup is visible right here), payload + scale
    /// planes at the engine's quant.
    pub fn parked_bytes(&self) -> usize {
        let arena = |k: &RowArena, v: &RowArena| {
            k.payload_bytes() + k.scale_bytes() + v.payload_bytes()
                + v.scale_bytes()
        };
        let parked: usize =
            self.parked.values().map(|p| arena(&p.k, &p.v)).sum();
        let chunking: usize =
            self.chunking.values().map(|p| arena(&p.k, &p.v)).sum();
        let shared: usize =
            self.prefix_store.values().map(|blk| arena(&blk.k, &blk.v)).sum();
        parked + chunking + shared
    }

    /// Sequences currently holding a decode lane, in lane order.
    pub fn live_ids(&self) -> Vec<SeqId> {
        self.lanes.ids().collect()
    }

    /// Every sequence with physically written cache rows, `(id, rows)`
    /// in id order — the physical-side half of the accounting contract,
    /// exposed for the engine auditor's cross-check against
    /// [`crate::coordinator::kvcache::KvCacheManager`].
    pub fn tracked_rows(&self) -> Vec<(SeqId, usize)> {
        let mut v: Vec<(SeqId, usize)> =
            self.rows.iter().map(|(&id, &r)| (id, r)).collect();
        v.sort_unstable();
        v
    }

    /// Internal-consistency audit over every private cache surface
    /// (LaneMap ↔ RowArena ↔ ArenaSizing ↔ metrics gauges). Returns one
    /// message per violated invariant; empty == consistent. Run by the
    /// [`crate::analysis::auditor::EngineAuditor`] after every scheduler
    /// step in debug / `audit`-feature builds.
    pub fn invariant_violations(&self) -> Vec<String> {
        let mut out = Vec::new();
        let mut violate = |msg: String| out.push(msg);

        // lane bijection (the PR 1 bug class)
        if let Err(e) = self.lanes.check() {
            violate(format!("LaneMap: {e}"));
        }

        // group arenas: storage shapes and tier-sized row counts
        let (l, b, n) = (self.cfg.n_layers, self.lanes.bucket(), self.tier);
        for (label, arena, d) in [
            ("k_group", &self.k_group, self.cfg.k_cache_dims),
            ("v_group", &self.v_group, self.cfg.v_cache_dims),
        ] {
            if let Err(e) = arena.check() {
                violate(format!("{label}: {e}"));
            }
            if arena.d != d {
                violate(format!("{label}: row width {} != manifest {d}",
                                arena.d));
            }
            if arena.rows != l * b * n {
                violate(format!(
                    "{label}: {} rows != L·B·N = {l}·{b}·{n}", arena.rows));
            }
        }

        // measured arena bytes == ArenaSizing prediction == gauges
        if b > 0 {
            let sizing = self.sizing();
            let payload =
                self.k_group.payload_bytes() + self.v_group.payload_bytes();
            let scales =
                self.k_group.scale_bytes() + self.v_group.scale_bytes();
            if payload != sizing.arena_payload_bytes(b, n) {
                violate(format!(
                    "arena payload {payload} != ArenaSizing prediction {}",
                    sizing.arena_payload_bytes(b, n)));
            }
            if scales != sizing.arena_scale_bytes(b, n) {
                violate(format!(
                    "arena scales {scales} != ArenaSizing prediction {}",
                    sizing.arena_scale_bytes(b, n)));
            }
            if self.metrics.arena_bytes as usize != payload {
                violate(format!(
                    "arena_bytes gauge {} != measured payload {payload}",
                    self.metrics.arena_bytes));
            }
            if self.metrics.arena_k_bytes as usize
                != self.k_group.payload_bytes()
            {
                violate(format!(
                    "arena_k_bytes gauge {} != measured K payload {}",
                    self.metrics.arena_k_bytes,
                    self.k_group.payload_bytes()));
            }
            if !self.rt.manifest().decode_batches.contains(&b) {
                violate(format!("bucket {b} is not an exported bucket"));
            }
        }
        if n > 0 && self.pin_tier.is_none()
            && !self.rt.manifest().tiers_for(&self.cfg.name).contains(&n)
        {
            violate(format!("tier {n} is not an exported tier"));
        }

        // every grouped sequence has a row count that fits its lane
        for id in self.lanes.ids() {
            match self.rows.get(&id) {
                None => violate(format!(
                    "seq {id} holds a lane but has no row accounting")),
                Some(&r) if r > n => violate(format!(
                    "seq {id}: {r} rows exceed arena tier {n}")),
                Some(_) => {}
            }
        }

        // parked rows: accounting matches storage, storage is well-formed
        // (the arenas hold only the private rows past the shared prefix)
        for (&id, p) in &self.parked {
            if self.rows.get(&id) != Some(&p.len) {
                violate(format!(
                    "parked seq {id}: rows {:?} != parked len {}",
                    self.rows.get(&id), p.len));
            }
            if p.shared_rows > p.len {
                violate(format!(
                    "parked seq {id}: shared rows {} exceed len {}",
                    p.shared_rows, p.len));
            }
            if p.shared_rows
                != self.prefix_of.get(&id).map(|pr| pr.rows).unwrap_or(0)
            {
                violate(format!(
                    "parked seq {id}: shared rows {} != prefix view {:?}",
                    p.shared_rows,
                    self.prefix_of.get(&id).map(|pr| pr.rows)));
            }
            let priv_len = p.len.saturating_sub(p.shared_rows);
            for (label, arena) in [("k", &p.k), ("v", &p.v)] {
                if let Err(e) = arena.check() {
                    violate(format!("parked seq {id} {label}: {e}"));
                }
                if arena.rows != l * priv_len {
                    violate(format!(
                        "parked seq {id} {label}: {} rows != L·private = \
                         {l}·{priv_len}",
                        arena.rows));
                }
            }
            if self.lanes.lane_of(id).is_some() {
                violate(format!("seq {id} is parked AND holds a lane"));
            }
        }

        // shared prefix store (ISSUE 8): every adopted view points at
        // resident, block-shaped storage; every resident block is
        // adopted by someone (an orphan block is a leaked publish)
        let bt = self.block_tokens;
        for (&id, pref) in &self.prefix_of {
            if bt == 0 || pref.blocks.len() * bt != pref.rows {
                violate(format!(
                    "seq {id}: prefix view {} blocks x {bt} != {} rows",
                    pref.blocks.len(), pref.rows));
            }
            if self.lanes.lane_of(id).is_none()
                && !self.parked.contains_key(&id)
                && !self.chunking.contains_key(&id)
            {
                violate(format!(
                    "seq {id} has a prefix view but no cache storage"));
            }
            for bid in &pref.blocks {
                match self.prefix_store.get(bid) {
                    None => violate(format!(
                        "seq {id}: adopted block {bid} is not resident")),
                    Some(blk) => {
                        for (label, arena, d) in [
                            ("k", &blk.k, self.cfg.k_cache_dims),
                            ("v", &blk.v, self.cfg.v_cache_dims),
                        ] {
                            if let Err(e) = arena.check() {
                                violate(format!(
                                    "prefix block {bid} {label}: {e}"));
                            }
                            if arena.rows != l * bt || arena.d != d {
                                violate(format!(
                                    "prefix block {bid} {label}: \
                                     {}x{} != L·bt = {l}·{bt} x {d}",
                                    arena.rows, arena.d));
                            }
                        }
                    }
                }
            }
        }
        for &bid in self.prefix_store.keys() {
            if !self
                .prefix_of
                .values()
                .any(|pref| pref.blocks.contains(&bid))
            {
                violate(format!(
                    "prefix block {bid} is resident but no sequence \
                     adopts it (leaked publish)"));
            }
        }

        // in-flight chunked prefills: mirrors span the prefill arena
        let s = self.rt.manifest().prefill_seq;
        for (&id, c) in &self.chunking {
            if self.rows.get(&id) != Some(&c.done) {
                violate(format!(
                    "chunking seq {id}: rows {:?} != done {}",
                    self.rows.get(&id), c.done));
            }
            for (label, arena) in [("k", &c.k), ("v", &c.v)] {
                if let Err(e) = arena.check() {
                    violate(format!("chunking seq {id} {label}: {e}"));
                }
                if arena.rows != l * s {
                    violate(format!(
                        "chunking seq {id} {label}: {} rows != L·S = \
                         {l}·{s}",
                        arena.rows));
                }
            }
        }

        // row accounting covers only live sequences (lane, parked, or
        // chunking) — an orphan entry is a leaked retirement
        for (&id, _) in &self.rows {
            if self.lanes.lane_of(id).is_none()
                && !self.parked.contains_key(&id)
                && !self.chunking.contains_key(&id)
            {
                violate(format!(
                    "seq {id} has row accounting but no cache storage"));
            }
        }
        out
    }

    /// Snapshot the step-mutable bookkeeping (see [`StepSnapshot`]).
    fn step_snapshot(&self) -> StepSnapshot {
        StepSnapshot {
            lanes: self.lanes.clone(),
            tier: self.tier,
            k_group: self.k_group.clone(),
            v_group: self.v_group.clone(),
            parked: self.parked.clone(),
            rows: self.rows.clone(),
            step_mass: self.step_mass.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Restore a pre-step snapshot after a failed decode step. The
    /// carried device literals may already reflect the rolled-back
    /// regroup, so they are dropped: the next step detects the missing
    /// literal and re-uploads from the restored (always-current) host
    /// mirror. Nothing downloaded from the device survives a failed step
    /// — a corrupt output literal can never reach the mirror.
    fn rollback_step(&mut self, snap: StepSnapshot) {
        self.lanes = snap.lanes;
        self.tier = snap.tier;
        self.k_group = snap.k_group;
        self.v_group = snap.v_group;
        self.parked = snap.parked;
        self.rows = snap.rows;
        self.step_mass = snap.step_mass;
        self.metrics = snap.metrics;
        self.k_lit = None;
        self.k_scale_lit = None;
        self.v_lit = None;
        self.v_scale_lit = None;
    }

    /// Capture a full-restore checkpoint of every step-mutable surface
    /// (see [`EngineCheckpoint`]). Pure host-memory clone: the device
    /// literals are NOT captured — the host mirrors are always current,
    /// so they are rebuilt on restore.
    pub fn checkpoint(&self) -> EngineCheckpoint {
        EngineCheckpoint {
            tier: self.tier,
            lanes: self.lanes.clone(),
            k_group: self.k_group.clone(),
            v_group: self.v_group.clone(),
            parked: self.parked.clone(),
            prefix_store: self.prefix_store.clone(),
            prefix_of: self.prefix_of.clone(),
            block_tokens: self.block_tokens,
            chunking: self
                .chunking
                .iter()
                .map(|(&id, c)| {
                    (id, ChunkCheckpoint {
                        done: c.done,
                        k: c.k.clone(),
                        v: c.v.clone(),
                    })
                })
                .collect(),
            rows: self.rows.clone(),
            evicted: self.evicted.clone(),
            rng: self.rng.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Rebuild this engine's serving state from a checkpoint — the warm
    /// half of a supervisor restart (ISSUE 9). Works on a FRESH engine
    /// (built from the same manifest/config/params) or on a poisoned one
    /// being recycled:
    ///
    /// - host surfaces (lanes, mirrors, parked/chunking rows, shared
    ///   prefix store, row accounting, RNG, metrics) are restored by
    ///   clone;
    /// - the decode arena literals are dropped, NOT rebuilt here — the
    ///   next decode step detects the missing literal and re-uploads
    ///   from the restored mirror (the same path `rollback_step` and
    ///   every join/tier-switch already uses);
    /// - in-flight chunked prefills DO rebuild their carried literals
    ///   eagerly (from mirrors current up to `done`), charged to
    ///   `sync_upload_bytes` exactly like a first chunk's upload. Rows
    ///   past `done` hold zeros instead of the dead engine's bytes, but
    ///   the chunk artifacts' causal/start masking never reads them.
    ///
    /// Restoring the RNG alongside the mirrors is what makes post-restore
    /// replay bit-exact: the sampler RNG is a pure function of (seed,
    /// consumption), both captured here.
    pub fn restore(&mut self, ck: &EngineCheckpoint) -> Result<()> {
        self.tier = ck.tier;
        self.lanes = ck.lanes.clone();
        self.k_group = ck.k_group.clone();
        self.v_group = ck.v_group.clone();
        self.parked = ck.parked.clone();
        self.prefix_store = ck.prefix_store.clone();
        self.prefix_of = ck.prefix_of.clone();
        self.block_tokens = ck.block_tokens;
        self.rows = ck.rows.clone();
        self.evicted = ck.evicted.clone();
        // per-step mass is transient telemetry: the next decode step
        // repopulates it, and the eviction scorer tolerates its absence
        self.step_mass.clear();
        self.rng = ck.rng.clone();
        self.metrics = ck.metrics.clone();
        self.k_lit = None;
        self.k_scale_lit = None;
        self.v_lit = None;
        self.v_scale_lit = None;
        self.last_prefill_logits = None;
        self.last_decode_logits = None;
        let s = self.max_prompt();
        let (l, kd, vd) = (self.cfg.n_layers, self.cfg.k_cache_dims,
                           self.cfg.v_cache_dims);
        self.chunking.clear();
        for (&id, c) in &ck.chunking {
            let (k_lit, k_scale_lit) =
                Self::arena_literals(&c.k, &[l, s, kd])?;
            let (v_lit, v_scale_lit) =
                Self::arena_literals(&c.v, &[l, s, vd])?;
            self.metrics.sync_upload_bytes +=
                (c.k.payload_bytes() + c.k.scale_bytes()
                 + c.v.payload_bytes() + c.v.scale_bytes()) as u64;
            self.chunking.insert(id, ChunkProgress {
                done: c.done,
                k_lit,
                v_lit,
                k_scale_lit,
                v_scale_lit,
                k: c.k.clone(),
                v: c.v.clone(),
            });
        }
        Ok(())
    }

    /// Mirror the runtime's injected-fault counter into the metrics
    /// block: the runtime owns the injector, the engine owns the report.
    /// Called by the scheduler after every round.
    pub fn sync_fault_metrics(&mut self) {
        self.metrics.faults_injected = self.rt.faults_injected();
    }

    /// FNV-1a digest over every logical host cache surface — lane
    /// assignment, tier, group mirrors, parked rows, chunked-prefill
    /// mirrors, and row accounting. Two engines with equal fingerprints
    /// hold byte-equal host state; the fault property tests assert a
    /// failed step leaves the fingerprint exactly where it was.
    pub fn state_fingerprint(&self) -> u64 {
        struct Fnv(u64);
        impl Fnv {
            fn write(&mut self, bytes: &[u8]) {
                for &b in bytes {
                    self.0 ^= u64::from(b);
                    self.0 = self.0.wrapping_mul(0x100_0000_01b3);
                }
            }
            fn u64(&mut self, x: u64) {
                self.write(&x.to_le_bytes());
            }
            fn arena(&mut self, a: &RowArena) {
                self.u64(a.rows as u64);
                self.u64(a.d as u64);
                self.u64(a.quant.elem_bytes() as u64);
                for &x in &a.f {
                    self.write(&x.to_bits().to_le_bytes());
                }
                for &x in &a.q {
                    self.write(&[x as u8]);
                }
                for &x in &a.s {
                    self.write(&x.to_bits().to_le_bytes());
                }
            }
        }
        let mut h = Fnv(0xcbf2_9ce4_8422_2325);
        h.u64(self.tier as u64);
        h.u64(self.lanes.bucket() as u64);
        let mut lane_ids: Vec<SeqId> = self.lanes.ids().collect();
        lane_ids.sort_unstable();
        for id in lane_ids {
            h.u64(id);
            h.u64(self.lanes.lane_of(id).map_or(u64::MAX, |l| l as u64));
        }
        h.arena(&self.k_group);
        h.arena(&self.v_group);
        let mut parked_ids: Vec<SeqId> =
            self.parked.keys().copied().collect();
        parked_ids.sort_unstable();
        for id in parked_ids {
            let p = &self.parked[&id];
            h.u64(id);
            h.u64(p.len as u64);
            h.u64(p.shared_rows as u64);
            h.arena(&p.k);
            h.arena(&p.v);
        }
        let mut block_ids: Vec<BlockId> =
            self.prefix_store.keys().copied().collect();
        block_ids.sort_unstable();
        for bid in block_ids {
            let blk = &self.prefix_store[&bid];
            h.u64(bid as u64);
            h.arena(&blk.k);
            h.arena(&blk.v);
        }
        let mut pref_ids: Vec<SeqId> =
            self.prefix_of.keys().copied().collect();
        pref_ids.sort_unstable();
        for id in pref_ids {
            let pref = &self.prefix_of[&id];
            h.u64(id);
            h.u64(pref.rows as u64);
            for &bid in &pref.blocks {
                h.u64(bid as u64);
            }
        }
        let mut chunk_ids: Vec<SeqId> =
            self.chunking.keys().copied().collect();
        chunk_ids.sort_unstable();
        for id in chunk_ids {
            let c = &self.chunking[&id];
            h.u64(id);
            h.u64(c.done as u64);
            h.arena(&c.k);
            h.arena(&c.v);
        }
        for (id, r) in self.tracked_rows() {
            h.u64(id);
            h.u64(r as u64);
        }
        h.0
    }
}

#[cfg(test)]
mod tests {
    // Engine behaviour against real artifacts is covered by
    // rust/tests/serving_e2e.rs; lane assignment, repack planning, and
    // bucket/tier selection are unit tested in crate::coordinator::lanes.

    #[test]
    fn bucket_selection_logic() {
        // mirror of target_bucket's growth search, without a Runtime
        let buckets = [1usize, 2, 4, 8, 16, 32];
        let pick = |n: usize| buckets.iter().copied().find(|&b| b >= n);
        assert_eq!(pick(1), Some(1));
        assert_eq!(pick(3), Some(4));
        assert_eq!(pick(8), Some(8));
        assert_eq!(pick(33), None);
    }
}
