//! Execution engine: runs the prefill/decode artifacts and owns the
//! physical cache storage.
//!
//! HLO executables are shape-specialized, so decode runs over *batch
//! buckets* {1,2,4,8,16,32}; the engine keeps the active sequences packed
//! into a dense group arena `(L, B, N, KD/VD)` matching the current bucket
//! and "parks" per-sequence cache rows host-side when membership changes.
//! In steady state (no joins/leaves) the previous step's output caches are
//! fed straight back in — no repacking.
//!
//! The *thin* K arena is the paper's saving made concrete: `KD =
//! n_kv_heads · d_qk_head` is 4x smaller for `servethin` than `servefull`
//! while `VD` is identical.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::coordinator::metrics::EngineMetrics;
use crate::coordinator::sampling::Sampler;
use crate::coordinator::sequence::{SeqId, Sequence};
use crate::runtime::client::{literal_to_tensor, Arg, Runtime};
use crate::runtime::manifest::ConfigEntry;
use crate::runtime::params::ParamStore;
use crate::substrate::rng::Rng;
use crate::substrate::tensor::{Tensor, TensorI32};

/// Per-sequence parked cache rows: `(L, len, D)` row-major.
#[derive(Clone, Debug)]
struct Parked {
    len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct Engine<'rt> {
    rt: &'rt Runtime,
    pub cfg: ConfigEntry,
    /// Model weights (read-only once the engine is built — the param
    /// literals below are converted a single time; see §Perf).
    pub params: ParamStore,
    pub pallas: bool,
    pub sampler: Sampler,
    rng: Rng,
    /// Pre-converted parameter literals (L3-opt-1: params never change at
    /// serve time, so the host->literal conversion happens once, not per
    /// step).
    param_lits: Vec<xla::Literal>,
    /// Steady-state cache literals (L3-opt-2: while group membership is
    /// unchanged, the previous step's output caches are fed straight back
    /// without literal<->tensor round trips).
    k_lit: Option<xla::Literal>,
    v_lit: Option<xla::Literal>,
    // group state
    lanes: Vec<Option<SeqId>>,
    k_group: Tensor,
    v_group: Tensor,
    parked: HashMap<SeqId, Parked>,
    /// Cache rows actually written per live sequence (= tokens fed so far).
    rows: HashMap<SeqId, usize>,
    pub metrics: EngineMetrics,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, cfg_name: &str, params: ParamStore,
               pallas: bool, sampler: Sampler, seed: u64) -> Result<Engine<'rt>> {
        let cfg = rt.manifest().config(cfg_name)?.clone();
        params.check_matches(&cfg)?;
        let param_lits = params
            .tensors
            .iter()
            .map(crate::runtime::client::tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(Engine {
            rt,
            cfg,
            params,
            pallas,
            sampler,
            rng: Rng::new(seed),
            param_lits,
            k_lit: None,
            v_lit: None,
            lanes: Vec::new(),
            k_group: Tensor::zeros(&[0]),
            v_group: Tensor::zeros(&[0]),
            parked: HashMap::new(),
            rows: HashMap::new(),
            metrics: EngineMetrics::default(),
        })
    }

    pub fn max_context(&self) -> usize {
        self.cfg.max_seq
    }

    pub fn max_prompt(&self) -> usize {
        self.rt.manifest().prefill_seq
    }

    fn param_args(&self) -> Vec<Arg<'_>> {
        self.param_lits.iter().map(Arg::L).collect()
    }

    /// Prefill a queued sequence: fill its cache rows, sample the first
    /// token. The sequence transitions to Decoding (or Finished if the
    /// first token ends it).
    pub fn prefill(&mut self, seq: &mut Sequence) -> Result<()> {
        let s = self.max_prompt();
        let p = seq.prompt.len();
        if p > s {
            bail!("prompt {p} exceeds prefill bucket {s}");
        }
        if p + seq.max_new > self.cfg.max_seq {
            bail!(
                "prompt {p} + max_new {} exceeds context {}",
                seq.max_new, self.cfg.max_seq
            );
        }
        let mut toks = vec![0i32; s];
        toks[..p].copy_from_slice(&seq.prompt);
        let tokens = TensorI32::new(&[1, s], toks);
        let artifact = self.rt.manifest().prefill_name(&self.cfg.name, self.pallas);
        let t0 = std::time::Instant::now();
        let mut args = self.param_args();
        args.push(Arg::I(&tokens));
        args.push(Arg::ScalarI(p as i32));
        let outs = self.rt.execute(&artifact, &args)?;
        self.metrics.prefill.record(t0.elapsed());
        self.metrics.prefill_tokens += p as u64;
        let logits = literal_to_tensor(&outs[0])?; // (1, V)
        let kc = literal_to_tensor(&outs[1])?; // (L, S, KD)
        let vc = literal_to_tensor(&outs[2])?; // (L, S, VD)

        // park rows 0..p
        let (l, kd, vd) = (self.cfg.n_layers, self.cfg.k_cache_dims,
                           self.cfg.v_cache_dims);
        let mut parked = Parked {
            len: p,
            k: vec![0.0; l * p * kd],
            v: vec![0.0; l * p * vd],
        };
        for li in 0..l {
            let ksrc = &kc.data[li * s * kd..(li * s + p) * kd];
            parked.k[li * p * kd..(li + 1) * p * kd].copy_from_slice(ksrc);
            let vsrc = &vc.data[li * s * vd..(li * s + p) * vd];
            parked.v[li * p * vd..(li + 1) * p * vd].copy_from_slice(vsrc);
        }
        self.parked.insert(seq.id, parked);
        self.rows.insert(seq.id, p);

        let tok = self.sampler.sample(&logits.data, &mut self.rng);
        seq.state = crate::coordinator::sequence::SeqState::Decoding;
        seq.push_token(tok);
        Ok(())
    }

    /// Smallest exported decode bucket that fits `n` lanes.
    fn bucket_for(&self, n: usize) -> Result<usize> {
        self.rt
            .manifest()
            .decode_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no decode bucket >= {n} (max {:?})",
                    self.rt.manifest().decode_batches.last()
                )
            })
    }

    /// Write a parked sequence's rows into group lane `lane`.
    fn unpark_into(&mut self, id: SeqId, lane: usize) {
        let (l, n) = (self.cfg.n_layers, self.cfg.max_seq);
        let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
        let b = self.lanes.len();
        let p = self.parked.get(&id).expect("unpark of unknown seq");
        for li in 0..l {
            for t in 0..p.len {
                let gk = ((li * b + lane) * n + t) * kd;
                self.k_group.data[gk..gk + kd].copy_from_slice(
                    &p.k[(li * p.len + t) * kd..(li * p.len + t + 1) * kd]);
                let gv = ((li * b + lane) * n + t) * vd;
                self.v_group.data[gv..gv + vd].copy_from_slice(
                    &p.v[(li * p.len + t) * vd..(li * p.len + t + 1) * vd]);
            }
        }
    }

    /// Copy a lane's live rows back into the parked store.
    fn park_from(&mut self, id: SeqId, lane: usize, len: usize) {
        let (l, n) = (self.cfg.n_layers, self.cfg.max_seq);
        let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
        let b = self.lanes.len();
        let mut parked = Parked {
            len,
            k: vec![0.0; l * len * kd],
            v: vec![0.0; l * len * vd],
        };
        for li in 0..l {
            for t in 0..len {
                let gk = ((li * b + lane) * n + t) * kd;
                parked.k[(li * len + t) * kd..(li * len + t + 1) * kd]
                    .copy_from_slice(&self.k_group.data[gk..gk + kd]);
                let gv = ((li * b + lane) * n + t) * vd;
                parked.v[(li * len + t) * vd..(li * len + t + 1) * vd]
                    .copy_from_slice(&self.v_group.data[gv..gv + vd]);
            }
        }
        self.parked.insert(id, parked);
    }

    /// Re-pack the decode group to hold exactly the `active` sequence ids
    /// (in order), parking every current member's live rows first so no
    /// cache state is lost on membership changes (including preemption).
    fn regroup(&mut self, active: &[SeqId]) -> Result<()> {
        let current: Vec<SeqId> = self.lanes.iter().flatten().copied().collect();
        if current == active && !self.lanes.is_empty() {
            return Ok(());
        }
        // park all current members (their latest rows live in the group)
        let to_park: Vec<(SeqId, usize)> = self
            .lanes
            .iter()
            .enumerate()
            .filter_map(|(lane, s)| s.map(|id| (id, lane)))
            .collect();
        for (id, lane) in to_park {
            if let Some(&rows) = self.rows.get(&id) {
                self.park_from(id, lane, rows);
            }
        }
        // build the new group
        let bucket = self.bucket_for(active.len())?;
        let (l, n) = (self.cfg.n_layers, self.cfg.max_seq);
        let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
        self.lanes = vec![None; bucket];
        self.k_group = Tensor::zeros(&[l, bucket, n, kd]);
        self.v_group = Tensor::zeros(&[l, bucket, n, vd]);
        for (lane, &id) in active.iter().enumerate() {
            self.lanes[lane] = Some(id);
            self.unpark_into(id, lane);
        }
        self.metrics.regroups += 1;
        Ok(())
    }

    /// One continuous-batching decode step over the given active
    /// sequences. Samples and records one token per sequence.
    pub fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()> {
        if seqs.is_empty() {
            return Ok(());
        }
        for s in seqs.iter() {
            if s.len() >= self.cfg.max_seq {
                bail!("sequence {} exceeds context arena", s.id);
            }
        }
        let active: Vec<SeqId> = seqs.iter().map(|s| s.id).collect();
        let current: Vec<SeqId> =
            self.lanes.iter().flatten().copied().collect();
        if current != active || self.k_lit.is_none() {
            // materialize the latest cache state for parking, then repack
            if let (Some(kl), Some(vl)) = (self.k_lit.take(), self.v_lit.take())
            {
                self.k_group = literal_to_tensor(&kl)?;
                self.v_group = literal_to_tensor(&vl)?;
            }
            self.regroup(&active)?;
            self.k_lit = Some(crate::runtime::client::tensor_to_literal(
                &self.k_group)?);
            self.v_lit = Some(crate::runtime::client::tensor_to_literal(
                &self.v_group)?);
        }
        let b = self.lanes.len();

        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for (lane, s) in seqs.iter().enumerate() {
            toks[lane] = s.last_token();
            pos[lane] = (s.len() - 1) as i32;
        }
        let tokens = TensorI32::new(&[b], toks);
        let positions = TensorI32::new(&[b], pos);
        let artifact =
            self.rt.manifest().decode_name(&self.cfg.name, b, self.pallas);
        let t0 = std::time::Instant::now();
        let outs = {
            let mut args = self.param_args();
            args.push(Arg::L(self.k_lit.as_ref().unwrap()));
            args.push(Arg::L(self.v_lit.as_ref().unwrap()));
            args.push(Arg::I(&tokens));
            args.push(Arg::I(&positions));
            self.rt.execute(&artifact, &args)?
        };
        self.metrics.decode.record(t0.elapsed());
        self.metrics.decode_steps += 1;
        self.metrics.decode_tokens += seqs.len() as u64;
        self.metrics.occupancy_sum += seqs.len() as f64 / b as f64;

        let logits = literal_to_tensor(&outs[0])?; // (B, V)
        let mut outs = outs;
        self.v_lit = Some(outs.remove(2));
        self.k_lit = Some(outs.remove(1));
        let v = self.cfg.vocab;
        for (lane, s) in seqs.iter_mut().enumerate() {
            // this step wrote the row for the token we just fed
            self.rows.insert(s.id, s.len());
            let row = &logits.data[lane * v..(lane + 1) * v];
            let tok = self.sampler.sample(row, &mut self.rng);
            s.push_token(tok);
        }
        // finished sequences leave the group on the next regroup
        Ok(())
    }

    /// Forget a sequence's cache storage.
    pub fn drop_seq(&mut self, id: SeqId) {
        self.parked.remove(&id);
        self.rows.remove(&id);
        // group tensors must be re-materialized from the literals on the
        // next decode (membership changed)
        for lane in self.lanes.iter_mut() {
            if *lane == Some(id) {
                *lane = None;
            }
        }
    }

    /// Bytes of host cache storage currently parked (diagnostics).
    pub fn parked_bytes(&self) -> usize {
        self.parked
            .values()
            .map(|p| (p.k.len() + p.v.len()) * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine behaviour against real artifacts is covered by
    // rust/tests/serving_e2e.rs; here we test the pure helpers.

    #[test]
    fn bucket_selection_logic() {
        // mirror of bucket_for's search, without a Runtime
        let buckets = [1usize, 2, 4, 8, 16, 32];
        let pick = |n: usize| buckets.iter().copied().find(|&b| b >= n);
        assert_eq!(pick(1), Some(1));
        assert_eq!(pick(3), Some(4));
        assert_eq!(pick(8), Some(8));
        assert_eq!(pick(33), None);
    }
}
