//! Execution engine: runs the prefill/decode artifacts and owns the
//! physical cache storage.
//!
//! HLO executables are shape-specialized, so decode runs over a
//! two-axis artifact grid: *batch buckets* {1,2,4,8,16,32} × *context
//! tiers* (powers of two up to `max_seq`, see EXPERIMENTS.md). The engine
//! packs active sequences into a dense group arena `(L, B, N, KD/VD)`
//! where `B` is the current bucket and `N` the current tier — the
//! smallest exported arena length covering the longest live sequence
//! (with grow-on-demand / shrink-with-hysteresis, [`lanes::target_tier`]),
//! so arena memory and per-step attention work scale with live context,
//! not model max context.
//!
//! Lane assignment is an explicit [`LaneMap`] (`SeqId → lane`) — the
//! single source of truth for where a sequence's cache rows live — and
//! regroup is *incremental and lane-stable*: a retirement just vacates
//! its lane (zero copies; the hole is fed a dummy token until reused), a
//! join writes only the joining lane, and lanes move only when the bucket
//! or tier itself changes. `EngineMetrics::copyback_bytes` counts the
//! host bytes actually moved, next to the bytes the old full park/unpark
//! design would have moved for the same membership changes.
//!
//! Host↔device sync contract (EXPERIMENTS.md §Sync): the decode
//! artifacts return, besides the updated arenas, the per-step written
//! rows `(L, B, KD)`/`(L, B, VD)`. The engine scatters those into
//! `k_group`/`v_group`, keeping an **always-current host mirror** at
//! O(L·B·(KD+VD)) per step — so membership changes repack the mirror
//! directly and *never* download the full arenas
//! (`EngineMetrics::sync_download_bytes` stays 0). Uploads happen only on
//! join / bucket resize / tier switch (`sync_upload_bytes`); per-step
//! host traffic is independent of `max_seq`.
//!
//! Accounting contract with the scheduler: `rows(id)` reports the cache
//! rows physically written per sequence; the scheduler mirrors it into
//! `KvCacheManager::commit_rows` so the logical block tables and the
//! physical arena always agree, and both are freed on the same
//! retirement event (`Scheduler::free_seq` → `kv.release` +
//! `engine.drop_seq`).
//!
//! Prefill runs either monolithically ([`Engine::prefill`], one
//! `prefill_{cfg}_s{S}` call for the whole prompt) or **chunked**
//! ([`Engine::prefill_chunk`], resumable `prefill_{cfg}_c{C}` calls of C
//! prompt positions each, ISSUE 3): between chunks the partially filled
//! arenas stay parked as device literals and the host mirror accumulates
//! only the per-chunk delta rows, so the scheduler can interleave decode
//! rounds — and preempt a long document's ingestion at a chunk boundary —
//! without a long prompt ever stalling interactive lanes for its whole
//! length. Both paths park bit-identical rows (the parity tests in
//! rust/tests/serving_e2e.rs and python/tests/test_model.py).
//!
//! The *thin* K arena is the paper's saving made concrete: `KD =
//! n_kv_heads · d_qk_head` is 4x smaller for `servethin` than `servefull`
//! while `VD` is identical.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::coordinator::lanes::{self, LaneMap};
use crate::coordinator::metrics::EngineMetrics;
use crate::coordinator::sampling::Sampler;
use crate::coordinator::sequence::{SeqId, Sequence};
use crate::runtime::client::{literal_to_tensor, Arg, Runtime};
use crate::runtime::manifest::ConfigEntry;
use crate::runtime::params::ParamStore;
use crate::substrate::rng::Rng;
use crate::substrate::tensor::{Tensor, TensorI32};

/// Per-sequence parked cache rows: `(L, len, D)` row-major.
#[derive(Clone, Debug)]
struct Parked {
    len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

/// In-flight chunked prefill (ISSUE 3): the sequence's prompt has been
/// ingested up to `done` tokens. The partially filled `(L, S, KD/VD)`
/// arenas are carried across chunks as device literals (fed straight back
/// via `Arg::L`, never round-tripped through host tensors), and the host
/// mirror accumulates only the per-chunk delta rows `k_rows`/`v_rows` —
/// the prefill twin of the decode delta-sync contract, so chunked prefill
/// never downloads a full arena between chunks either.
struct ChunkProgress {
    done: usize,
    k_lit: xla::Literal,
    v_lit: xla::Literal,
    /// Host mirror of the prefill arenas, `(L, S, KD)` / `(L, S, VD)`,
    /// current up to row `done`; compacted into [`Parked`] on completion.
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct Engine<'rt> {
    rt: &'rt Runtime,
    pub cfg: ConfigEntry,
    /// Model weights (read-only once the engine is built — the param
    /// literals below are converted a single time; see EXPERIMENTS.md
    /// §Perf).
    pub params: ParamStore,
    pub pallas: bool,
    pub sampler: Sampler,
    /// Force a fixed arena tier instead of auto-selecting the smallest
    /// covering one. `Some(cfg.max_seq)` reproduces the pre-tiering
    /// engine (every arena sized at max context) — the benchmark
    /// baseline.
    pub pin_tier: Option<usize>,
    rng: Rng,
    /// Pre-converted parameter literals (L3-opt-1: params never change at
    /// serve time, so the host->literal conversion happens once, not per
    /// step).
    param_lits: Vec<xla::Literal>,
    /// Steady-state cache literals (L3-opt-2: while lane assignment and
    /// tier cover the active set, the previous step's output caches are
    /// fed straight back without literal<->tensor round trips — including
    /// across zero-copy retirements).
    k_lit: Option<xla::Literal>,
    v_lit: Option<xla::Literal>,
    // group state
    lanes: LaneMap,
    /// Current arena length N (context tier); 0 before the first group.
    tier: usize,
    /// Always-current host mirrors of the decode arenas, delta-synced
    /// from the per-step `k_rows`/`v_rows` outputs.
    k_group: Tensor,
    v_group: Tensor,
    parked: HashMap<SeqId, Parked>,
    /// In-flight chunked prefills (prompt partially ingested).
    chunking: HashMap<SeqId, ChunkProgress>,
    /// Cache rows actually written per live sequence (= tokens fed so
    /// far; for an in-flight chunked prefill, the chunked progress).
    /// Physical-side half of the unified accounting contract.
    rows: HashMap<SeqId, usize>,
    /// Logits of the most recent completed prefill (monolithic or final
    /// chunk) — exposed for the chunked-vs-monolithic parity tests.
    last_prefill_logits: Option<Tensor>,
    pub metrics: EngineMetrics,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, cfg_name: &str, params: ParamStore,
               pallas: bool, sampler: Sampler, seed: u64) -> Result<Engine<'rt>> {
        let cfg = rt.manifest().config(cfg_name)?.clone();
        params.check_matches(&cfg)?;
        let param_lits = params
            .tensors
            .iter()
            .map(crate::runtime::client::tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(Engine {
            rt,
            cfg,
            params,
            pallas,
            sampler,
            pin_tier: None,
            rng: Rng::new(seed),
            param_lits,
            k_lit: None,
            v_lit: None,
            lanes: LaneMap::new(),
            tier: 0,
            k_group: Tensor::zeros(&[0]),
            v_group: Tensor::zeros(&[0]),
            parked: HashMap::new(),
            chunking: HashMap::new(),
            rows: HashMap::new(),
            last_prefill_logits: None,
            metrics: EngineMetrics::default(),
        })
    }

    pub fn max_context(&self) -> usize {
        self.cfg.max_seq
    }

    pub fn max_prompt(&self) -> usize {
        self.rt.manifest().prefill_seq
    }

    /// Current arena length N (0 before the first decode group).
    pub fn current_tier(&self) -> usize {
        self.tier
    }

    /// Current decode bucket B / lane count (0 before the first group).
    pub fn current_bucket(&self) -> usize {
        self.lanes.bucket()
    }

    /// Cache rows physically written for `id` (0 if unknown). The
    /// scheduler mirrors this into the KV block accounting.
    pub fn rows(&self, id: SeqId) -> usize {
        self.rows.get(&id).copied().unwrap_or(0)
    }

    /// The lane a sequence currently decodes in, if it is grouped.
    pub fn lane_of(&self, id: SeqId) -> Option<usize> {
        self.lanes.lane_of(id)
    }

    /// Prompt tokens ingested so far by an in-flight chunked prefill
    /// (None once complete, or if never chunk-prefilled).
    pub fn prefill_progress(&self, id: SeqId) -> Option<usize> {
        self.chunking.get(&id).map(|p| p.done)
    }

    /// Chunk lengths available for this config (empty on pre-chunking
    /// manifests — chunked mode is then unavailable).
    pub fn chunk_sizes(&self) -> Vec<usize> {
        self.rt.manifest().chunks_for(&self.cfg.name)
    }

    /// Logits of the most recent completed prefill (monolithic or final
    /// chunk) — the chunked-vs-monolithic parity oracle.
    pub fn last_prefill_logits(&self) -> Option<&Tensor> {
        self.last_prefill_logits.as_ref()
    }

    /// The parked cache rows of a sequence that finished prefill but has
    /// not joined a decode lane yet: `(len, k, v)` with k `(L, len, KD)`
    /// and v `(L, len, VD)` row-major. Parity-test surface: chunked and
    /// monolithic prefill must park bit-identical rows.
    pub fn parked_snapshot(&self, id: SeqId)
        -> Option<(usize, &[f32], &[f32])> {
        self.parked
            .get(&id)
            .map(|p| (p.len, p.k.as_slice(), p.v.as_slice()))
    }

    fn param_args(&self) -> Vec<Arg<'_>> {
        self.param_lits.iter().map(Arg::L).collect()
    }

    /// Bytes of one cache row (K + V) across all layers.
    fn row_bytes(&self) -> usize {
        self.cfg.n_layers * (self.cfg.k_cache_dims + self.cfg.v_cache_dims) * 4
    }

    /// THE designated path for downloading a full cache arena literal to
    /// host — it counts the bytes into `sync_download_bytes`, which the
    /// steady-churn regression test and bench_serving assert is 0. The
    /// delta-synced mirror removed every caller; if a future change needs
    /// an arena download again it must go through here (a bare
    /// `literal_to_tensor` on an arena is a review error), making the
    /// regression visible in the metric instead of silent.
    #[allow(dead_code)]
    fn download_arena(&mut self, lit: &xla::Literal) -> Result<Tensor> {
        let t = literal_to_tensor(lit)?;
        self.metrics.sync_download_bytes += (t.data.len() * 4) as u64;
        Ok(t)
    }

    /// Prefill a queued sequence: fill its cache rows, sample the first
    /// token. The sequence transitions to Decoding (or Finished if the
    /// first token ends it).
    pub fn prefill(&mut self, seq: &mut Sequence) -> Result<()> {
        let s = self.max_prompt();
        let p = seq.prompt.len();
        if p > s {
            bail!("prompt {p} exceeds prefill bucket {s}");
        }
        if p + seq.max_new > self.cfg.max_seq {
            bail!(
                "prompt {p} + max_new {} exceeds context {}",
                seq.max_new, self.cfg.max_seq
            );
        }
        let mut toks = vec![0i32; s];
        toks[..p].copy_from_slice(&seq.prompt);
        let tokens = TensorI32::new(&[1, s], toks);
        let artifact = self.rt.manifest().prefill_name(&self.cfg.name, self.pallas);
        let t0 = std::time::Instant::now();
        let mut args = self.param_args();
        args.push(Arg::I(&tokens));
        args.push(Arg::ScalarI(p as i32));
        let outs = self.rt.execute(&artifact, &args)?;
        self.metrics.prefill.record(t0.elapsed());
        self.metrics.prefill_tokens += p as u64;
        let logits = literal_to_tensor(&outs[0])?; // (1, V)

        // Park rows 0..p straight from the output literals (L, S, KD/VD):
        // park_prefilled compacts each layer's first p rows in place and
        // truncates — no intermediate full-S Tensor and no second
        // full-arena copy.
        let k = outs[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("download k_cache: {e}"))?;
        let v = outs[2]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("download v_cache: {e}"))?;
        self.park_prefilled(seq, k, v, logits);
        Ok(())
    }

    /// Shared prefill epilogue — THE single definition of how a finished
    /// prefill parks its rows and samples the first token, so the
    /// monolithic and chunked paths cannot drift apart (their bit-parity
    /// is a tested contract): compact the `(L, S, D)` buffers' first `p`
    /// rows in place, truncate, park, record the physical rows, sample
    /// from `logits`, and transition the sequence to Decoding.
    fn park_prefilled(&mut self, seq: &mut Sequence, mut k: Vec<f32>,
                      mut v: Vec<f32>, logits: Tensor) {
        let s = self.max_prompt();
        let p = seq.prompt.len();
        let (l, kd, vd) = (self.cfg.n_layers, self.cfg.k_cache_dims,
                           self.cfg.v_cache_dims);
        for li in 0..l {
            k.copy_within(li * s * kd..(li * s + p) * kd, li * p * kd);
            v.copy_within(li * s * vd..(li * s + p) * vd, li * p * vd);
        }
        k.truncate(l * p * kd);
        v.truncate(l * p * vd);
        self.parked.insert(seq.id, Parked { len: p, k, v });
        self.rows.insert(seq.id, p);
        let tok = self.sampler.sample(&logits.data, &mut self.rng);
        self.last_prefill_logits = Some(logits);
        seq.state = crate::coordinator::sequence::SeqState::Decoding;
        seq.push_token(tok);
    }

    /// Advance a sequence's prefill by ONE chunk of `chunk` prompt
    /// positions (resumable; ISSUE 3). Returns `Ok(true)` when the whole
    /// prompt has been ingested — the first token is then sampled and the
    /// rows parked exactly as [`Engine::prefill`] would have parked them
    /// (bit-identical, see the parity tests). `Ok(false)` means the
    /// prompt is partially ingested: the arenas stay parked as device
    /// literals in [`ChunkProgress`] and the scheduler may interleave
    /// decode rounds (or higher-priority prefills) before the next chunk.
    ///
    /// `rows(id)` tracks the chunked progress, so the scheduler's
    /// `commit_rows` mirror stays exact mid-prefill too.
    pub fn prefill_chunk(&mut self, seq: &mut Sequence, chunk: usize)
        -> Result<bool> {
        let s = self.max_prompt();
        let p = seq.prompt.len();
        if p > s {
            bail!("prompt {p} exceeds prefill bucket {s}");
        }
        if p + seq.max_new > self.cfg.max_seq {
            bail!(
                "prompt {p} + max_new {} exceeds context {}",
                seq.max_new, self.cfg.max_seq
            );
        }
        if self.pallas {
            // the chunk artifacts are ref-only (aot.py exports no _pallas
            // chunk column); mixing ref chunked prefill with pallas decode
            // would silently break the chunked==monolithic parity contract
            bail!(
                "chunked prefill has no pallas artifact path — serve with \
                 --chunk-tokens 0 or without --pallas"
            );
        }
        let chunks = self.chunk_sizes();
        if !chunks.contains(&chunk) {
            bail!("chunk {chunk} not exported (available: {chunks:?})");
        }
        let (l, kd, vd) = (self.cfg.n_layers, self.cfg.k_cache_dims,
                           self.cfg.v_cache_dims);
        if !self.chunking.contains_key(&seq.id) {
            // first chunk: fresh zero arenas, uploaded once as literals —
            // counted against the sync contract like any arena upload
            let prog = ChunkProgress {
                done: 0,
                k_lit: crate::runtime::client::tensor_to_literal(
                    &Tensor::zeros(&[l, s, kd]))?,
                v_lit: crate::runtime::client::tensor_to_literal(
                    &Tensor::zeros(&[l, s, vd]))?,
                k: vec![0.0; l * s * kd],
                v: vec![0.0; l * s * vd],
            };
            self.metrics.sync_upload_bytes +=
                (l * s * (kd + vd) * 4) as u64;
            self.chunking.insert(seq.id, prog);
            self.rows.insert(seq.id, 0);
        }
        let start = self.chunking[&seq.id].done;
        debug_assert!(start < p, "chunk past end of prompt");
        let n_valid = chunk.min(p - start);
        let mut toks = vec![0i32; chunk];
        toks[..n_valid].copy_from_slice(&seq.prompt[start..start + n_valid]);
        let tokens = TensorI32::new(&[1, chunk], toks);
        let artifact =
            self.rt.manifest().prefill_chunk_name(&self.cfg.name, chunk);
        let t0 = std::time::Instant::now();
        let outs = {
            let prog = &self.chunking[&seq.id];
            let mut args = self.param_args();
            args.push(Arg::L(&prog.k_lit));
            args.push(Arg::L(&prog.v_lit));
            args.push(Arg::I(&tokens));
            args.push(Arg::ScalarI(start as i32));
            args.push(Arg::ScalarI(p as i32));
            self.rt.execute(&artifact, &args)?
        };
        self.metrics.prefill.record(t0.elapsed());
        self.metrics.prefill_chunks += 1;
        self.metrics.prefill_tokens += n_valid as u64;
        let logits = literal_to_tensor(&outs[0])?; // (1, V)
        let k_rows = outs[3]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("download k_rows: {e}"))?;
        let v_rows = outs[4]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("download v_rows: {e}"))?;
        let mut outs = outs;
        let v_lit = outs.remove(2);
        let k_lit = outs.remove(1);
        let prog = self.chunking.get_mut(&seq.id).expect("chunk progress");
        prog.k_lit = k_lit;
        prog.v_lit = v_lit;
        // delta-sync: scatter this chunk's written rows (L, chunk, KD/VD)
        // into the host mirror at [start, start+n_valid)
        for li in 0..l {
            let src = li * chunk * kd;
            let dst = (li * s + start) * kd;
            prog.k[dst..dst + n_valid * kd]
                .copy_from_slice(&k_rows[src..src + n_valid * kd]);
            let src = li * chunk * vd;
            let dst = (li * s + start) * vd;
            prog.v[dst..dst + n_valid * vd]
                .copy_from_slice(&v_rows[src..src + n_valid * vd]);
        }
        prog.done = start + n_valid;
        self.rows.insert(seq.id, prog.done);
        if prog.done < p {
            return Ok(false);
        }
        // final chunk: the host mirror holds every prompt row — park it
        // through the same epilogue the monolithic prefill uses
        let prog = self.chunking.remove(&seq.id).expect("chunk progress");
        self.park_prefilled(seq, prog.k, prog.v, logits);
        Ok(true)
    }

    /// Bucket to repack into for `n` active lanes: minimal on first group
    /// and growth, sticky on shrink (see [`lanes::target_bucket`]).
    fn target_bucket(&self, n: usize) -> Result<usize> {
        lanes::target_bucket(
            &self.rt.manifest().decode_batches,
            n,
            self.lanes.bucket(),
        )
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no decode bucket >= {n} (max {:?})",
                self.rt.manifest().decode_batches.last()
            )
        })
    }

    /// Arena tier for the longest active sequence needing `need` rows:
    /// smallest covering tier on growth, sticky shrink with ~2x headroom
    /// (see [`lanes::target_tier`]); `pin_tier` overrides.
    fn target_tier(&self, need: usize) -> Result<usize> {
        if let Some(t) = self.pin_tier {
            if t < need {
                bail!("pinned tier {t} < required rows {need}");
            }
            return Ok(t);
        }
        let tiers = self.rt.manifest().tiers_for(&self.cfg.name);
        lanes::target_tier(&tiers, need, self.tier).ok_or_else(|| {
            anyhow::anyhow!("no decode tier >= {need} (tiers {tiers:?})")
        })
    }

    /// Write a parked sequence's rows into group lane `lane` (one
    /// contiguous copy per layer per arena).
    fn unpark_into(&mut self, id: SeqId, lane: usize) {
        let (l, n) = (self.cfg.n_layers, self.tier);
        let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
        let b = self.lanes.bucket();
        let p = self.parked.get(&id).expect("unpark of unknown seq");
        for li in 0..l {
            let gk = (li * b + lane) * n * kd;
            self.k_group.data[gk..gk + p.len * kd]
                .copy_from_slice(&p.k[li * p.len * kd..(li + 1) * p.len * kd]);
            let gv = (li * b + lane) * n * vd;
            self.v_group.data[gv..gv + p.len * vd]
                .copy_from_slice(&p.v[li * p.len * vd..(li + 1) * p.len * vd]);
        }
    }

    /// Copy a lane's live rows from the (always-current) mirror back into
    /// the parked store.
    fn park_from(&mut self, id: SeqId, lane: usize, len: usize) {
        let (l, n) = (self.cfg.n_layers, self.tier);
        let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
        let b = self.lanes.bucket();
        let mut parked = Parked {
            len,
            k: vec![0.0; l * len * kd],
            v: vec![0.0; l * len * vd],
        };
        for li in 0..l {
            let gk = (li * b + lane) * n * kd;
            parked.k[li * len * kd..(li + 1) * len * kd]
                .copy_from_slice(&self.k_group.data[gk..gk + len * kd]);
            let gv = (li * b + lane) * n * vd;
            parked.v[li * len * vd..(li + 1) * len * vd]
                .copy_from_slice(&self.v_group.data[gv..gv + len * vd]);
        }
        self.parked.insert(id, parked);
    }

    /// Incrementally repack the decode group to cover the `active`
    /// sequence ids at arena tier `tier`: stable sequences keep their
    /// lanes (zero copies), live leavers are parked, joiners are unparked
    /// into holes, and kept lanes move only on a bucket resize or tier
    /// switch (each copied once, directly between arenas — not the old
    /// park+unpark double copy). Operates entirely on the host mirror —
    /// no device downloads.
    fn regroup(&mut self, active: &[SeqId], tier: usize) -> Result<()> {
        let bucket = self.target_bucket(active.len())?;
        let plan = self.lanes.plan(active, bucket);
        let mut cost = lanes::copy_cost(
            &plan,
            |id| self.rows.get(&id).copied().unwrap_or(0),
            self.row_bytes(),
        );
        if tier != self.tier && !plan.resize {
            // a tier-only switch still copies every kept lane into the
            // newly sized arena
            let kept: u64 = plan
                .keep
                .iter()
                .map(|&(id, _, _)| {
                    self.rows.get(&id).copied().unwrap_or(0) as u64
                })
                .sum();
            cost.actual += kept * self.row_bytes() as u64;
        }
        // park live leavers while the mirror still holds their rows at
        // the old bucket/tier strides
        for &(id, lane) in &plan.leave {
            if let Some(&len) = self.rows.get(&id) {
                self.park_from(id, lane, len);
            }
            self.metrics.lane_leaves += 1;
        }
        if plan.resize || tier != self.tier {
            let l = self.cfg.n_layers;
            let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
            let (old_b, old_n) = (self.lanes.bucket(), self.tier);
            let old_k = std::mem::replace(
                &mut self.k_group, Tensor::zeros(&[l, bucket, tier, kd]));
            let old_v = std::mem::replace(
                &mut self.v_group, Tensor::zeros(&[l, bucket, tier, vd]));
            for &(id, from, to) in &plan.keep {
                let len = self.rows.get(&id).copied().unwrap_or(0);
                for li in 0..l {
                    let src = (li * old_b + from) * old_n * kd;
                    let dst = (li * bucket + to) * tier * kd;
                    self.k_group.data[dst..dst + len * kd]
                        .copy_from_slice(&old_k.data[src..src + len * kd]);
                    let src = (li * old_b + from) * old_n * vd;
                    let dst = (li * bucket + to) * tier * vd;
                    self.v_group.data[dst..dst + len * vd]
                        .copy_from_slice(&old_v.data[src..src + len * vd]);
                }
            }
            if tier != self.tier {
                self.metrics.tier_switches += 1;
            }
            self.tier = tier;
            self.metrics.arena_bytes =
                ((self.k_group.data.len() + self.v_group.data.len()) * 4)
                    as u64;
        }
        self.lanes.apply(&plan);
        for &(id, lane) in &plan.join {
            self.unpark_into(id, lane);
            // the arena is now the live copy; drop the parked snapshot
            self.parked.remove(&id);
            self.metrics.lane_joins += 1;
        }
        self.metrics.regroups += 1;
        self.metrics.copyback_bytes += cost.actual;
        self.metrics.copyback_bytes_full += cost.full_equiv;
        Ok(())
    }

    /// One continuous-batching decode step over the given active
    /// sequences. Samples and records one token per sequence, feeding
    /// each lane from the lane map (never from enumeration order — see
    /// the lane-misalignment regression tests).
    pub fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()> {
        if seqs.is_empty() {
            return Ok(());
        }
        for s in seqs.iter() {
            if s.len() >= self.cfg.max_seq {
                bail!("sequence {} exceeds context arena", s.id);
            }
        }
        let active: Vec<SeqId> = seqs.iter().map(|s| s.id).collect();
        // rows the arena must hold: the longest sequence writes row
        // len-1 this step and attends to rows 0..len
        let need = seqs.iter().map(|s| s.len()).max().unwrap();
        let tier = self.target_tier(need)?;
        let in_sync = self.k_lit.is_some()
            && tier == self.tier
            && self.lanes.live() == active.len()
            && active.iter().all(|&id| self.lanes.lane_of(id).is_some());
        if !in_sync {
            // the host mirror is always current (delta-synced every
            // step), so a membership change or tier switch repacks it
            // directly — there is no full-arena download here, only the
            // upload of the repacked arenas
            self.regroup(&active, tier)?;
            self.k_lit = Some(crate::runtime::client::tensor_to_literal(
                &self.k_group)?);
            self.v_lit = Some(crate::runtime::client::tensor_to_literal(
                &self.v_group)?);
            self.metrics.sync_upload_bytes +=
                ((self.k_group.data.len() + self.v_group.data.len()) * 4)
                    as u64;
        }
        let b = self.lanes.bucket();
        let n = self.tier;

        // holes (vacated lanes) decode a dummy token at position 0; the
        // row they write is overwritten when a joiner reuses the lane
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for s in seqs.iter() {
            let lane = self.lanes.lane_of(s.id).expect("active seq has a lane");
            toks[lane] = s.last_token();
            pos[lane] = (s.len() - 1) as i32;
        }
        let tokens = TensorI32::new(&[b], toks);
        let positions = TensorI32::new(&[b], pos);
        let artifact =
            self.rt.manifest().decode_name(&self.cfg.name, b, n, self.pallas);
        let t0 = std::time::Instant::now();
        let outs = {
            let mut args = self.param_args();
            args.push(Arg::L(self.k_lit.as_ref().unwrap()));
            args.push(Arg::L(self.v_lit.as_ref().unwrap()));
            args.push(Arg::I(&tokens));
            args.push(Arg::I(&positions));
            self.rt.execute(&artifact, &args)?
        };
        self.metrics.decode.record(t0.elapsed());
        self.metrics.decode_steps += 1;
        self.metrics.decode_tokens += seqs.len() as u64;
        self.metrics.occupancy_sum += seqs.len() as f64 / b as f64;
        *self.metrics.tier_steps.entry(n).or_insert(0) += 1;

        let logits = literal_to_tensor(&outs[0])?; // (B, V)
        let k_rows = literal_to_tensor(&outs[3])?; // (L, B, KD)
        let v_rows = literal_to_tensor(&outs[4])?; // (L, B, VD)
        let mut outs = outs;
        self.v_lit = Some(outs.remove(2));
        self.k_lit = Some(outs.remove(1));
        let l = self.cfg.n_layers;
        let (kd, vd) = (self.cfg.k_cache_dims, self.cfg.v_cache_dims);
        self.metrics.row_sync_bytes +=
            ((k_rows.data.len() + v_rows.data.len()) * 4) as u64;
        // delta-sync: scatter this step's written rows into the host
        // mirror — O(L·B·(KD+VD)) per step, independent of max_seq — so
        // the next membership change repacks without any arena download
        for s in seqs.iter() {
            let lane = self.lanes.lane_of(s.id).expect("active seq has a lane");
            let row = s.len() - 1;
            for li in 0..l {
                let src = (li * b + lane) * kd;
                let dst = ((li * b + lane) * n + row) * kd;
                self.k_group.data[dst..dst + kd]
                    .copy_from_slice(&k_rows.data[src..src + kd]);
                let src = (li * b + lane) * vd;
                let dst = ((li * b + lane) * n + row) * vd;
                self.v_group.data[dst..dst + vd]
                    .copy_from_slice(&v_rows.data[src..src + vd]);
            }
        }
        let v = self.cfg.vocab;
        for s in seqs.iter_mut() {
            let lane = self.lanes.lane_of(s.id).expect("active seq has a lane");
            // this step wrote the row for the token we just fed
            self.rows.insert(s.id, s.len());
            let row = &logits.data[lane * v..(lane + 1) * v];
            let tok = self.sampler.sample(row, &mut self.rng);
            s.push_token(tok);
        }
        // finished sequences vacate their lanes via drop_seq (zero-copy)
        Ok(())
    }

    /// Forget a sequence's cache storage. If it held a lane, the lane
    /// becomes a hole — no bytes move, no regroup is scheduled; survivors
    /// keep decoding from their existing lanes.
    pub fn drop_seq(&mut self, id: SeqId) {
        self.parked.remove(&id);
        self.chunking.remove(&id); // cancel an in-flight chunked prefill
        self.rows.remove(&id);
        if self.lanes.remove(id) {
            self.metrics.lane_leaves += 1;
            // what the old full park/unpark design would have copied for
            // this retirement: every survivor out and back in
            let survivors: u64 = self
                .lanes
                .ids()
                .map(|sid| self.rows.get(&sid).copied().unwrap_or(0) as u64)
                .sum();
            let full = 2 * survivors * self.row_bytes() as u64;
            self.metrics.copyback_bytes_full += full;
        }
    }

    /// Bytes of host cache storage currently parked (diagnostics) —
    /// completed-prefill rows plus in-flight chunked-prefill mirrors.
    pub fn parked_bytes(&self) -> usize {
        let parked: usize = self
            .parked
            .values()
            .map(|p| (p.k.len() + p.v.len()) * 4)
            .sum();
        let chunking: usize = self
            .chunking
            .values()
            .map(|p| (p.k.len() + p.v.len()) * 4)
            .sum();
        parked + chunking
    }
}

#[cfg(test)]
mod tests {
    // Engine behaviour against real artifacts is covered by
    // rust/tests/serving_e2e.rs; lane assignment, repack planning, and
    // bucket/tier selection are unit tested in crate::coordinator::lanes.

    #[test]
    fn bucket_selection_logic() {
        // mirror of target_bucket's growth search, without a Runtime
        let buckets = [1usize, 2, 4, 8, 16, 32];
        let pick = |n: usize| buckets.iter().copied().find(|&b| b >= n);
        assert_eq!(pick(1), Some(1));
        assert_eq!(pick(3), Some(4));
        assert_eq!(pick(8), Some(8));
        assert_eq!(pick(33), None);
    }
}
