//! Paged KV cache: a **refcounted block pool** with per-sequence block
//! tables and copy-on-write shared-prefix sharing (vLLM-style, ISSUE 8).
//!
//! Factored keys make K entries `r/d` the size of V entries; the pool
//! tracks both surfaces per block (a token always needs one K slot *and*
//! one V slot, so the K/V pools were always symmetric — one `BlockId`
//! addresses both, with independent per-token byte costs for the
//! capacity accounting that doubles as the Table 10 calculator).
//! Quantized deployments are modeled by the per-element byte widths
//! (bf16 = 2, int8 = 1, int4 = 0.5) — the 16x composed compression of §6.
//!
//! Sharing model (ISSUE 8): a radix tree over exact `block_tokens`-sized
//! prompt chunks maps a prefix path to the blocks that physically hold
//! it. Admission walks the tree ([`KvCacheManager::allocate_prompt`]) —
//! every matched block is adopted into the new table with a refcount
//! bump and its rows are **never prefilled again**; the first divergent
//! token gets a private fresh block (copy-on-write: shared blocks are
//! immutable, writes only ever land in ref==1 unregistered blocks).
//! A completed prefill registers its full-prompt blocks
//! ([`KvCacheManager::seal_prefix`]); registration is *weak* — the tree
//! holds no refcount, so when the last table drops a block the block is
//! freed AND its tree node deregistered, preserving the drain invariant
//! (`free == total` after release, no persistent cache).
//! [`KvCacheManager::fork`] shares a running sequence's full written
//! blocks with a child refcount-only and copies just the partial tail
//! block (`cow_split`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

pub type SeqId = u64;
/// Index into the block pool; one id addresses the paired K+V block.
pub type BlockId = usize;

#[derive(Clone, Debug)]
pub struct KvCacheConfig {
    pub n_layers: usize,
    /// K dims per token per layer (n_kv_heads * d_qk_head) — THIN.
    pub k_dims: usize,
    /// V dims per token per layer (n_kv_heads * d_v_head) — FULL.
    pub v_dims: usize,
    pub block_tokens: usize,
    pub bytes_per_el_k: f64,
    pub bytes_per_el_v: f64,
    /// Total budget for K+V pools, in bytes.
    pub budget_bytes: f64,
}

impl KvCacheConfig {
    pub fn k_bytes_per_token(&self) -> f64 {
        self.n_layers as f64 * self.k_dims as f64 * self.bytes_per_el_k
    }

    pub fn v_bytes_per_token(&self) -> f64 {
        self.n_layers as f64 * self.v_dims as f64 * self.bytes_per_el_v
    }

    pub fn bytes_per_token(&self) -> f64 {
        self.k_bytes_per_token() + self.v_bytes_per_token()
    }

    /// Token capacity implied by the budget.
    pub fn token_capacity(&self) -> usize {
        (self.budget_bytes / self.bytes_per_token()) as usize
    }

    /// K+V bytes held by one block (both surfaces, all layers).
    pub fn block_bytes(&self) -> f64 {
        self.block_tokens as f64 * self.bytes_per_token()
    }
}

#[derive(Clone, Debug, Default)]
struct BlockTable {
    n_tokens: usize,
    /// Cache rows the engine has physically written for this sequence —
    /// mirrored from `Engine::rows` by the scheduler so the logical
    /// reservation and the physical arena stay in agreement.
    rows_written: usize,
    blocks: Vec<BlockId>,
    /// Rows addressed through possibly-shared blocks (always a multiple
    /// of `block_tokens`). Blocks past `shared_rows / block_tokens` are
    /// private: refcount 1, never tree-registered — the only blocks this
    /// sequence may still write (the CoW privacy invariant).
    shared_rows: usize,
    /// Position-slots whose block was evicted whole back to the pool
    /// (ISSUE 10). Slot `i` covers rows `[i*bt, (i+1)*bt)`; `blocks`
    /// holds only the LIVE slots in ascending slot order, so the table's
    /// slot span is `blocks.len() + evicted_slots.len()` and stays equal
    /// to `ceil(n_tokens / bt)` (slot conservation). Sorted, unique.
    evicted_slots: Vec<usize>,
}

impl BlockTable {
    /// Total position-slots (live + evicted) — always covers `n_tokens`.
    fn slot_span(&self) -> usize {
        self.blocks.len() + self.evicted_slots.len()
    }

    /// Index into `blocks` of the live block at position-slot `slot`
    /// (None when the slot is evicted or out of range).
    fn live_index(&self, slot: usize) -> Option<usize> {
        if slot >= self.slot_span() || self.evicted_slots.contains(&slot) {
            return None;
        }
        Some(slot - self.evicted_slots.iter().filter(|&&e| e < slot).count())
    }

    /// Position-slots currently holding live blocks, ascending.
    fn live_slots(&self) -> Vec<usize> {
        (0..self.slot_span())
            .filter(|s| !self.evicted_slots.contains(s))
            .collect()
    }
}

/// The refcounted block pool. `refs[b] == 0` ⟺ `b` is on the free list;
/// sharing a block is a refcount bump, the last release frees it.
#[derive(Clone, Debug)]
struct Pool {
    total: usize,
    free: Vec<BlockId>,
    refs: Vec<u32>,
}

impl Pool {
    fn new(total: usize) -> Pool {
        Pool { total, free: (0..total).rev().collect(), refs: vec![0; total] }
    }

    fn used(&self) -> usize {
        self.total - self.free.len()
    }

    fn alloc(&mut self) -> Option<BlockId> {
        let b = self.free.pop()?;
        self.refs[b] = 1;
        Some(b)
    }

    fn retain(&mut self, b: BlockId) {
        self.refs[b] += 1;
    }

    /// Drop one reference; returns true when the block is freed.
    fn release(&mut self, b: BlockId) -> bool {
        debug_assert!(self.refs[b] > 0, "release of a free block");
        self.refs[b] = self.refs[b].saturating_sub(1);
        if self.refs[b] == 0 {
            self.free.push(b);
            true
        } else {
            false
        }
    }
}

/// Radix tree over exact `block_tokens`-sized prompt chunks. Each node
/// owns one block; children are keyed by the next full chunk of prompt
/// tokens. Registration is weak: the tree never holds a refcount, and a
/// freed block's node is removed in the same release.
#[derive(Clone, Debug, Default)]
struct PrefixNode {
    chunk: Vec<i32>,
    block: BlockId,
    parent: Option<usize>,
    children: BTreeMap<Vec<i32>, usize>,
}

#[derive(Clone, Debug, Default)]
struct PrefixTree {
    nodes: Vec<Option<PrefixNode>>,
    free_slots: Vec<usize>,
    roots: BTreeMap<Vec<i32>, usize>,
    node_of_block: BTreeMap<BlockId, usize>,
}

impl PrefixTree {
    /// Longest registered prefix path along `chunks`, as the blocks that
    /// hold it.
    fn lookup(&self, chunks: &[&[i32]]) -> Vec<BlockId> {
        let mut out = Vec::new();
        let mut level = &self.roots;
        for &chunk in chunks {
            let Some(&slot) = level.get(chunk) else { break };
            let Some(node) = self.nodes[slot].as_ref() else { break };
            out.push(node.block);
            level = &node.children;
        }
        out
    }

    /// Walk/extend the tree along `chunks`, registering `blocks[i]` at
    /// every depth that has no node yet. Returns `(depth, newly)`: the
    /// number of leading chunks whose node holds OUR block (pre-existing
    /// match or fresh registration — a node holding a *different* block
    /// is a physically divergent twin prefix and stops the walk), and the
    /// freshly registered `(chunk index, block)` pairs.
    fn register(&mut self, chunks: &[&[i32]], blocks: &[BlockId])
        -> (usize, Vec<(usize, BlockId)>) {
        let mut newly = Vec::new();
        let mut parent: Option<usize> = None;
        let mut depth = 0;
        for (i, &chunk) in chunks.iter().enumerate() {
            let existing = match parent {
                None => self.roots.get(chunk).copied(),
                Some(p) => self.nodes[p]
                    .as_ref()
                    .and_then(|n| n.children.get(chunk).copied()),
            };
            match existing {
                Some(slot) => {
                    let node = self.nodes[slot].as_ref().expect(
                        "prefix tree: live child points at a freed slot");
                    if node.block != blocks[i] {
                        break;
                    }
                    parent = Some(slot);
                }
                None => {
                    let node = PrefixNode {
                        chunk: chunk.to_vec(),
                        block: blocks[i],
                        parent,
                        children: BTreeMap::new(),
                    };
                    let slot = match self.free_slots.pop() {
                        Some(slot) => {
                            self.nodes[slot] = Some(node);
                            slot
                        }
                        None => {
                            self.nodes.push(Some(node));
                            self.nodes.len() - 1
                        }
                    };
                    match parent {
                        None => {
                            self.roots.insert(chunk.to_vec(), slot);
                        }
                        Some(p) => {
                            if let Some(pn) = self.nodes[p].as_mut() {
                                pn.children.insert(chunk.to_vec(), slot);
                            }
                        }
                    }
                    self.node_of_block.insert(blocks[i], slot);
                    newly.push((i, blocks[i]));
                    parent = Some(slot);
                }
            }
            depth = i + 1;
        }
        (depth, newly)
    }

    /// Remove a freed block's node. Safe against same-batch parent frees:
    /// refcounts are non-increasing root→leaf (every holder of a child
    /// block holds the whole path), so a parent freed in this release has
    /// all its children freed in the same release.
    fn deregister(&mut self, block: BlockId) {
        let Some(slot) = self.node_of_block.remove(&block) else { return };
        let Some(node) = self.nodes[slot].take() else { return };
        self.free_slots.push(slot);
        match node.parent {
            None => {
                self.roots.remove(&node.chunk);
            }
            Some(p) => {
                if let Some(pn) =
                    self.nodes.get_mut(p).and_then(|n| n.as_mut())
                {
                    pn.children.remove(&node.chunk);
                }
            }
        }
    }

    fn is_registered(&self, block: BlockId) -> bool {
        self.node_of_block.contains_key(&block)
    }

    fn registered(&self) -> impl Iterator<Item = BlockId> + '_ {
        self.node_of_block.keys().copied()
    }

    fn len(&self) -> usize {
        self.node_of_block.len()
    }
}

/// What a prompt admission matched in the prefix tree: the adopted
/// (refcount-bumped) blocks and the rows they hold — rows the engine
/// never prefills again.
#[derive(Clone, Debug, Default)]
pub struct PrefixGrant {
    pub matched_rows: usize,
    pub matched_blocks: Vec<BlockId>,
}

/// What sealing a completed prefill registered: the sequence's shared
/// prefix (all full-prompt blocks on the registered path) plus the
/// subset the tree had never seen — the engine must publish exactly
/// those rows into its shared prefix store.
#[derive(Clone, Debug, Default)]
pub struct SealOutcome {
    /// Freshly registered `(block index in table, block)` pairs.
    pub registered: Vec<(usize, BlockId)>,
    /// The full shared-prefix block list after sealing.
    pub blocks: Vec<BlockId>,
    pub shared_rows: usize,
}

/// A copy-on-write fork grant: the child shares every full block the
/// parent has written (refcount only) and privately copies the partial
/// tail block, if any (`cow_split`).
#[derive(Clone, Debug, Default)]
pub struct ForkGrant {
    pub shared_blocks: Vec<BlockId>,
    pub shared_rows: usize,
    /// True when the parent's write frontier split a block: the tail
    /// rows must be copied into the child's private storage.
    pub cow_split: bool,
    /// Parent blocks that BECOME shared by this fork `(block index,
    /// block)` — previously private, the engine must publish their rows.
    pub published: Vec<(usize, BlockId)>,
}

/// Pool-level sharing gauges for [`crate::coordinator::metrics`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SharingStats {
    /// Blocks referenced by 2+ tables right now.
    pub shared_blocks: usize,
    /// Bytes sharing saves vs one private copy per reference.
    pub dedup_bytes: f64,
    pub prefix_nodes: usize,
    pub blocks_used: usize,
    pub blocks_total: usize,
}

#[derive(Clone, Debug)]
pub struct KvCacheManager {
    pub cfg: KvCacheConfig,
    pool: Pool,
    tree: PrefixTree,
    tables: BTreeMap<SeqId, BlockTable>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct CacheStats {
    pub seqs: usize,
    pub tokens: usize,
    /// Rows physically written by the engine, summed over live sequences.
    pub tokens_written: usize,
    pub k_blocks_used: usize,
    pub v_blocks_used: usize,
    pub k_bytes_used: f64,
    pub v_bytes_used: f64,
    pub k_bytes_capacity: f64,
    pub v_bytes_capacity: f64,
}

impl CacheStats {
    pub fn bytes_used(&self) -> f64 {
        self.k_bytes_used + self.v_bytes_used
    }

    /// K share of live cache bytes — ~r/(r+d) under factored keys.
    pub fn k_fraction(&self) -> f64 {
        let t = self.bytes_used();
        if t == 0.0 { 0.0 } else { self.k_bytes_used / t }
    }
}

impl KvCacheManager {
    /// Size the pool so every block covers one K slot and one V slot per
    /// token (the budget splits implicitly by the per-surface byte
    /// costs).
    pub fn new(cfg: KvCacheConfig) -> KvCacheManager {
        let tokens = cfg.token_capacity();
        let blocks = tokens / cfg.block_tokens;
        Self::with_block_count(cfg, blocks)
    }

    /// Size the pool to an explicit block count, ignoring the byte
    /// budget — the `--kv-budget-blocks` serve axis (ISSUE 10), which
    /// pins the bounded-cache experiments to an exact pool size instead
    /// of deriving one from dtype-aware byte math.
    pub fn with_block_count(cfg: KvCacheConfig, blocks: usize)
        -> KvCacheManager {
        KvCacheManager {
            pool: Pool::new(blocks),
            tree: PrefixTree::default(),
            tables: BTreeMap::new(),
            cfg,
        }
    }

    fn blocks_for(&self, n_tokens: usize) -> usize {
        n_tokens.div_ceil(self.cfg.block_tokens)
    }

    /// Free blocks available for new sequences, in tokens.
    pub fn free_token_capacity(&self) -> usize {
        self.pool.free.len() * self.cfg.block_tokens
    }

    /// Total block capacity in tokens — the largest reservation that
    /// could ever be admitted, even into an empty cache.
    pub fn total_token_capacity(&self) -> usize {
        self.pool.total * self.cfg.block_tokens
    }

    pub fn can_admit(&self, n_tokens: usize) -> bool {
        self.pool.free.len() >= self.blocks_for(n_tokens)
    }

    /// Full prompt chunks eligible for sharing: the partial tail block is
    /// never shared (the sequence still writes it), and at least one
    /// prompt token must stay unshared so prefill produces the logits the
    /// first sampled token needs.
    fn shareable_chunks(&self, prompt: &[i32]) -> Vec<&[i32]> {
        let bt = self.cfg.block_tokens;
        let max_blocks = prompt.len().saturating_sub(1) / bt;
        prompt.chunks(bt).take(max_blocks).collect()
    }

    /// Like [`KvCacheManager::can_admit`], but credits the blocks a
    /// prefix match would adopt instead of allocating — sharing admits
    /// strictly more concurrent sequences on the same pool.
    pub fn can_admit_prompt(&self, prompt: &[i32], n_tokens: usize,
                            sharing: bool) -> bool {
        let matched = if sharing {
            self.tree.lookup(&self.shareable_chunks(prompt)).len()
        } else {
            0
        };
        self.pool.free.len() >= self.blocks_for(n_tokens).saturating_sub(matched)
    }

    /// Reserve blocks for a new sequence of `n_tokens` (prompt +
    /// headroom), adopting every block of the longest registered prefix
    /// of `prompt` (refcount bump, no allocation) when `sharing` is on.
    /// The returned grant names the adopted rows — the engine seeds its
    /// prefill from them and never recomputes them.
    pub fn allocate_prompt(&mut self, seq: SeqId, prompt: &[i32],
                           n_tokens: usize, sharing: bool)
        -> Result<PrefixGrant> {
        if self.tables.contains_key(&seq) {
            bail!("sequence {seq} already allocated");
        }
        if n_tokens < prompt.len() {
            bail!("reservation {n_tokens} smaller than prompt {}",
                  prompt.len());
        }
        let matched_blocks = if sharing {
            self.tree.lookup(&self.shareable_chunks(prompt))
        } else {
            Vec::new()
        };
        let need = self.blocks_for(n_tokens);
        let fresh = need - matched_blocks.len();
        if self.pool.free.len() < fresh {
            bail!(
                "KV cache full: need {fresh} fresh blocks ({} matched), \
                 free {}",
                matched_blocks.len(),
                self.pool.free.len()
            );
        }
        let mut t = BlockTable {
            n_tokens,
            shared_rows: matched_blocks.len() * self.cfg.block_tokens,
            ..Default::default()
        };
        for &b in &matched_blocks {
            self.pool.retain(b);
            t.blocks.push(b);
        }
        for _ in 0..fresh {
            t.blocks.push(self.pool.alloc().expect(
                "pool accounting: the free-block check above guarantees \
                 `fresh` free blocks"));
        }
        let matched_rows = t.shared_rows;
        self.tables.insert(seq, t);
        Ok(PrefixGrant { matched_rows, matched_blocks })
    }

    /// Reserve blocks for a new sequence with sharing disabled (legacy
    /// path; also the sharing-off baseline).
    pub fn allocate(&mut self, seq: SeqId, n_tokens: usize) -> Result<()> {
        if n_tokens == 0 {
            bail!("empty reservation for sequence {seq}");
        }
        self.allocate_prompt(seq, &[], n_tokens, false).map(|_| ())
    }

    /// Register a completed prefill's full-prompt blocks in the prefix
    /// tree so later prompts sharing the prefix adopt them. Weak: no
    /// refcount is taken — the registration dies with the blocks. The
    /// walk stops at a physically divergent twin (a node already holding
    /// a different block for the same chunk); everything registered or
    /// matched becomes this sequence's shared prefix, which it must
    /// never write again.
    pub fn seal_prefix(&mut self, seq: SeqId, prompt: &[i32])
        -> Result<SealOutcome> {
        let bt = self.cfg.block_tokens;
        let full = prompt.len() / bt;
        let t = self
            .tables
            .get(&seq)
            .ok_or_else(|| anyhow::anyhow!("seal_prefix: unknown sequence {seq}"))?;
        if t.rows_written < full * bt {
            bail!(
                "seal_prefix: sequence {seq} wrote {} rows, prompt holds \
                 {} full blocks",
                t.rows_written,
                full
            );
        }
        if t.evicted_slots.iter().any(|&s| s < full) {
            bail!(
                "seal_prefix: sequence {seq} evicted a prompt block — \
                 evicted rows cannot be registered for sharing"
            );
        }
        let chunks: Vec<&[i32]> = prompt.chunks(bt).take(full).collect();
        let blocks: Vec<BlockId> = t.blocks[..full].to_vec();
        let (depth, registered) = self.tree.register(&chunks, &blocks);
        let t = self.tables.get_mut(&seq).expect("table checked above");
        t.shared_rows = t.shared_rows.max(depth * bt);
        Ok(SealOutcome {
            registered,
            blocks: blocks[..depth].to_vec(),
            shared_rows: depth * bt,
        })
    }

    /// Fork `parent` into `child` copy-on-write: the child's table shares
    /// every full block the parent has written (refcount bump) and gets
    /// fresh private blocks for the rest of its `n_tokens` reservation.
    /// Parent blocks that were private until now are `published` — the
    /// engine must move their rows into the shared prefix store before
    /// either side decodes again.
    pub fn fork(&mut self, parent: SeqId, child: SeqId, n_tokens: usize)
        -> Result<ForkGrant> {
        if self.tables.contains_key(&child) {
            bail!("fork target {child} already allocated");
        }
        let bt = self.cfg.block_tokens;
        let p = self
            .tables
            .get(&parent)
            .ok_or_else(|| anyhow::anyhow!("fork: unknown parent {parent}"))?;
        if !p.evicted_slots.is_empty() {
            bail!(
                "fork: parent {parent} has evicted blocks — a child cannot \
                 share rows whose content was evicted"
            );
        }
        let w = p.rows_written;
        let full = w / bt;
        if n_tokens < w {
            bail!("fork reservation {n_tokens} smaller than parent rows {w}");
        }
        let need = self.blocks_for(n_tokens);
        let fresh = need - full;
        if self.pool.free.len() < fresh {
            bail!(
                "KV cache full on fork: need {fresh} fresh blocks, free {}",
                self.pool.free.len()
            );
        }
        let shared_blocks: Vec<BlockId> = p.blocks[..full].to_vec();
        let published: Vec<(usize, BlockId)> = (p.shared_rows / bt..full)
            .map(|i| (i, p.blocks[i]))
            .collect();
        let parent_t = self.tables.get_mut(&parent).expect("parent checked");
        parent_t.shared_rows = parent_t.shared_rows.max(full * bt);
        let mut t = BlockTable {
            n_tokens,
            shared_rows: full * bt,
            ..Default::default()
        };
        for &b in &shared_blocks {
            self.pool.retain(b);
            t.blocks.push(b);
        }
        for _ in 0..fresh {
            t.blocks.push(self.pool.alloc().expect(
                "pool accounting: the free-block check above guarantees \
                 `fresh` free blocks"));
        }
        self.tables.insert(child, t);
        Ok(ForkGrant {
            shared_blocks,
            shared_rows: full * bt,
            cow_split: w % bt != 0,
            published,
        })
    }

    /// Grow a sequence by `added` tokens (decode); allocates new blocks at
    /// block boundaries.
    pub fn extend(&mut self, seq: SeqId, added: usize) -> Result<()> {
        let bt = self.cfg.block_tokens;
        let t = self
            .tables
            .get_mut(&seq)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {seq}"))?;
        let new_total = t.n_tokens + added;
        let need = new_total.div_ceil(bt);
        // evicted slots still occupy their position range: fresh blocks
        // are only needed past the table's full slot span
        let extra = need.saturating_sub(t.slot_span());
        if self.pool.free.len() < extra {
            bail!("KV cache full on extend of sequence {seq}");
        }
        for _ in 0..extra {
            t.blocks.push(self.pool.alloc().expect(
                "pool accounting: the free-length check above guarantees \
                 `extra` free blocks"));
        }
        t.n_tokens = new_total;
        Ok(())
    }

    /// Record the cache rows the engine has physically written for `seq`.
    /// Fails if the sequence is unknown or the arena outgrew the logical
    /// reservation — either means the two accountings diverged.
    pub fn commit_rows(&mut self, seq: SeqId, rows: usize) -> Result<()> {
        let t = self
            .tables
            .get_mut(&seq)
            .ok_or_else(|| anyhow::anyhow!("commit_rows: unknown sequence {seq}"))?;
        if rows > t.n_tokens {
            bail!(
                "sequence {seq} wrote {rows} rows but reserved only {} tokens",
                t.n_tokens
            );
        }
        t.rows_written = rows;
        Ok(())
    }

    /// Physically written rows for `seq`, if it is allocated.
    pub fn rows_written(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|t| t.rows_written)
    }

    /// Sequences currently holding block reservations, in id order —
    /// the logical-side half of the accounting contract the engine
    /// auditor cross-checks against the engine's physical row map.
    pub fn live_seqs(&self) -> Vec<SeqId> {
        self.tables.keys().copied().collect()
    }

    /// Drop one reference from every block in `seq`'s table. Returns the
    /// blocks that actually freed (refcount hit 0) — the scheduler hands
    /// them to `Engine::drop_blocks` so the shared prefix store and the
    /// pool free together. Freed blocks are deregistered from the prefix
    /// tree in the same call (weak registration: no persistent cache).
    pub fn release(&mut self, seq: SeqId) -> Vec<BlockId> {
        let mut freed = Vec::new();
        if let Some(t) = self.tables.remove(&seq) {
            for b in t.blocks {
                if self.pool.release(b) {
                    self.tree.deregister(b);
                    freed.push(b);
                }
            }
        }
        freed
    }

    /// Evict the block at position-slot `slot` of `seq`, freeing it
    /// whole back to the pool (ISSUE 10). Refused — with an error, so
    /// the caller can count `refused_shared` — when the block is shared
    /// (refcount > 1), registered in the prefix tree, inside the
    /// copy-on-write shared region, not yet fully written, already
    /// evicted, or out of range. On success the freed [`BlockId`] is
    /// returned; the caller must zero the engine mirror rows
    /// (`Engine::evict_rows`) for the slot's `[slot*bt, (slot+1)*bt)`
    /// position range.
    pub fn evict_slot(&mut self, seq: SeqId, slot: usize)
        -> Result<BlockId> {
        let bt = self.cfg.block_tokens;
        let t = self
            .tables
            .get(&seq)
            .ok_or_else(|| anyhow::anyhow!("evict_slot: unknown sequence {seq}"))?;
        let idx = t.live_index(slot).ok_or_else(|| {
            anyhow::anyhow!(
                "evict_slot: seq {seq} slot {slot} is evicted or out of \
                 range ({} slots)",
                t.slot_span()
            )
        })?;
        if (slot + 1) * bt > t.rows_written {
            bail!(
                "evict_slot: seq {seq} slot {slot} not fully written \
                 ({} rows)",
                t.rows_written
            );
        }
        if slot * bt < t.shared_rows {
            bail!(
                "evict_slot: seq {seq} slot {slot} is inside the shared \
                 prefix region ({} rows)",
                t.shared_rows
            );
        }
        let b = t.blocks[idx];
        if self.pool.refs[b] > 1 {
            bail!("evict_slot: seq {seq} block {b} is shared (refcount {})",
                  self.pool.refs[b]);
        }
        if self.tree.is_registered(b) {
            bail!("evict_slot: seq {seq} block {b} is tree-registered");
        }
        let t = self.tables.get_mut(&seq).expect("table checked above");
        t.blocks.remove(idx);
        let at = t.evicted_slots.partition_point(|&e| e < slot);
        t.evicted_slots.insert(at, slot);
        let freed = self.pool.release(b);
        debug_assert!(freed, "refcount-1 block must free on release");
        Ok(b)
    }

    /// Live (non-evicted) blocks held by `seq`.
    pub fn live_blocks(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|t| t.blocks.len())
    }

    /// Position-slots of `seq` currently holding live blocks, ascending.
    pub fn live_slots(&self, seq: SeqId) -> Option<Vec<usize>> {
        self.tables.get(&seq).map(|t| t.live_slots())
    }

    /// Position-slots of `seq` whose block was evicted, ascending.
    pub fn evicted_slots(&self, seq: SeqId) -> Option<Vec<usize>> {
        self.tables.get(&seq).map(|t| t.evicted_slots.clone())
    }

    /// Rows of `seq` covered by evicted blocks — the logical half of the
    /// evicted-rows ledger the auditor reconciles against
    /// `Engine::evicted_rows_of`.
    pub fn evicted_rows(&self, seq: SeqId) -> Option<usize> {
        self.tables
            .get(&seq)
            .map(|t| t.evicted_slots.len() * self.cfg.block_tokens)
    }

    pub fn seq_tokens(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|t| t.n_tokens)
    }

    /// The block table of a live sequence (auditor surface).
    pub fn table_blocks(&self, seq: SeqId) -> Option<Vec<BlockId>> {
        self.tables.get(&seq).map(|t| t.blocks.clone())
    }

    /// Rows `seq` addresses through possibly-shared blocks.
    pub fn shared_rows(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|t| t.shared_rows)
    }

    /// Current reference count of a block (0 == free).
    pub fn block_ref(&self, b: BlockId) -> u32 {
        self.pool.refs.get(b).copied().unwrap_or(0)
    }

    pub fn is_block_registered(&self, b: BlockId) -> bool {
        self.tree.is_registered(b)
    }

    /// Pool-level sharing gauges: blocks referenced 2+ times and the
    /// bytes sharing saves vs one private copy per reference.
    pub fn sharing_stats(&self) -> SharingStats {
        let shared_blocks =
            self.pool.refs.iter().filter(|&&r| r >= 2).count();
        let extra_refs: u64 = self
            .pool
            .refs
            .iter()
            .map(|&r| u64::from(r.saturating_sub(1)))
            .sum();
        SharingStats {
            shared_blocks,
            dedup_bytes: extra_refs as f64 * self.cfg.block_bytes(),
            prefix_nodes: self.tree.len(),
            blocks_used: self.pool.used(),
            blocks_total: self.pool.total,
        }
    }

    /// Full refcount/table/tree consistency audit. Empty == consistent.
    /// Checks, bidirectionally: refcounts equal the number of tables
    /// holding each block; the free list is exactly the ref==0 blocks
    /// with no duplicates; every tree-registered block is live and held;
    /// and the CoW privacy invariant — blocks past a table's
    /// `shared_rows` are refcount-1 and unregistered (no one ever
    /// aliases a block a sequence may still write).
    pub fn refcount_violations(&self) -> Vec<String> {
        let mut out = Vec::new();
        let bt = self.cfg.block_tokens;
        let mut expected = vec![0u32; self.pool.total];
        for (id, t) in &self.tables {
            if t.shared_rows % bt != 0 {
                out.push(format!(
                    "seq {id}: shared_rows {} not block-aligned",
                    t.shared_rows));
            }
            if t.shared_rows > t.blocks.len() * bt {
                out.push(format!(
                    "seq {id}: shared_rows {} exceeds table ({} blocks)",
                    t.shared_rows,
                    t.blocks.len()));
            }
            // slot conservation: evicted slots keep their position range,
            // so live + evicted always tiles the reservation exactly
            if t.slot_span() != t.n_tokens.div_ceil(bt) {
                out.push(format!(
                    "seq {id}: {} live + {} evicted slots != ceil({} / {bt})",
                    t.blocks.len(),
                    t.evicted_slots.len(),
                    t.n_tokens));
            }
            if t.evicted_slots.windows(2).any(|w| w[0] >= w[1]) {
                out.push(format!(
                    "seq {id}: evicted slots not sorted/unique: {:?}",
                    t.evicted_slots));
            }
            if t.evicted_slots.iter().any(|&s| s * bt < t.shared_rows) {
                out.push(format!(
                    "seq {id}: evicted slot inside shared region \
                     ({} rows): {:?}",
                    t.shared_rows,
                    t.evicted_slots));
            }
            for (i, &b) in t.blocks.iter().enumerate() {
                if b >= self.pool.total {
                    out.push(format!("seq {id}: block {b} out of pool"));
                    continue;
                }
                expected[b] += 1;
                if i >= t.shared_rows / bt {
                    if self.pool.refs[b] != 1 {
                        out.push(format!(
                            "CoW privacy: seq {id} writable block {b} has \
                             refcount {}",
                            self.pool.refs[b]));
                    }
                    if self.tree.is_registered(b) {
                        out.push(format!(
                            "CoW privacy: seq {id} writable block {b} is \
                             tree-registered"));
                    }
                }
            }
        }
        for (b, (&have, &want)) in
            self.pool.refs.iter().zip(&expected).enumerate()
        {
            if have != want {
                out.push(format!(
                    "block {b}: refcount {have} but {want} table refs"));
            }
        }
        let mut on_free = vec![false; self.pool.total];
        for &b in &self.pool.free {
            if on_free[b] {
                out.push(format!("block {b} on the free list twice"));
            }
            on_free[b] = true;
        }
        for (b, &free) in on_free.iter().enumerate() {
            if free != (self.pool.refs[b] == 0) {
                out.push(format!(
                    "block {b}: free-list {free} vs refcount {}",
                    self.pool.refs[b]));
            }
        }
        for b in self.tree.registered() {
            if self.pool.refs.get(b).copied().unwrap_or(0) == 0 {
                out.push(format!(
                    "prefix tree holds freed block {b} (leaked \
                     registration)"));
            }
        }
        out
    }

    pub fn stats(&self) -> CacheStats {
        let bt = self.cfg.block_tokens as f64;
        CacheStats {
            seqs: self.tables.len(),
            tokens: self.tables.values().map(|t| t.n_tokens).sum(),
            tokens_written: self.tables.values().map(|t| t.rows_written).sum(),
            k_blocks_used: self.pool.used(),
            v_blocks_used: self.pool.used(),
            k_bytes_used: self.pool.used() as f64 * bt
                * self.cfg.k_bytes_per_token(),
            v_bytes_used: self.pool.used() as f64 * bt
                * self.cfg.v_bytes_per_token(),
            k_bytes_capacity: self.pool.total as f64 * bt
                * self.cfg.k_bytes_per_token(),
            v_bytes_capacity: self.pool.total as f64 * bt
                * self.cfg.v_bytes_per_token(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(k_dims: usize, budget_mb: f64) -> KvCacheConfig {
        KvCacheConfig {
            n_layers: 4,
            k_dims,
            v_dims: 128,
            block_tokens: 16,
            bytes_per_el_k: 2.0,
            bytes_per_el_v: 2.0,
            budget_bytes: budget_mb * 1e6,
        }
    }

    #[test]
    fn thin_keys_increase_token_capacity() {
        let full = KvCacheManager::new(cfg(128, 8.0));
        let thin = KvCacheManager::new(cfg(32, 8.0));
        let (cf, ct) = (
            full.cfg.token_capacity() as f64,
            thin.cfg.token_capacity() as f64,
        );
        // paper: K/4 -> total KV per token falls 37.5% -> capacity x1.6
        assert!((ct / cf - 1.6).abs() < 0.02, "ratio {}", ct / cf);
    }

    #[test]
    fn alloc_extend_release_roundtrip() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        let cap0 = m.free_token_capacity();
        m.allocate(1, 100).unwrap();
        m.allocate(2, 50).unwrap();
        assert_eq!(m.stats().seqs, 2);
        assert_eq!(m.seq_tokens(1), Some(100));
        m.extend(1, 60).unwrap();
        assert_eq!(m.seq_tokens(1), Some(160));
        assert!(m.free_token_capacity() < cap0);
        m.release(1);
        m.release(2);
        assert_eq!(m.free_token_capacity(), cap0);
        assert_eq!(m.stats().tokens, 0);
    }

    #[test]
    fn admission_control_rejects_over_budget() {
        let mut m = KvCacheManager::new(cfg(128, 0.5));
        let cap = m.free_token_capacity();
        assert!(m.allocate(1, cap + 16).is_err());
        m.allocate(2, cap).unwrap();
        assert!(!m.can_admit(16));
        assert!(m.allocate(3, 16).is_err());
    }

    #[test]
    fn extend_allocates_only_at_block_boundaries() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 10).unwrap(); // 1 block of 16
        let used0 = m.stats().k_blocks_used;
        m.extend(1, 5).unwrap(); // 15 tokens, still 1 block
        assert_eq!(m.stats().k_blocks_used, used0);
        m.extend(1, 2).unwrap(); // 17 tokens -> 2 blocks
        assert_eq!(m.stats().k_blocks_used, used0 + 1);
    }

    #[test]
    fn k_fraction_reflects_thinness() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 64).unwrap();
        let f = m.stats().k_fraction();
        assert!((f - 32.0 / 160.0).abs() < 1e-9, "k fraction {f}");
    }

    #[test]
    fn quantization_composes_with_thin_keys() {
        // 4x dims (thin) * 4x width (int4 vs bf16) = 16x K bytes/token.
        let bf16_full = cfg(128, 8.0);
        let mut int4_thin = cfg(32, 8.0);
        int4_thin.bytes_per_el_k = 0.5;
        let ratio = bf16_full.k_bytes_per_token() / int4_thin.k_bytes_per_token();
        assert!((ratio - 16.0).abs() < 1e-9);
    }

    #[test]
    fn commit_rows_tracks_physical_writes() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 100).unwrap();
        assert_eq!(m.rows_written(1), Some(0));
        m.commit_rows(1, 40).unwrap();
        assert_eq!(m.rows_written(1), Some(40));
        assert_eq!(m.stats().tokens_written, 40);
        m.release(1);
        assert_eq!(m.rows_written(1), None);
        assert_eq!(m.stats().tokens_written, 0);
    }

    #[test]
    fn commit_rows_rejects_divergence() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        assert!(m.commit_rows(1, 1).is_err(), "unknown sequence");
        m.allocate(1, 32).unwrap();
        assert!(m.commit_rows(1, 33).is_err(), "arena outgrew reservation");
        assert!(m.commit_rows(1, 32).is_ok());
    }

    #[test]
    fn total_capacity_covers_empty_cache_admission() {
        let mut m = KvCacheManager::new(cfg(128, 0.5));
        let total = m.total_token_capacity();
        assert_eq!(total, m.free_token_capacity());
        m.allocate(1, 32).unwrap();
        assert_eq!(m.total_token_capacity(), total);
        assert!(m.free_token_capacity() < total);
    }

    #[test]
    fn double_allocate_rejected() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 16).unwrap();
        assert!(m.allocate(1, 16).is_err());
    }

    // --- ISSUE 8: refcounted sharing -----------------------------------

    fn prompt(n: usize, seed: i32) -> Vec<i32> {
        (0..n as i32).map(|i| i * 7 + seed).collect()
    }

    #[test]
    fn seal_then_allocate_prompt_adopts_shared_blocks() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        let p = prompt(40, 0); // 2 full blocks + 8-token tail
        m.allocate_prompt(1, &p, 48, true).unwrap();
        m.commit_rows(1, 40).unwrap();
        let sealed = m.seal_prefix(1, &p).unwrap();
        assert_eq!(sealed.shared_rows, 32);
        assert_eq!(sealed.registered.len(), 2);
        assert_eq!(sealed.blocks.len(), 2);

        let used0 = m.stats().k_blocks_used;
        let grant = m.allocate_prompt(2, &p, 48, true).unwrap();
        assert_eq!(grant.matched_rows, 32);
        assert_eq!(grant.matched_blocks, sealed.blocks);
        // only the private tail allocated fresh: 3 needed, 2 matched
        assert_eq!(m.stats().k_blocks_used, used0 + 1);
        for &b in &grant.matched_blocks {
            assert_eq!(m.block_ref(b), 2);
        }
        assert!(m.refcount_violations().is_empty(),
                "{:?}", m.refcount_violations());
    }

    #[test]
    fn partial_tail_block_is_never_shared() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        // prompt an exact multiple of block_tokens: the last full block
        // still may not be fully matched away — at least one token must
        // prefill to produce first-token logits
        let p = prompt(32, 3);
        m.allocate_prompt(1, &p, 40, true).unwrap();
        m.commit_rows(1, 32).unwrap();
        m.seal_prefix(1, &p).unwrap();
        let grant = m.allocate_prompt(2, &p, 40, true).unwrap();
        assert_eq!(grant.matched_rows, 16, "matched past (p-1)/bt blocks");
    }

    #[test]
    fn divergent_prompt_shares_only_common_prefix() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        let a = prompt(48, 0);
        let mut b = a.clone();
        b[20] += 1; // diverge inside the second block
        m.allocate_prompt(1, &a, 64, true).unwrap();
        m.commit_rows(1, 48).unwrap();
        m.seal_prefix(1, &a).unwrap();
        let grant = m.allocate_prompt(2, &b, 64, true).unwrap();
        assert_eq!(grant.matched_rows, 16, "only the first block matches");
        m.commit_rows(2, 48).unwrap();
        // sealing the divergent prompt registers its own suffix path
        let sealed = m.seal_prefix(2, &b).unwrap();
        assert_eq!(sealed.shared_rows, 48);
        assert_eq!(sealed.registered.len(), 2);
        assert!(m.refcount_violations().is_empty(),
                "{:?}", m.refcount_violations());
    }

    #[test]
    fn release_frees_refcounts_and_deregisters_weakly() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        let cap0 = m.free_token_capacity();
        let p = prompt(40, 1);
        m.allocate_prompt(1, &p, 48, true).unwrap();
        m.commit_rows(1, 40).unwrap();
        let sealed = m.seal_prefix(1, &p).unwrap();
        m.allocate_prompt(2, &p, 48, true).unwrap();
        // donor leaves: shared blocks survive on the consumer's refcount
        let freed = m.release(1);
        assert_eq!(freed.len(), 1, "only the donor's private tail freed");
        for &b in &sealed.blocks {
            assert_eq!(m.block_ref(b), 1);
            assert!(m.is_block_registered(b), "registration must survive");
        }
        // a third prompt still hits the (consumer-held) prefix
        let grant = m.allocate_prompt(3, &p, 48, true).unwrap();
        assert_eq!(grant.matched_rows, 32);
        // last holders leave: blocks free AND the tree forgets them
        let mut freed: Vec<BlockId> = m.release(2);
        freed.extend(m.release(3));
        for &b in &sealed.blocks {
            assert!(freed.contains(&b));
            assert!(!m.is_block_registered(b), "weak registration leaked");
        }
        assert_eq!(m.free_token_capacity(), cap0, "blocks leaked");
        assert_eq!(m.sharing_stats().prefix_nodes, 0);
        assert!(m.refcount_violations().is_empty(),
                "{:?}", m.refcount_violations());
    }

    #[test]
    fn fork_shares_full_blocks_and_cow_splits_the_tail() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        let p = prompt(20, 2);
        m.allocate_prompt(1, &p, 64, true).unwrap();
        m.commit_rows(1, 42).unwrap(); // 2 full blocks + 10-row tail
        let used0 = m.stats().k_blocks_used;
        let grant = m.fork(1, 2, 64).unwrap();
        assert_eq!(grant.shared_rows, 32);
        assert_eq!(grant.shared_blocks.len(), 2);
        assert!(grant.cow_split, "partial tail must copy-on-write");
        assert_eq!(grant.published.len(), 2,
                   "previously private full blocks become shared");
        // child allocated 4 blocks total, 2 shared: only 2 fresh
        assert_eq!(m.stats().k_blocks_used, used0 + 2);
        for &b in &grant.shared_blocks {
            assert_eq!(m.block_ref(b), 2);
        }
        assert!(m.refcount_violations().is_empty(),
                "{:?}", m.refcount_violations());
        m.release(2);
        assert!(m.refcount_violations().is_empty());
        let freed = m.release(1);
        assert!(freed.len() >= 3);
        assert_eq!(m.free_token_capacity(), m.total_token_capacity());
    }

    #[test]
    fn sharing_admits_more_than_private_allocation() {
        let mut m = KvCacheManager::new(cfg(128, 0.5));
        let total = m.total_token_capacity();
        let p = prompt(total - 32, 4);
        m.allocate_prompt(1, &p, total - 16, true).unwrap();
        m.commit_rows(1, p.len()).unwrap();
        m.seal_prefix(1, &p).unwrap();
        // a private twin can never fit, but the sharing path can
        assert!(!m.can_admit(total - 16));
        assert!(m.can_admit_prompt(&p, total - 16, true));
        assert!(!m.can_admit_prompt(&p, total - 16, false));
        m.allocate_prompt(2, &p, total - 16, true).unwrap();
        let s = m.sharing_stats();
        assert!(s.shared_blocks > 0);
        assert!(s.dedup_bytes > 0.0);
        assert!(m.refcount_violations().is_empty(),
                "{:?}", m.refcount_violations());
    }

    // --- ISSUE 10: bounded-cache eviction ------------------------------

    #[test]
    fn evict_slot_frees_whole_blocks_and_conserves_slots() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 80).unwrap(); // 5 blocks of 16
        m.commit_rows(1, 80).unwrap();
        let used0 = m.stats().k_blocks_used;
        let b = m.evict_slot(1, 1).unwrap();
        assert_eq!(m.block_ref(b), 0, "evicted block must free");
        assert_eq!(m.stats().k_blocks_used, used0 - 1);
        assert_eq!(m.live_blocks(1), Some(4));
        assert_eq!(m.evicted_rows(1), Some(16));
        assert_eq!(m.live_slots(1).unwrap(), vec![0, 2, 3, 4]);
        // double-evict refused; pool accounting stays balanced
        assert!(m.evict_slot(1, 1).is_err());
        assert!(m.refcount_violations().is_empty(),
                "{:?}", m.refcount_violations());
        m.release(1);
        assert_eq!(m.free_token_capacity(), m.total_token_capacity(),
                   "release after eviction must not double-free");
    }

    #[test]
    fn evict_refuses_shared_registered_and_unwritten() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        let p = prompt(40, 7); // 2 full blocks + tail
        m.allocate_prompt(1, &p, 80, true).unwrap();
        m.commit_rows(1, 40).unwrap();
        m.seal_prefix(1, &p).unwrap();
        m.allocate_prompt(2, &p, 80, true).unwrap();
        // slot 0: shared region (refcount 2 via seq 2, tree-registered)
        assert!(m.evict_slot(1, 0).is_err(), "shared prefix must pin");
        // slot 3: reserved but unwritten
        assert!(m.evict_slot(1, 3).is_err(), "unwritten slot must pin");
        // slot 9: out of range
        assert!(m.evict_slot(1, 9).is_err());
        // slot 2 (the written private tail block) is evictable
        m.commit_rows(1, 48).unwrap();
        m.evict_slot(1, 2).unwrap();
        assert!(m.refcount_violations().is_empty(),
                "{:?}", m.refcount_violations());
    }

    #[test]
    fn extend_accounts_for_evicted_slots() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 48).unwrap(); // 3 blocks
        m.commit_rows(1, 48).unwrap();
        m.evict_slot(1, 1).unwrap();
        let used0 = m.stats().k_blocks_used;
        // growing within the existing slot span allocates nothing (the
        // evicted slot still occupies its position range)
        assert_eq!(m.seq_tokens(1), Some(48));
        m.extend(1, 16).unwrap(); // 64 tokens -> slot 3, one fresh block
        assert_eq!(m.stats().k_blocks_used, used0 + 1);
        assert_eq!(m.live_slots(1).unwrap(), vec![0, 2, 3]);
        assert!(m.refcount_violations().is_empty(),
                "{:?}", m.refcount_violations());
    }

    #[test]
    fn fork_of_evicted_sequence_refused() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 64).unwrap();
        m.commit_rows(1, 64).unwrap();
        m.evict_slot(1, 1).unwrap();
        assert!(m.fork(1, 2, 64).is_err(),
                "a child cannot share evicted rows");
    }

    #[test]
    fn refcount_violation_detection_catches_seeded_corruption() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        let p = prompt(40, 5);
        m.allocate_prompt(1, &p, 48, true).unwrap();
        assert!(m.refcount_violations().is_empty());
        // seed: drop a refcount without touching the table
        m.pool.refs[m.tables[&1].blocks[0]] += 1;
        let v = m.refcount_violations();
        assert!(v.iter().any(|s| s.contains("refcount")), "{v:?}");
    }
}
