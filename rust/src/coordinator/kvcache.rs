//! Paged KV cache with **split K/V pools** — the paper's key asymmetry as a
//! memory manager.
//!
//! Standard paged attention (vLLM) allocates unified KV blocks. Factored
//! keys make K entries `r/d` the size of V entries, so we keep two block
//! pools with independent per-token byte costs; capacity accounting is
//! exact and doubles as the Table 10 calculator. Quantized deployments are
//! modeled by the per-element byte widths (bf16 = 2, int8 = 1, int4 = 0.5),
//! which is how the 16x composed compression of §6 is exercised.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

pub type SeqId = u64;

#[derive(Clone, Debug)]
pub struct KvCacheConfig {
    pub n_layers: usize,
    /// K dims per token per layer (n_kv_heads * d_qk_head) — THIN.
    pub k_dims: usize,
    /// V dims per token per layer (n_kv_heads * d_v_head) — FULL.
    pub v_dims: usize,
    pub block_tokens: usize,
    pub bytes_per_el_k: f64,
    pub bytes_per_el_v: f64,
    /// Total budget for K+V pools, in bytes.
    pub budget_bytes: f64,
}

impl KvCacheConfig {
    pub fn k_bytes_per_token(&self) -> f64 {
        self.n_layers as f64 * self.k_dims as f64 * self.bytes_per_el_k
    }

    pub fn v_bytes_per_token(&self) -> f64 {
        self.n_layers as f64 * self.v_dims as f64 * self.bytes_per_el_v
    }

    pub fn bytes_per_token(&self) -> f64 {
        self.k_bytes_per_token() + self.v_bytes_per_token()
    }

    /// Token capacity implied by the budget.
    pub fn token_capacity(&self) -> usize {
        (self.budget_bytes / self.bytes_per_token()) as usize
    }
}

#[derive(Clone, Debug, Default)]
struct BlockTable {
    n_tokens: usize,
    /// Cache rows the engine has physically written for this sequence —
    /// mirrored from `Engine::rows` by the scheduler so the logical
    /// reservation and the physical arena stay in agreement.
    rows_written: usize,
    k_blocks: Vec<usize>,
    v_blocks: Vec<usize>,
}

/// One pool of fixed-size blocks (indices only; storage lives in the
/// engine's arenas / parked buffers).
#[derive(Clone, Debug)]
struct Pool {
    total: usize,
    free: Vec<usize>,
}

impl Pool {
    fn new(total: usize) -> Pool {
        Pool { total, free: (0..total).rev().collect() }
    }

    fn used(&self) -> usize {
        self.total - self.free.len()
    }
}

#[derive(Clone, Debug)]
pub struct KvCacheManager {
    pub cfg: KvCacheConfig,
    k_pool: Pool,
    v_pool: Pool,
    tables: BTreeMap<SeqId, BlockTable>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct CacheStats {
    pub seqs: usize,
    pub tokens: usize,
    /// Rows physically written by the engine, summed over live sequences.
    pub tokens_written: usize,
    pub k_blocks_used: usize,
    pub v_blocks_used: usize,
    pub k_bytes_used: f64,
    pub v_bytes_used: f64,
    pub k_bytes_capacity: f64,
    pub v_bytes_capacity: f64,
}

impl CacheStats {
    pub fn bytes_used(&self) -> f64 {
        self.k_bytes_used + self.v_bytes_used
    }

    /// K share of live cache bytes — ~r/(r+d) under factored keys.
    pub fn k_fraction(&self) -> f64 {
        let t = self.bytes_used();
        if t == 0.0 { 0.0 } else { self.k_bytes_used / t }
    }
}

impl KvCacheManager {
    /// Split the budget so both pools cover the same token capacity (a
    /// token always needs one K slot *and* one V slot).
    pub fn new(cfg: KvCacheConfig) -> KvCacheManager {
        let tokens = cfg.token_capacity();
        let blocks = tokens / cfg.block_tokens;
        KvCacheManager {
            k_pool: Pool::new(blocks),
            v_pool: Pool::new(blocks),
            tables: BTreeMap::new(),
            cfg,
        }
    }

    fn blocks_for(&self, n_tokens: usize) -> usize {
        n_tokens.div_ceil(self.cfg.block_tokens)
    }

    /// Free K+V blocks available for new sequences, in tokens.
    pub fn free_token_capacity(&self) -> usize {
        self.k_pool.free.len().min(self.v_pool.free.len())
            * self.cfg.block_tokens
    }

    /// Total K+V block capacity in tokens — the largest reservation that
    /// could ever be admitted, even into an empty cache.
    pub fn total_token_capacity(&self) -> usize {
        self.k_pool.total.min(self.v_pool.total) * self.cfg.block_tokens
    }

    pub fn can_admit(&self, n_tokens: usize) -> bool {
        let need = self.blocks_for(n_tokens);
        self.k_pool.free.len() >= need && self.v_pool.free.len() >= need
    }

    /// Reserve blocks for a new sequence of `n_tokens` (prompt + headroom).
    pub fn allocate(&mut self, seq: SeqId, n_tokens: usize) -> Result<()> {
        if self.tables.contains_key(&seq) {
            bail!("sequence {seq} already allocated");
        }
        if !self.can_admit(n_tokens) {
            bail!(
                "KV cache full: need {} blocks, free k={} v={}",
                self.blocks_for(n_tokens),
                self.k_pool.free.len(),
                self.v_pool.free.len()
            );
        }
        let need = self.blocks_for(n_tokens);
        let mut t = BlockTable { n_tokens, ..Default::default() };
        for _ in 0..need {
            t.k_blocks.push(self.k_pool.free.pop()
                .expect("pool accounting: the free-block check above \
                         guarantees `need` free k blocks"));
            t.v_blocks.push(self.v_pool.free.pop()
                .expect("pool accounting: the free-block check above \
                         guarantees `need` free v blocks"));
        }
        self.tables.insert(seq, t);
        Ok(())
    }

    /// Grow a sequence by `added` tokens (decode); allocates new blocks at
    /// block boundaries.
    pub fn extend(&mut self, seq: SeqId, added: usize) -> Result<()> {
        let bt = self.cfg.block_tokens;
        let t = self
            .tables
            .get_mut(&seq)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {seq}"))?;
        let new_total = t.n_tokens + added;
        let need = new_total.div_ceil(bt);
        let extra = need.saturating_sub(t.k_blocks.len());
        if self.k_pool.free.len() < extra || self.v_pool.free.len() < extra {
            bail!("KV cache full on extend of sequence {seq}");
        }
        for _ in 0..extra {
            t.k_blocks.push(self.k_pool.free.pop()
                .expect("pool accounting: the free-length check above \
                         guarantees `extra` free k blocks"));
            t.v_blocks.push(self.v_pool.free.pop()
                .expect("pool accounting: the free-length check above \
                         guarantees `extra` free v blocks"));
        }
        t.n_tokens = new_total;
        Ok(())
    }

    /// Record the cache rows the engine has physically written for `seq`.
    /// Fails if the sequence is unknown or the arena outgrew the logical
    /// reservation — either means the two accountings diverged.
    pub fn commit_rows(&mut self, seq: SeqId, rows: usize) -> Result<()> {
        let t = self
            .tables
            .get_mut(&seq)
            .ok_or_else(|| anyhow::anyhow!("commit_rows: unknown sequence {seq}"))?;
        if rows > t.n_tokens {
            bail!(
                "sequence {seq} wrote {rows} rows but reserved only {} tokens",
                t.n_tokens
            );
        }
        t.rows_written = rows;
        Ok(())
    }

    /// Physically written rows for `seq`, if it is allocated.
    pub fn rows_written(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|t| t.rows_written)
    }

    /// Sequences currently holding block reservations, in id order —
    /// the logical-side half of the accounting contract the engine
    /// auditor cross-checks against the engine's physical row map.
    pub fn live_seqs(&self) -> Vec<SeqId> {
        self.tables.keys().copied().collect()
    }

    pub fn release(&mut self, seq: SeqId) {
        if let Some(t) = self.tables.remove(&seq) {
            self.k_pool.free.extend(t.k_blocks);
            self.v_pool.free.extend(t.v_blocks);
        }
    }

    pub fn seq_tokens(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|t| t.n_tokens)
    }

    pub fn stats(&self) -> CacheStats {
        let bt = self.cfg.block_tokens as f64;
        CacheStats {
            seqs: self.tables.len(),
            tokens: self.tables.values().map(|t| t.n_tokens).sum(),
            tokens_written: self.tables.values().map(|t| t.rows_written).sum(),
            k_blocks_used: self.k_pool.used(),
            v_blocks_used: self.v_pool.used(),
            k_bytes_used: self.k_pool.used() as f64 * bt
                * self.cfg.k_bytes_per_token(),
            v_bytes_used: self.v_pool.used() as f64 * bt
                * self.cfg.v_bytes_per_token(),
            k_bytes_capacity: self.k_pool.total as f64 * bt
                * self.cfg.k_bytes_per_token(),
            v_bytes_capacity: self.v_pool.total as f64 * bt
                * self.cfg.v_bytes_per_token(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(k_dims: usize, budget_mb: f64) -> KvCacheConfig {
        KvCacheConfig {
            n_layers: 4,
            k_dims,
            v_dims: 128,
            block_tokens: 16,
            bytes_per_el_k: 2.0,
            bytes_per_el_v: 2.0,
            budget_bytes: budget_mb * 1e6,
        }
    }

    #[test]
    fn thin_keys_increase_token_capacity() {
        let full = KvCacheManager::new(cfg(128, 8.0));
        let thin = KvCacheManager::new(cfg(32, 8.0));
        let (cf, ct) = (
            full.cfg.token_capacity() as f64,
            thin.cfg.token_capacity() as f64,
        );
        // paper: K/4 -> total KV per token falls 37.5% -> capacity x1.6
        assert!((ct / cf - 1.6).abs() < 0.02, "ratio {}", ct / cf);
    }

    #[test]
    fn alloc_extend_release_roundtrip() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        let cap0 = m.free_token_capacity();
        m.allocate(1, 100).unwrap();
        m.allocate(2, 50).unwrap();
        assert_eq!(m.stats().seqs, 2);
        assert_eq!(m.seq_tokens(1), Some(100));
        m.extend(1, 60).unwrap();
        assert_eq!(m.seq_tokens(1), Some(160));
        assert!(m.free_token_capacity() < cap0);
        m.release(1);
        m.release(2);
        assert_eq!(m.free_token_capacity(), cap0);
        assert_eq!(m.stats().tokens, 0);
    }

    #[test]
    fn admission_control_rejects_over_budget() {
        let mut m = KvCacheManager::new(cfg(128, 0.5));
        let cap = m.free_token_capacity();
        assert!(m.allocate(1, cap + 16).is_err());
        m.allocate(2, cap).unwrap();
        assert!(!m.can_admit(16));
        assert!(m.allocate(3, 16).is_err());
    }

    #[test]
    fn extend_allocates_only_at_block_boundaries() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 10).unwrap(); // 1 block of 16
        let used0 = m.stats().k_blocks_used;
        m.extend(1, 5).unwrap(); // 15 tokens, still 1 block
        assert_eq!(m.stats().k_blocks_used, used0);
        m.extend(1, 2).unwrap(); // 17 tokens -> 2 blocks
        assert_eq!(m.stats().k_blocks_used, used0 + 1);
    }

    #[test]
    fn k_fraction_reflects_thinness() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 64).unwrap();
        let f = m.stats().k_fraction();
        assert!((f - 32.0 / 160.0).abs() < 1e-9, "k fraction {f}");
    }

    #[test]
    fn quantization_composes_with_thin_keys() {
        // 4x dims (thin) * 4x width (int4 vs bf16) = 16x K bytes/token.
        let bf16_full = cfg(128, 8.0);
        let mut int4_thin = cfg(32, 8.0);
        int4_thin.bytes_per_el_k = 0.5;
        let ratio = bf16_full.k_bytes_per_token() / int4_thin.k_bytes_per_token();
        assert!((ratio - 16.0).abs() < 1e-9);
    }

    #[test]
    fn commit_rows_tracks_physical_writes() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 100).unwrap();
        assert_eq!(m.rows_written(1), Some(0));
        m.commit_rows(1, 40).unwrap();
        assert_eq!(m.rows_written(1), Some(40));
        assert_eq!(m.stats().tokens_written, 40);
        m.release(1);
        assert_eq!(m.rows_written(1), None);
        assert_eq!(m.stats().tokens_written, 0);
    }

    #[test]
    fn commit_rows_rejects_divergence() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        assert!(m.commit_rows(1, 1).is_err(), "unknown sequence");
        m.allocate(1, 32).unwrap();
        assert!(m.commit_rows(1, 33).is_err(), "arena outgrew reservation");
        assert!(m.commit_rows(1, 32).is_ok());
    }

    #[test]
    fn total_capacity_covers_empty_cache_admission() {
        let mut m = KvCacheManager::new(cfg(128, 0.5));
        let total = m.total_token_capacity();
        assert_eq!(total, m.free_token_capacity());
        m.allocate(1, 32).unwrap();
        assert_eq!(m.total_token_capacity(), total);
        assert!(m.free_token_capacity() < total);
    }

    #[test]
    fn double_allocate_rejected() {
        let mut m = KvCacheManager::new(cfg(32, 4.0));
        m.allocate(1, 16).unwrap();
        assert!(m.allocate(1, 16).is_err());
    }
}
