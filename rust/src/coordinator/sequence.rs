//! Request/sequence lifecycle: a request enters Queued, is admitted and
//! prefetched (Prefill), generates under continuous batching (Decoding),
//! and finishes on EOS / max_tokens / cache pressure.

use std::time::Instant;

pub type SeqId = u64;

/// Scheduling class (ISSUE 3): Interactive requests (chat) are admitted
/// and granted prefill chunks ahead of Batch requests (document
/// ingestion), so chat preempts a long document at a chunk boundary
/// instead of waiting out its whole prompt. Ordered: Interactive < Batch
/// in priority-queue terms (lower sorts first).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    #[default]
    Interactive,
    Batch,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqState {
    Queued,
    Decoding,
    Finished(FinishReason),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    Eos,
    CacheOverflow,
    /// Admission succeeded but the engine rejected the prefill (e.g. the
    /// prompt exceeds the prefill bucket); the KV reservation is rolled
    /// back and the request reported as rejected, never silently dropped.
    PrefillFailed,
    /// Quarantined: the sequence hit a persistent sequence-local fault
    /// (e.g. repeated corrupt-output attribution) and was evicted from
    /// the batch after retries, with the rest of the batch untouched.
    Failed,
    /// Load-shed: the router dropped the request from the waiting queue
    /// (per-class deadline exceeded under sustained faults or KV
    /// pressure) before it ever reached the engine.
    Shed,
}

#[derive(Clone, Debug)]
pub struct Sequence {
    pub id: SeqId,
    pub prompt: Vec<i32>,
    pub generated: Vec<i32>,
    pub max_new: usize,
    pub eos: Option<i32>,
    pub priority: Priority,
    pub state: SeqState,
    // timing
    pub arrived: Instant,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
}

impl Sequence {
    pub fn new(id: SeqId, prompt: Vec<i32>, max_new: usize, eos: Option<i32>)
        -> Sequence {
        assert!(!prompt.is_empty(), "empty prompt");
        Sequence {
            id,
            prompt,
            generated: Vec::new(),
            max_new,
            eos,
            priority: Priority::Interactive,
            state: SeqState::Queued,
            arrived: Instant::now(),
            first_token_at: None,
            finished_at: None,
        }
    }

    /// Builder: set the scheduling class (default Interactive).
    pub fn with_priority(mut self, priority: Priority) -> Sequence {
        self.priority = priority;
        self
    }

    /// Builder: backdate the arrival stamp. The router uses this to charge
    /// queueing delay from the TRACE arrival time rather than the submit
    /// call — without it, a request "arriving" while a monolithic prefill
    /// blocks the scheduler would get a flattering TTFT that excludes the
    /// very stall chunked prefill removes.
    pub fn with_arrival(mut self, arrived: Instant) -> Sequence {
        self.arrived = arrived;
        self
    }

    /// Total tokens whose K/V rows exist (prompt + generated).
    pub fn len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    pub fn is_empty(&self) -> bool {
        false // a sequence always has a non-empty prompt
    }

    /// Index where the NEXT generated token's K/V row will be written.
    pub fn next_pos(&self) -> usize {
        self.len()
    }

    pub fn last_token(&self) -> i32 {
        *self
            .generated
            .last()
            .unwrap_or_else(|| {
                self.prompt
                    .last()
                    .expect("sequences are constructed with a non-empty prompt")
            })
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, SeqState::Finished(_))
    }

    /// Record a generated token; returns true if the sequence finished.
    pub fn push_token(&mut self, tok: i32) -> bool {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        self.generated.push(tok);
        if Some(tok) == self.eos {
            self.finish(FinishReason::Eos);
            return true;
        }
        if self.generated.len() >= self.max_new {
            self.finish(FinishReason::MaxTokens);
            return true;
        }
        false
    }

    /// Roll the sequence back to Queued for re-admission after preemption.
    /// Clears generation *and* first-token timing, so TTFT measured after
    /// the restart reflects the re-admission, not the first admission.
    pub fn reset_for_restart(&mut self) {
        self.generated.clear();
        self.first_token_at = None;
        self.finished_at = None;
        self.state = SeqState::Queued;
    }

    pub fn finish(&mut self, why: FinishReason) {
        self.state = SeqState::Finished(why);
        self.finished_at = Some(Instant::now());
    }

    /// Copy-on-write fork (ISSUE 8): a child with its own id and
    /// generation budget that inherits the parent's entire served
    /// history (prompt + tokens generated so far) and decodes
    /// independently from here on. Timing restarts — the child's TTFT
    /// measures the fork's first divergent token, not the parent's.
    pub fn fork_as(&self, id: SeqId, max_new: usize) -> Sequence {
        Sequence {
            id,
            prompt: self.prompt.clone(),
            generated: self.generated.clone(),
            max_new: self.generated.len() + max_new,
            eos: self.eos,
            priority: self.priority,
            state: SeqState::Decoding,
            arrived: Instant::now(),
            first_token_at: None,
            finished_at: None,
        }
    }

    pub fn ttft_s(&self) -> Option<f64> {
        self.first_token_at
            .map(|t| t.duration_since(self.arrived).as_secs_f64())
    }

    pub fn e2e_s(&self) -> Option<f64> {
        self.finished_at
            .map(|t| t.duration_since(self.arrived).as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_max_tokens() {
        let mut s = Sequence::new(1, vec![5, 6], 3, None);
        assert_eq!(s.state, SeqState::Queued);
        assert_eq!(s.next_pos(), 2);
        assert!(!s.push_token(7));
        assert!(!s.push_token(8));
        assert!(s.push_token(9));
        assert_eq!(s.state, SeqState::Finished(FinishReason::MaxTokens));
        assert_eq!(s.generated, vec![7, 8, 9]);
        assert_eq!(s.len(), 5);
        assert!(s.ttft_s().is_some() && s.e2e_s().is_some());
    }

    #[test]
    fn lifecycle_eos() {
        let mut s = Sequence::new(2, vec![1], 10, Some(99));
        assert!(!s.push_token(5));
        assert!(s.push_token(99));
        assert_eq!(s.state, SeqState::Finished(FinishReason::Eos));
    }

    #[test]
    fn last_token_tracks_generation() {
        let mut s = Sequence::new(3, vec![1, 2, 3], 5, None);
        assert_eq!(s.last_token(), 3);
        s.push_token(42);
        assert_eq!(s.last_token(), 42);
        assert_eq!(s.next_pos(), 4);
    }
}

#[cfg(test)]
mod extra_tests {
    use super::*;

    #[test]
    fn cache_overflow_finish_reason() {
        let mut s = Sequence::new(9, vec![1, 2], 100, None);
        s.finish(FinishReason::CacheOverflow);
        assert!(s.is_finished());
        assert_eq!(s.state, SeqState::Finished(FinishReason::CacheOverflow));
    }

    #[test]
    fn eos_equal_to_max_tokens_prefers_eos() {
        let mut s = Sequence::new(10, vec![1], 1, Some(7));
        assert!(s.push_token(7));
        assert_eq!(s.state, SeqState::Finished(FinishReason::Eos));
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        let _ = Sequence::new(11, vec![], 4, None);
    }

    #[test]
    fn priority_defaults_interactive_and_orders() {
        let s = Sequence::new(20, vec![1], 4, None);
        assert_eq!(s.priority, Priority::Interactive);
        let b = Sequence::new(21, vec![1], 4, None)
            .with_priority(Priority::Batch);
        assert_eq!(b.priority, Priority::Batch);
        // Interactive sorts ahead of Batch (priority-queue order)
        assert!(Priority::Interactive < Priority::Batch);
    }

    #[test]
    fn backdated_arrival_charges_queueing_delay() {
        let t0 = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let mut s = Sequence::new(22, vec![1], 4, None).with_arrival(t0);
        s.push_token(5);
        // TTFT measured from the backdated trace arrival, not the submit
        assert!(s.ttft_s().unwrap() >= 0.002);
    }

    #[test]
    fn fork_inherits_history_with_fresh_budget_and_timing() {
        let mut p = Sequence::new(30, vec![1, 2, 3], 10, Some(99))
            .with_priority(Priority::Batch);
        p.push_token(7);
        p.push_token(8);
        let c = p.fork_as(31, 4);
        assert_eq!(c.id, 31);
        assert_eq!(c.prompt, p.prompt);
        assert_eq!(c.generated, vec![7, 8]);
        assert_eq!(c.len(), p.len());
        assert_eq!(c.state, SeqState::Decoding);
        assert_eq!(c.priority, Priority::Batch);
        assert_eq!(c.eos, Some(99));
        // 4 NEW tokens on top of the inherited 2
        assert_eq!(c.max_new, 6);
        assert!(c.first_token_at.is_none() && c.ttft_s().is_none());
    }

    #[test]
    fn restart_clears_generation_and_ttft() {
        let mut s = Sequence::new(12, vec![1, 2], 8, None);
        s.push_token(5);
        s.push_token(6);
        assert!(s.first_token_at.is_some());
        s.reset_for_restart();
        assert_eq!(s.state, SeqState::Queued);
        assert!(s.generated.is_empty());
        assert!(s.first_token_at.is_none(), "stale TTFT survives preemption");
        assert!(s.ttft_s().is_none());
        // the next token after re-admission re-stamps TTFT
        s.push_token(7);
        assert!(s.first_token_at.is_some());
    }
}
