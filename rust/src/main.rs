//! `thinkeys` — the leader binary.
//!
//! Subcommands:
//!   info                      artifact/config inventory + kernel report
//!   check                     static grid audit: shapes, ladders, quant
//!                             variants, scheduler reachability (no exec)
//!   serve                     run a synthetic serving workload
//!   train --config NAME       pretrain a config on the synthetic corpus
//!   compress --rank-div N     factored-keys surgery on a checkpoint
//!   experiments [LIST|all]    regenerate paper tables/figures
//!
//! Python never runs here: everything executes from artifacts/ built once
//! by `make artifacts`.

use anyhow::{bail, Result};

use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::eviction::{EvictionConfig, EvictionPolicy};
use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::coordinator::router::{Router, RouterPolicy};
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::scheduler::{SchedConfig, Scheduler};
use thinkeys::coordinator::supervisor::{Supervisor, SupervisorConfig};
use thinkeys::datagen::arrival::{infinite_chat_trace, mixed_chat_doc_trace,
                                 poisson_trace, TraceConfig};
use thinkeys::experiments::{self, Opts};
use thinkeys::analysis::grid;
use thinkeys::runtime::{FaultPlan, KvQuant, Manifest, ParamStore, Runtime};
use thinkeys::substrate::args::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match cmd.as_str() {
        "info" => info(),
        "check" => check(rest),
        "serve" => serve(rest),
        "train" => train(rest),
        "compress" => compress(rest),
        "experiments" => run_experiments(rest),
        _ => {
            println!(
                "thinkeys — Thin Keys, Full Values reproduction\n\n\
                 usage: thinkeys <info|check|serve|train|compress|\
                 experiments> [flags]\n\
                 run `thinkeys <cmd> --help` for flags"
            );
            Ok(())
        }
    }
}

fn info() -> Result<()> {
    let rt = Runtime::new()?;
    let m = rt.manifest();
    println!("artifacts dir: {:?}", m.dir);
    println!("{} configs, {} artifacts", m.configs.len(), m.artifacts.len());
    println!("decode buckets: {:?}", m.decode_batches);
    for (cfg, tiers) in &m.decode_tiers {
        println!("decode tiers for {cfg}: {tiers:?}");
    }
    for (name, c) in &m.configs {
        println!(
            "  {name}: {} {}  d_model {} d_select {} heads {}/{} \
             layers {} params {:.2}M  kv_budget {}",
            c.arch, c.attn, c.d_model, c.d_select, c.n_heads, c.n_kv_heads,
            c.n_layers, c.n_parameters() as f64 / 1e6, c.kv_budget
        );
    }
    Ok(())
}

fn check(argv: &[String]) -> Result<()> {
    let p = Args::new(
        "audit the exported artifact grid without executing anything: \
         config algebra, tier/chunk ladders, per-artifact geometry, \
         q8/fp32/pallas variant agreement, and scheduler reachability \
         (every (bucket, tier, quant) cell the hysteresis state machines \
         can visit must have an artifact)",
    )
    .flag_bool("skip-files",
               "audit the manifest contract only; do not require the \
                .hlo.txt files on disk (useful against a bare manifest)")
    .parse(argv)?;
    let dir = thinkeys::artifacts_dir();
    let m = Manifest::load(&dir)?;
    let mut violations = grid::check_manifest(&m);
    if !p.bool("skip-files") {
        violations.extend(grid::check_files(&m));
    }
    let n_rules = grid::RULES.len();
    if violations.is_empty() {
        println!(
            "thinkeys check: OK — {} artifacts, {} configs, {n_rules} rules, \
             0 violations",
            m.artifacts.len(),
            m.configs.len()
        );
        Ok(())
    } else {
        for v in &violations {
            eprintln!("FAIL {v}");
        }
        bail!(
            "thinkeys check: {} violation(s) across {} artifacts",
            violations.len(),
            m.artifacts.len()
        )
    }
}

fn serve(argv: &[String]) -> Result<()> {
    let p = Args::new("serve a synthetic trace on the factored-keys engine")
        .flag_str("config", Some("servethin"),
                  "serving config: servefull | servethin (factored keys \
                   r=d/4) | servegqa (8q/2kv grouped heads) | servegqathin \
                   (grouped + factored — composes with --kv-quant q8 for \
                   the measured 64x key-arena cut)")
        .flag_usize("requests", Some(32), "number of requests")
        .flag_f64("rate", Some(4.0), "arrival rate (req/s)")
        .flag_f64("budget-mb", Some(8.0), "KV cache budget (MB)")
        .flag_usize("max-batch", Some(16), "max concurrent sequences")
        .flag_usize("chunk-tokens", Some(0),
                    "chunked prefill: advance one C-token prompt chunk per \
                     round, interleaved with decode (0 = monolithic \
                     prefill; exported sizes: manifest prefill_chunks)")
        .flag_usize("round-budget", Some(128),
                    "tokens one scheduling round may spend across decode \
                     lanes (1 each) and a prefill chunk (chunked mode)")
        .flag_usize("interactive-weight", Some(4),
                    "chunk grants to interactive prefills before a pending \
                     batch prefill gets one (anti-starvation)")
        .flag_bool("mixed",
                   "serve the mixed chat+doc trace (batch-class documents \
                    + interactive chats) instead of the poisson trace")
        .flag_str("kv-quant", Some("fp32"),
                  "KV-cache element format: fp32, or q8 (int8 arenas with \
                   per-row fp32 scales, dequant-fused attention — 4x less \
                   arena payload and per-step sync; needs the _q8 \
                   artifact grid from `make artifacts`)")
        .flag_bool("pallas", "use the Pallas-kernel decode artifacts")
        .flag_str("fault-plan", Some(""),
                  "seeded fault injection at the runtime boundary, e.g. \
                   'seed=7,exec=0.05,load=0.02,corrupt=0.02,latency=0.1,\
                   latency-us=300,burst=2' (probabilities per execute \
                   call; empty = no injection, byte-identical serving)")
        .flag_f64("batch-deadline-ms", Some(0.0),
                  "shed a WAITING batch request once it queued this long \
                   while degraded (faults or KV pressure); 0 = never")
        .flag_f64("interactive-deadline-ms", Some(0.0),
                  "shed a WAITING interactive request once it queued this \
                   long while degraded; 0 = never (shed batch first)")
        .flag_usize("checkpoint-every", Some(8),
                    "supervised recovery: checkpoint the full serving \
                     state every K scheduler rounds; a Fatal engine error \
                     warm-restarts from the last checkpoint and replays \
                     (0 = unsupervised, a Fatal ends the run)")
        .flag_usize("max-restarts", Some(8),
                    "consecutive engine restarts tolerated before the \
                     supervisor escalates and the router drains/sheds")
        .flag_f64("watchdog-ms", Some(0.0),
                  "per-round wall-clock deadline: a round exceeding it is \
                   treated as a wedged engine and discarded via restart \
                   (0 = watchdog off; pair with a wedge=P fault plan)")
        .flag_usize("shared-prefix-users", Some(0),
                    "instead of a trace: serve N chat users over ONE \
                     48-token system prompt on a fixed block pool, \
                     reporting prefix hits, dedup bytes, and concurrency \
                     (0 = off; see --no-prefix-sharing for the baseline)")
        .flag_usize("prefix-pool-blocks", Some(20),
                    "KV pool size in 16-token blocks for the \
                     shared-prefix mode (both sharing modes compete on \
                     this same pool)")
        .flag_bool("no-prefix-sharing",
                   "disable prefix-tree matching and copy-on-write block \
                    sharing (per-sequence private blocks only — the \
                    pre-paged baseline)")
        .flag_usize("kv-budget-blocks", Some(0),
                    "total KV pool size in 16-token blocks (0 = derive \
                     from --budget-mb); with --eviction active, streams \
                     whose full reservation exceeds this pool are admitted \
                     capped and stay within it by evicting their middle")
        .flag_str("eviction", Some("none"),
                  "bounded-cache eviction over the paged block tables: \
                   none (reject-on-overflow) | sink (pin sink + recency, \
                   FIFO middle) | a2sf (forgetting-factor accumulated \
                   attention argmin) | tova (current-step attention \
                   argmin); a2sf/tova need the attn_mass decode output \
                   plane from `make artifacts`")
        .flag_bool("infinite-chat",
                   "serve the infinite-chat streaming trace: short \
                    prompts, generations long enough that full \
                    reservations exceed the pool (rejected without \
                    --eviction, completes bounded with it)")
        .parse(argv)?;
    let cfg_name = p.str("config")?;
    let quant_name = p.str("kv-quant")?;
    let quant = KvQuant::parse(&quant_name).ok_or_else(|| {
        anyhow::anyhow!("--kv-quant {quant_name}: expected fp32 or q8")
    })?;
    let rt = Runtime::new()?;
    let fault_spec = p.str("fault-plan")?;
    let fault_plan = FaultPlan::parse(&fault_spec)?;
    if !fault_plan.is_empty() {
        println!("fault plan: {fault_plan:?}");
        rt.install_fault_plan(fault_plan);
    }
    let shared_users = p.usize("shared-prefix-users")?;
    if shared_users > 0 {
        let sharing = !p.bool("no-prefix-sharing");
        let r = experiments::serving::shared_prefix_run(
            &rt, &cfg_name, shared_users, 48, 8, 8,
            p.usize("prefix-pool-blocks")?, sharing)?;
        println!(
            "shared-prefix cohort ({cfg_name}, sharing {}): {} users, \
             {} prefill tokens computed, {} prefix hits ({} rows \
             adopted), peak {} concurrent, peak dedup {:.0} B, \
             TTFT p50 {:.1} ms",
            if sharing { "on" } else { "off" },
            shared_users, r.prefill_tokens, r.prefix_hits,
            r.prefix_hit_tokens, r.peak_concurrent, r.peak_dedup_bytes,
            r.report.ttft.quantile_us(0.5) / 1e3
        );
        println!("{}", r.report.report());
        if r.sync_download_bytes != 0 {
            bail!("sync_download_bytes = {} (device-residency regression)",
                  r.sync_download_bytes);
        }
        return Ok(());
    }
    let cfg = rt.manifest().config(&cfg_name)?.clone();
    println!(
        "config {cfg_name}: {} heads {}q/{}kv (group {}), cache row \
         KD {} + VD {} els/layer at {}",
        cfg.attn, cfg.n_heads, cfg.n_kv_heads, cfg.group(),
        cfg.k_cache_dims, cfg.v_cache_dims, quant.name()
    );
    let params = ParamStore::init(&cfg, 42);
    let eng = Engine::with_kv_quant(&rt, &cfg_name, params, p.bool("pallas"),
                                    Sampler::Greedy, 0, quant)?;
    // admission accounting at the serving element widths: the q8 rows
    // amortize their per-row fp32 scale over the row's elements (the
    // fp32 path keeps the historical bf16-deployment model)
    let (bk, bv) = match quant {
        KvQuant::Fp32 => (2.0, 2.0),
        KvQuant::Q8 => (
            1.0 + 4.0 / cfg.k_cache_dims as f64,
            1.0 + 4.0 / cfg.v_cache_dims as f64,
        ),
    };
    let kv_cfg = KvCacheConfig {
        n_layers: cfg.n_layers,
        k_dims: cfg.k_cache_dims,
        v_dims: cfg.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: bk,
        bytes_per_el_v: bv,
        budget_bytes: p.f64("budget-mb")? * 1e6,
    };
    let kv = match p.usize("kv-budget-blocks")? {
        0 => KvCacheManager::new(kv_cfg),
        b => KvCacheManager::with_block_count(kv_cfg, b),
    };
    let ev_name = p.str("eviction")?;
    let policy = EvictionPolicy::parse(&ev_name).ok_or_else(|| {
        anyhow::anyhow!(
            "--eviction {ev_name}: expected none, sink, a2sf, or tova"
        )
    })?;
    if policy.needs_scores() && !eng.supports_attn_mass() {
        bail!(
            "--eviction {} ranks victims by attention scores, but this \
             artifact grid exports no attn_mass decode plane; re-run \
             `make artifacts` or use --eviction sink",
            policy.name()
        );
    }
    let eviction = EvictionConfig { policy, ..EvictionConfig::default() };
    if eviction.active() {
        println!(
            "eviction: {} (budget {} blocks/seq = {} sink + {} window + \
             {} slack; pool {} blocks)",
            policy.name(), eviction.budget_blocks(), eviction.sink_blocks,
            eviction.window_blocks, eviction.slack_blocks,
            kv.total_token_capacity() / kv.cfg.block_tokens
        );
    }
    let chunk = match p.usize("chunk-tokens")? {
        0 => None,
        c => {
            if p.bool("pallas") {
                bail!(
                    "--chunk-tokens requires the ref prefill path (the \
                     chunk artifacts have no pallas column); drop --pallas \
                     or use --chunk-tokens 0"
                );
            }
            let sizes = rt.manifest().chunks_for(&cfg_name);
            if !sizes.contains(&c) {
                bail!(
                    "--chunk-tokens {c} not exported for {cfg_name} \
                     (available: {sizes:?}; 0 = monolithic)"
                );
            }
            Some(c)
        }
    };
    let sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: p.usize("max-batch")?,
        round_budget: p.usize("round-budget")?,
        chunk_tokens: chunk,
        interactive_weight: p.usize("interactive-weight")?,
        prefix_sharing: !p.bool("no-prefix-sharing"),
        eviction,
        ..SchedConfig::default()
    });
    let deadline = |ms: f64| if ms > 0.0 { Some(ms / 1e3) } else { None };
    let policy = RouterPolicy {
        batch_deadline_s: deadline(p.f64("batch-deadline-ms")?),
        interactive_deadline_s: deadline(p.f64("interactive-deadline-ms")?),
        only_when_degraded: true,
    };
    let mut router = Router::new(sched).with_policy(policy);
    let checkpoint_every = p.usize("checkpoint-every")?;
    if checkpoint_every > 0 {
        let watchdog_ms = p.f64("watchdog-ms")?;
        let sup_cfg = SupervisorConfig {
            checkpoint_every,
            max_restarts: p.usize("max-restarts")?,
            watchdog_step_s: if watchdog_ms > 0.0 {
                Some(watchdog_ms / 1e3)
            } else {
                None
            },
            ..SupervisorConfig::default()
        };
        // the restore target after a Fatal: a fresh engine from the SAME
        // manifest/config/seed the serving engine was built from
        let fact_cfg = cfg.clone();
        let fact_name = cfg_name.clone();
        let pallas = p.bool("pallas");
        let rt_ref = &rt;
        let factory = move || {
            let params = ParamStore::init(&fact_cfg, 42);
            Engine::with_kv_quant(rt_ref, &fact_name, params, pallas,
                                  Sampler::Greedy, 0, quant)
        };
        router = router.with_supervisor(Supervisor::new(sup_cfg, factory));
    }
    let n = p.usize("requests")?;
    let trace = if p.bool("infinite-chat") {
        // each stream's full reservation (8 prompt + 192 gen) dwarfs a
        // bounded pool; only capped admission + eviction completes it
        infinite_chat_trace(n, 192, 0.002)
    } else if p.bool("mixed") {
        // 1 doc per 4 requests, chats arriving while docs prefill
        mixed_chat_doc_trace(n - n / 4, n / 4, 0.002, 0.0005)
    } else {
        poisson_trace(
            &TraceConfig {
                rate_per_s: p.f64("rate")?,
                n_requests: n,
                ..Default::default()
            },
            0,
        )
    };
    let report = router.run_trace(&trace, 0)?;
    println!("{}", report.report());
    println!("{}", report.report_by_class());
    println!("\nengine:\n{}", router.sched.engine.metrics.report());
    let stats = router.sched.kv.stats();
    println!(
        "\nkv pools: K used {:.2} MB / {:.2} MB, V used {:.2} MB / {:.2} MB \
         (K fraction of live cache: {:.1}%)",
        stats.k_bytes_used / 1e6,
        stats.k_bytes_capacity / 1e6,
        stats.v_bytes_used / 1e6,
        stats.v_bytes_capacity / 1e6,
        100.0 * stats.k_fraction()
    );
    // With eviction on, the whole point is that bounded pools stop
    // rejecting: hard-fail the smoke if a stream was still turned away or
    // lost, or if eviction round-tripped an arena through host memory
    // (it zeroes rows host-side and re-uploads; downloads stay 0).
    if eviction.active() {
        let m = &router.sched.engine.metrics;
        if report.rejected > 0 || report.failed > 0 {
            bail!(
                "eviction {} active but {} requests rejected / {} failed",
                eviction.policy.name(), report.rejected, report.failed
            );
        }
        if m.sync_download_bytes != 0 {
            bail!(
                "sync_download_bytes = {} under eviction \
                 (device-residency regression)",
                m.sync_download_bytes
            );
        }
    }
    Ok(())
}

fn train(argv: &[String]) -> Result<()> {
    let p = Args::new("pretrain a config on the synthetic corpus")
        .flag_str("config", Some("tinylm_ds64"), "model config")
        .flag_usize("steps", Some(240), "optimizer steps")
        .flag_usize("seed", Some(137), "seed")
        .parse(argv)?;
    let rt = Runtime::new()?;
    let cfg_name = p.str("config")?;
    let corpus = experiments::common::corpus_for(
        &rt, &cfg_name, experiments::common::LARGE_CORPUS);
    let pre = experiments::common::pretrain_lm(
        &rt, &cfg_name, &corpus, "cli", p.usize("steps")?,
        p.usize("seed")? as u64)?;
    let ppl =
        experiments::common::val_ppl(&rt, &cfg_name, &pre.params, &corpus)?;
    println!(
        "{} trained {} steps in {:.1}s (cached: {}), val PPL {:.2}",
        cfg_name,
        p.usize("steps")?,
        pre.seconds,
        pre.cached,
        ppl
    );
    Ok(())
}

fn compress(argv: &[String]) -> Result<()> {
    let p = Args::new("factored-keys surgery: full ckpt -> thin ckpt")
        .flag_str("from", Some("tinylm_ds64"), "full config")
        .flag_str("to", Some("tinylm_ds16"), "thin config")
        .flag_str("ckpt", None, "input .tkw (default: fresh init)")
        .flag_str("out", Some("/tmp/thin.tkw"), "output .tkw")
        .parse(argv)?;
    let rt = Runtime::new()?;
    let full_cfg = rt.manifest().config(&p.str("from")?)?.clone();
    let thin_cfg = rt.manifest().config(&p.str("to")?)?.clone();
    let full = match p.str("ckpt") {
        Ok(path) => ParamStore::load(std::path::Path::new(&path))?,
        Err(_) => ParamStore::init(&full_cfg, 42),
    };
    let thin = thinkeys::model::surgery::factor_to_thin(
        &full, &full_cfg, &thin_cfg)?;
    let out = p.str("out")?;
    thin.save(std::path::Path::new(&out))?;
    println!(
        "factored {} ({:.2}M params) -> {} ({:.2}M params), K cache dims \
         {} -> {} ({:.0}% K cache saved); wrote {}",
        full_cfg.name,
        full.n_elements() as f64 / 1e6,
        thin_cfg.name,
        thin.n_elements() as f64 / 1e6,
        full_cfg.k_cache_dims,
        thin_cfg.k_cache_dims,
        100.0 * (1.0 - thin_cfg.k_cache_dims as f64
                 / full_cfg.k_cache_dims as f64),
        out
    );
    Ok(())
}

fn run_experiments(argv: &[String]) -> Result<()> {
    let p = Args::new("regenerate paper tables/figures")
        .flag_f64("scale", Some(1.0), "step-budget multiplier")
        .flag_usize("seeds", Some(2), "number of seeds (trajectories)")
        .parse(argv)?;
    let mut opts = Opts { scale: p.f64("scale")?, ..Default::default() };
    opts.seeds.truncate(p.usize("seeds")?.max(1));
    let which: Vec<String> = if p.positional.is_empty() {
        vec!["all".into()]
    } else {
        p.positional.clone()
    };
    let all = which.iter().any(|w| w == "all");
    let want = |name: &str| all || which.iter().any(|w| w == name);
    let known = ["analytical", "exp1", "exp2", "exp34", "exp5", "exp6",
                 "exp7", "exp8", "exp19", "serving"];
    if !all && !which.iter().all(|w| known.contains(&w.as_str())) {
        bail!("unknown experiment in {which:?}; known: {known:?} or all");
    }
    let rt = Runtime::new()?;

    if want("analytical") {
        for t in experiments::analytical::run() {
            t.print();
        }
    }
    if want("exp1") {
        experiments::exp1_copyback::run(&rt, &opts)?.print();
    }
    if want("exp2") {
        experiments::exp2_kvret::run(&rt, &opts)?.print();
    }
    if want("exp34") {
        for t in experiments::exp34_lm_sweep::run(&rt, &opts)? {
            t.print();
        }
    }
    if want("exp5") {
        for t in experiments::exp5_svd::run(&rt, &opts)? {
            t.print();
        }
    }
    if want("exp6") {
        experiments::exp67_llama::table16(&rt, &opts)?.print();
        experiments::exp67_llama::table17(&rt, &opts)?.print();
    }
    if want("exp7") {
        for t in experiments::exp67_llama::tables_3_4_figs(&rt, &opts)? {
            t.print();
        }
        experiments::exp67_llama::table5(&rt, &opts)?.print();
    }
    if want("exp8") {
        for t in experiments::exp8_gqa::run(&rt, &opts)? {
            t.print();
        }
    }
    if want("exp19") {
        experiments::exp19_domain_ft::run(&rt, &opts)?.print();
    }
    if want("serving") {
        for t in experiments::serving::run(&rt, &opts)? {
            t.print();
        }
    }
    Ok(())
}
