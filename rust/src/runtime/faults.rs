//! Deterministic, seeded fault injection at the `Runtime::execute`
//! boundary.
//!
//! A [`FaultPlan`] gives per-call probabilities for six fault kinds
//! (transient exec failures, artifact-load failures, corrupted output
//! literals, latency spikes, fatal errors, wedged executes); a
//! [`FaultInjector`] draws from its own seeded [`Rng`] stream — never
//! the engine's — so installing a plan perturbs *when* steps fail but
//! not *what* surviving sequences decode.
//!
//! Two properties the chaos tests lean on:
//!
//! - **Fixed draw count.** `decide` consumes exactly seven RNG draws per
//!   call regardless of outcome, so the fault schedule for call N depends
//!   only on the seed and N — not on which earlier faults fired or how
//!   callers reacted to them.
//! - **Burst clamp.** At most `max_burst` consecutive *erroring* faults
//!   are injected; the next call is then forced clean. A retry budget
//!   larger than `max_burst` therefore always recovers a transient
//!   fault, which is what lets the chaos e2e assert zero Fatal
//!   escalations under any seed. Injected FATAL errors are also
//!   burst-clamped (so a bounded restart budget always outlasts a
//!   burst), but they are never retried in place — the scheduler
//!   escalates and the supervisor restarts the engine. Latency spikes
//!   and wedges don't error and don't count toward the burst.
use crate::substrate::rng::Rng;

/// The six injectable fault kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Device execution failed after the artifact was loaded.
    ExecFailure,
    /// The artifact (HLO executable) could not be loaded.
    ArtifactLoad,
    /// Execution "succeeded" but the output literal is garbage; the
    /// injector discards the real outputs and errors instead, since a
    /// corrupt literal must never reach the host mirror.
    CorruptOutput,
    /// Execution succeeded but took `latency_us` longer than usual.
    LatencySpike,
    /// The device is poisoned: the coordinator classifies this as
    /// `EngineError::Fatal` (never retried in place — the supervisor
    /// drops the engine and warm-restarts from the last checkpoint).
    FatalError,
    /// The execute wedges: it eventually succeeds but only after
    /// `wedge_us` of dead time — long enough to trip a supervisor
    /// watchdog deadline. Does not error and does not count toward
    /// the burst.
    Wedge,
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self {
            FaultKind::ExecFailure => "exec-failure",
            FaultKind::ArtifactLoad => "artifact-load",
            FaultKind::CorruptOutput => "corrupt-output",
            FaultKind::LatencySpike => "latency-spike",
            FaultKind::FatalError => "fatal-error",
            FaultKind::Wedge => "wedge",
        };
        f.write_str(name)
    }
}

/// The typed payload carried by every injected error. The coordinator
/// downcasts to this (`anyhow::Error::downcast_ref`) to classify the
/// failure; anything *not* carrying an `InjectedFault` is a real
/// runtime error and escalates as Fatal.
#[derive(Clone, Copy, Debug)]
pub struct InjectedFault {
    pub kind: FaultKind,
    /// Which batch lane the fault nominally hit. Only meaningful for
    /// `CorruptOutput` (a corrupt literal is attributable to one
    /// sequence's row); callers reduce it modulo the batch size.
    pub lane_hint: u64,
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected {} fault (lane hint {})", self.kind, self.lane_hint)
    }
}

impl std::error::Error for InjectedFault {}

/// A seeded fault schedule. All probabilities are per `execute` call,
/// evaluated independently; an all-zero plan is "empty" and installs
/// nothing (the serving path is then byte-identical to a build without
/// fault injection).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed for the injector's private RNG stream.
    pub seed: u64,
    /// P(transient exec failure) per call.
    pub exec: f64,
    /// P(artifact-load failure) per call.
    pub load: f64,
    /// P(corrupted output literal) per call.
    pub corrupt: f64,
    /// P(latency spike) per call.
    pub latency: f64,
    /// Added latency per spike, in microseconds.
    pub latency_us: u64,
    /// P(fatal engine error) per call — kills the engine; only a
    /// supervisor warm restart recovers it.
    pub fatal: f64,
    /// P(wedged execute) per call — succeeds after `wedge_us` of dead
    /// time (watchdog fodder; no error).
    pub wedge: f64,
    /// Dead time per wedge, in microseconds.
    pub wedge_us: u64,
    /// Max consecutive erroring faults before a forced-clean call.
    pub max_burst: u32,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            seed: 0,
            exec: 0.0,
            load: 0.0,
            corrupt: 0.0,
            latency: 0.0,
            latency_us: 500,
            fatal: 0.0,
            wedge: 0.0,
            wedge_us: 20_000,
            max_burst: 2,
        }
    }
}

impl FaultPlan {
    /// The no-fault plan (all probabilities zero).
    pub fn empty() -> Self {
        Self::default()
    }

    /// True when no fault kind can ever fire.
    pub fn is_empty(&self) -> bool {
        self.exec == 0.0
            && self.load == 0.0
            && self.corrupt == 0.0
            && self.latency == 0.0
            && self.fatal == 0.0
            && self.wedge == 0.0
    }

    /// Parse the `--fault-plan` spec: comma-separated `key=value` pairs.
    ///
    /// Keys: `seed` (u64), `exec` / `load` / `corrupt` / `latency` /
    /// `fatal` / `wedge` (probabilities in [0,1]), `latency-us` /
    /// `wedge-us` (u64), `burst` (u32 >= 1). The empty string parses to
    /// the empty plan.
    ///
    /// Example: `seed=7,exec=0.05,fatal=0.01,wedge=0.02,latency-us=300`
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut plan = Self::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("fault-plan entry `{part}` is not key=value")
            })?;
            let prob = |v: &str| -> anyhow::Result<f64> {
                let p: f64 = v.parse().map_err(|_| {
                    anyhow::anyhow!("fault-plan {key}: `{v}` is not a number")
                })?;
                if !(0.0..=1.0).contains(&p) {
                    anyhow::bail!(
                        "fault-plan {key}: probability {p} outside [0, 1]"
                    );
                }
                Ok(p)
            };
            match key {
                "seed" => plan.seed = value.parse()?,
                "exec" => plan.exec = prob(value)?,
                "load" => plan.load = prob(value)?,
                "corrupt" => plan.corrupt = prob(value)?,
                "latency" => plan.latency = prob(value)?,
                "latency-us" => plan.latency_us = value.parse()?,
                "fatal" => plan.fatal = prob(value)?,
                "wedge" => plan.wedge = prob(value)?,
                "wedge-us" => plan.wedge_us = value.parse()?,
                "burst" => {
                    plan.max_burst = value.parse()?;
                    if plan.max_burst == 0 {
                        anyhow::bail!("fault-plan burst must be >= 1");
                    }
                }
                _ => anyhow::bail!("unknown fault-plan key `{key}`"),
            }
        }
        Ok(plan)
    }
}

/// What the injector decided for one `execute` call.
#[derive(Clone, Copy, Debug, Default)]
pub struct Decision {
    /// Sleep this long before proceeding (0 = no spike).
    pub latency_us: u64,
    /// Fail before execution with this fault kind.
    pub error: Option<FaultKind>,
    /// Execute for real, then discard the outputs and report a
    /// `CorruptOutput` fault instead of returning them.
    pub corrupt: bool,
    /// Raw draw for attributing `CorruptOutput` to a batch lane.
    pub lane_hint: u64,
}

/// Seeded injector installed on a `Runtime`. One instance per runtime;
/// `decide` is called once per `execute`.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    rng: Rng,
    injected: u64,
    consecutive: u32,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            rng: Rng::new(plan.seed),
            plan,
            injected: 0,
            consecutive: 0,
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Total faults injected so far (erroring faults + latency spikes).
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Decide the fate of one `execute` call. Always consumes exactly
    /// seven RNG draws so the schedule is a pure function of (seed, call
    /// index) — see the module docs.
    pub fn decide(&mut self, _artifact: &str) -> Decision {
        let r_latency = self.rng.f64();
        let r_load = self.rng.f64();
        let r_exec = self.rng.f64();
        let r_corrupt = self.rng.f64();
        let lane_hint = self.rng.next_u64();
        let r_fatal = self.rng.f64();
        let r_wedge = self.rng.f64();

        let mut d = Decision {
            lane_hint,
            ..Decision::default()
        };
        if r_latency < self.plan.latency {
            d.latency_us = self.plan.latency_us;
            self.injected += 1;
        }
        if r_wedge < self.plan.wedge {
            d.latency_us += self.plan.wedge_us;
            self.injected += 1;
        }
        // Erroring faults are burst-clamped; first matching kind wins.
        let mut fault = None;
        if self.consecutive < self.plan.max_burst {
            if r_load < self.plan.load {
                fault = Some(FaultKind::ArtifactLoad);
            } else if r_exec < self.plan.exec {
                fault = Some(FaultKind::ExecFailure);
            } else if r_corrupt < self.plan.corrupt {
                fault = Some(FaultKind::CorruptOutput);
            } else if r_fatal < self.plan.fatal {
                fault = Some(FaultKind::FatalError);
            }
        }
        match fault {
            Some(FaultKind::CorruptOutput) => d.corrupt = true,
            Some(kind) => d.error = Some(kind),
            None => {}
        }
        if fault.is_some() {
            self.consecutive += 1;
            self.injected += 1;
        } else {
            self.consecutive = 0;
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        let plan = FaultPlan::parse(
            "seed=7,exec=0.05,load=0.02,corrupt=0.03,latency=0.1,\
             latency-us=250,fatal=0.01,wedge=0.04,wedge-us=9000,burst=3",
        )
        .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.exec, 0.05);
        assert_eq!(plan.load, 0.02);
        assert_eq!(plan.corrupt, 0.03);
        assert_eq!(plan.latency, 0.1);
        assert_eq!(plan.latency_us, 250);
        assert_eq!(plan.fatal, 0.01);
        assert_eq!(plan.wedge, 0.04);
        assert_eq!(plan.wedge_us, 9000);
        assert_eq!(plan.max_burst, 3);
        assert!(!plan.is_empty());
    }

    #[test]
    fn fatal_only_and_wedge_only_plans_are_not_empty() {
        assert!(!FaultPlan { fatal: 0.1, ..FaultPlan::empty() }.is_empty());
        assert!(!FaultPlan { wedge: 0.1, ..FaultPlan::empty() }.is_empty());
    }

    #[test]
    fn parse_empty_spec_is_empty_plan() {
        let plan = FaultPlan::parse("").unwrap();
        assert!(plan.is_empty());
        assert_eq!(plan, FaultPlan::empty());
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(FaultPlan::parse("exec=1.5").is_err());
        assert!(FaultPlan::parse("exec=-0.1").is_err());
        assert!(FaultPlan::parse("exec=abc").is_err());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("exec").is_err());
        assert!(FaultPlan::parse("burst=0").is_err());
    }

    #[test]
    fn same_seed_same_schedule() {
        let plan = FaultPlan {
            seed: 42,
            exec: 0.3,
            load: 0.1,
            corrupt: 0.2,
            latency: 0.25,
            ..FaultPlan::default()
        };
        let mut a = FaultInjector::new(plan);
        let mut b = FaultInjector::new(plan);
        for i in 0..500 {
            let da = a.decide("decode");
            let db = b.decide("decode");
            assert_eq!(da.error, db.error, "call {i}");
            assert_eq!(da.corrupt, db.corrupt, "call {i}");
            assert_eq!(da.latency_us, db.latency_us, "call {i}");
            assert_eq!(da.lane_hint, db.lane_hint, "call {i}");
        }
        assert_eq!(a.injected(), b.injected());
        assert!(a.injected() > 0, "probabilities this high must fire");
    }

    #[test]
    fn burst_clamp_bounds_consecutive_errors() {
        // Certain-failure plan: without the clamp every call would
        // error; with it, every (max_burst+1)-th call is forced clean.
        let plan = FaultPlan {
            seed: 1,
            exec: 1.0,
            max_burst: 2,
            ..FaultPlan::default()
        };
        let mut inj = FaultInjector::new(plan);
        let mut streak = 0u32;
        for _ in 0..300 {
            let d = inj.decide("decode");
            if d.error.is_some() || d.corrupt {
                streak += 1;
                assert!(streak <= plan.max_burst, "burst clamp violated");
            } else {
                streak = 0;
            }
        }
    }

    #[test]
    fn empty_plan_never_injects() {
        let mut inj = FaultInjector::new(FaultPlan::empty());
        for _ in 0..200 {
            let d = inj.decide("prefill");
            assert!(d.error.is_none());
            assert!(!d.corrupt);
            assert_eq!(d.latency_us, 0);
        }
        assert_eq!(inj.injected(), 0);
    }

    #[test]
    fn forced_fatal_errors_are_burst_clamped() {
        let plan = FaultPlan {
            seed: 11,
            fatal: 1.0,
            max_burst: 2,
            ..FaultPlan::default()
        };
        let mut inj = FaultInjector::new(plan);
        let mut streak = 0u32;
        let mut fired = 0u32;
        for _ in 0..300 {
            let d = inj.decide("decode");
            match d.error {
                Some(FaultKind::FatalError) => {
                    streak += 1;
                    fired += 1;
                    assert!(streak <= plan.max_burst, "burst clamp violated");
                }
                Some(k) => panic!("unexpected kind {k}"),
                None => streak = 0,
            }
            assert!(!d.corrupt);
        }
        assert!(fired > 0, "certain fatal plan never fired");
    }

    #[test]
    fn fatal_yields_to_higher_priority_erroring_kinds() {
        // with exec also certain, the erroring slot is taken by exec and
        // fatal never fires (first matching kind wins)
        let plan = FaultPlan {
            seed: 5,
            exec: 1.0,
            fatal: 1.0,
            max_burst: 1_000_000,
            ..FaultPlan::default()
        };
        let mut inj = FaultInjector::new(plan);
        for _ in 0..100 {
            assert_eq!(inj.decide("decode").error,
                       Some(FaultKind::ExecFailure));
        }
    }

    #[test]
    fn wedges_add_dead_time_without_erroring() {
        let plan = FaultPlan {
            seed: 4,
            wedge: 1.0,
            wedge_us: 13,
            ..FaultPlan::default()
        };
        let mut inj = FaultInjector::new(plan);
        for _ in 0..50 {
            let d = inj.decide("decode");
            assert_eq!(d.latency_us, 13);
            assert!(d.error.is_none() && !d.corrupt);
        }
        assert_eq!(inj.injected(), 50);
    }

    #[test]
    fn wedge_dead_time_stacks_on_latency_spikes() {
        let plan = FaultPlan {
            seed: 4,
            latency: 1.0,
            latency_us: 7,
            wedge: 1.0,
            wedge_us: 13,
            ..FaultPlan::default()
        };
        let mut inj = FaultInjector::new(plan);
        let d = inj.decide("decode");
        assert_eq!(d.latency_us, 20);
        assert!(d.error.is_none());
    }

    #[test]
    fn latency_spikes_do_not_consume_burst() {
        let plan = FaultPlan {
            seed: 3,
            latency: 1.0,
            latency_us: 7,
            ..FaultPlan::default()
        };
        let mut inj = FaultInjector::new(plan);
        for _ in 0..50 {
            let d = inj.decide("decode");
            assert_eq!(d.latency_us, 7);
            assert!(d.error.is_none() && !d.corrupt);
        }
        assert_eq!(inj.injected(), 50);
    }
}
