//! Named, ordered parameter store — the host-side twin of the flat HLO
//! argument list. Ordering always follows the manifest's param specs, so a
//! `ParamStore` can be splatted directly into a train/eval/serve call.

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::manifest::ConfigEntry;
use crate::substrate::rng::Rng;
use crate::substrate::tensor::Tensor;
use crate::substrate::tensorfile;

#[derive(Clone, Debug)]
pub struct ParamStore {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
}

impl ParamStore {
    /// Initialize per the manifest init specs (normal / scaled / zeros /
    /// ones) — the rust twin of python `model.init_params`.
    pub fn init(cfg: &ConfigEntry, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut names = Vec::with_capacity(cfg.params.len());
        let mut tensors = Vec::with_capacity(cfg.params.len());
        for spec in &cfg.params {
            let t = match spec.init.as_str() {
                "zeros" => Tensor::zeros(&spec.shape),
                "ones" => Tensor::ones(&spec.shape),
                // "normal" and "normal_scaled" differ only in std, which the
                // manifest carries explicitly.
                _ => Tensor::randn(&spec.shape, spec.std as f32, &mut rng),
            };
            names.push(spec.name.clone());
            tensors.push(t);
        }
        ParamStore { names, tensors }
    }

    /// Zeros with the same names/shapes (Adam m/v state).
    pub fn zeros_like(&self) -> ParamStore {
        ParamStore {
            names: self.names.clone(),
            tensors: self.tensors.iter().map(|t| Tensor::zeros(&t.shape)).collect(),
        }
    }

    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow::anyhow!("no parameter {name:?}"))
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        Ok(&self.tensors[self.index_of(name)?])
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let i = self.index_of(name)?;
        if self.tensors[i].shape != t.shape {
            bail!(
                "set {name:?}: shape {:?} != existing {:?}",
                t.shape,
                self.tensors[i].shape
            );
        }
        self.tensors[i] = t;
        Ok(())
    }

    pub fn n_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Replace all tensors from freshly downloaded literals (same order).
    pub fn replace_from(&mut self, tensors: Vec<Tensor>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            bail!("replace_from: {} vs {}", tensors.len(), self.tensors.len());
        }
        for (old, new) in self.tensors.iter().zip(&tensors) {
            if old.shape != new.shape {
                bail!("replace_from shape {:?} vs {:?}", new.shape, old.shape);
            }
        }
        self.tensors = tensors;
        Ok(())
    }

    /// Validate against a config's specs (names, order, shapes).
    pub fn check_matches(&self, cfg: &ConfigEntry) -> Result<()> {
        if self.names.len() != cfg.params.len() {
            bail!(
                "param count {} != config {} ({})",
                self.names.len(),
                cfg.params.len(),
                cfg.name
            );
        }
        for (i, spec) in cfg.params.iter().enumerate() {
            if self.names[i] != spec.name {
                bail!("param {i}: {:?} != spec {:?}", self.names[i], spec.name);
            }
            if self.tensors[i].shape != spec.shape {
                bail!(
                    "param {:?}: shape {:?} != spec {:?}",
                    spec.name,
                    self.tensors[i].shape,
                    spec.shape
                );
            }
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let pairs: Vec<(String, &Tensor)> = self
            .names
            .iter()
            .cloned()
            .zip(self.tensors.iter())
            .collect();
        tensorfile::save(path, &pairs)
    }

    pub fn load(path: &Path) -> Result<ParamStore> {
        let (names, mut map) = tensorfile::load(path)?;
        let tensors = names
            .iter()
            .map(|n| map.remove(n).unwrap())
            .collect();
        Ok(ParamStore { names, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn cfg() -> Option<ConfigEntry> {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Manifest::load(&dir).unwrap().config("tinylm_ds32").unwrap().clone())
    }

    #[test]
    fn init_matches_specs() {
        let Some(c) = cfg() else { return };
        let p = ParamStore::init(&c, 0);
        p.check_matches(&c).unwrap();
        // ln gains init to ones, embeddings to noise
        assert!(p.get("l0.ln1.g").unwrap().data.iter().all(|&x| x == 1.0));
        assert!(p.get("emb.tok").unwrap().data.iter().any(|&x| x != 0.0));
        // scaled init has smaller magnitude than base init
        let wo = p.get("l0.attn.wo").unwrap();
        let wq = p.get("l0.attn.wq").unwrap();
        let rms = |t: &Tensor| {
            (t.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
                / t.len() as f64)
                .sqrt()
        };
        assert!(rms(wo) < rms(wq));
    }

    #[test]
    fn deterministic_init() {
        let Some(c) = cfg() else { return };
        let a = ParamStore::init(&c, 7);
        let b = ParamStore::init(&c, 7);
        assert_eq!(a.tensors, b.tensors);
        let c2 = ParamStore::init(&c, 8);
        assert_ne!(a.tensors, c2.tensors);
    }

    #[test]
    fn save_load_roundtrip() {
        let Some(c) = cfg() else { return };
        let p = ParamStore::init(&c, 3);
        let path = std::env::temp_dir().join("params_roundtrip.tkw");
        p.save(&path).unwrap();
        let q = ParamStore::load(&path).unwrap();
        assert_eq!(p.names, q.names);
        assert_eq!(p.tensors, q.tensors);
        q.check_matches(&c).unwrap();
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn set_rejects_bad_shape() {
        let Some(c) = cfg() else { return };
        let mut p = ParamStore::init(&c, 0);
        assert!(p.set("emb.tok", Tensor::zeros(&[2, 2])).is_err());
        let shape = p.get("ln_f.g").unwrap().shape.clone();
        assert!(p.set("ln_f.g", Tensor::zeros(&shape)).is_ok());
    }
}
