//! PJRT execution: compile HLO-text artifacts once, cache the executables,
//! execute with `Tensor`/`TensorI32` arguments.
//!
//! Follows the /opt/xla-example/load_hlo pattern: `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` →
//! `execute`, then unwrap the 1-tuple (aot.py lowers with
//! `return_tuple=True`) and decompose into per-output literals.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::faults::{FaultInjector, FaultKind, FaultPlan,
                             InjectedFault};
use crate::runtime::manifest::{ArtifactEntry, Manifest};
use crate::substrate::tensor::{Tensor, TensorI32, TensorI8};

/// A runtime argument: f32 tensor, i32 tensor, i8 tensor (quantized
/// KV-cache payloads), scalars, or a pre-built literal (the hot-path
/// fast lane — skips the host-side conversion; see EXPERIMENTS.md §Perf).
pub enum Arg<'a> {
    F(&'a Tensor),
    I(&'a TensorI32),
    I8(&'a TensorI8),
    ScalarF(f32),
    ScalarI(i32),
    L(&'a xla::Literal),
}

/// The XLA element type a manifest input-spec dtype string names. The
/// manifest records numpy dtype names (aot.py `str(s.dtype)`).
fn spec_element_type(dtype: &str) -> Result<xla::ElementType> {
    match dtype {
        "float32" => Ok(xla::ElementType::F32),
        "int32" => Ok(xla::ElementType::S32),
        "int8" => Ok(xla::ElementType::S8),
        other => bail!("unsupported manifest dtype {other:?}"),
    }
}

pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// (artifact, compile seconds) log — surfaced by the perf report.
    pub compile_log: RefCell<Vec<(String, f64)>>,
    /// Seeded fault injector (chaos testing / `serve --fault-plan`).
    /// `None` in production: the execute path is then byte-identical to
    /// a build without fault injection.
    fault: RefCell<Option<FaultInjector>>,
}

impl Runtime {
    /// Load the manifest from [`crate::artifacts_dir`] and create the CPU
    /// PJRT client.
    pub fn new() -> Result<Runtime> {
        Self::with_dir(crate::artifacts_dir())
    }

    pub fn with_dir(dir: PathBuf) -> Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            exes: RefCell::new(HashMap::new()),
            compile_log: RefCell::new(Vec::new()),
            fault: RefCell::new(None),
        })
    }

    /// Install a seeded fault schedule on the execute boundary. An empty
    /// plan uninstalls the injector entirely, restoring the exact
    /// production code path.
    pub fn install_fault_plan(&self, plan: FaultPlan) {
        *self.fault.borrow_mut() = if plan.is_empty() {
            None
        } else {
            Some(FaultInjector::new(plan))
        };
    }

    /// True when a (non-empty) fault plan is installed. The engine uses
    /// this to gate per-step state snapshots: without an injector a real
    /// execute error is Fatal anyway, so rollback bookkeeping would be
    /// pure overhead.
    pub fn fault_injection_active(&self) -> bool {
        self.fault.borrow().is_some()
    }

    /// Total faults injected so far (0 with no injector installed).
    pub fn faults_injected(&self) -> u64 {
        self.fault.borrow().as_ref().map_or(0, |f| f.injected())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest.artifact(name)
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.artifact(name)?;
        let path = self.dir.join(&entry.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        let secs = t0.elapsed().as_secs_f64();
        self.compile_log.borrow_mut().push((name.to_string(), secs));
        let rc = Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.exes.borrow().contains_key(name)
    }

    /// Execute an artifact with typed args; returns per-output literals.
    pub fn execute(&self, name: &str, args: &[Arg]) -> Result<Vec<xla::Literal>> {
        let entry = self.manifest.artifact(name)?;
        if args.len() != entry.inputs.len() {
            bail!(
                "{name}: got {} args, artifact wants {}",
                args.len(),
                entry.inputs.len()
            );
        }
        // Build owned literals for tensor/scalar args; Arg::L passes a
        // caller-cached literal through without conversion. Every tensor
        // and cached-literal arg is validated against the manifest spec —
        // shape AND dtype — so a stale literal (kept across a bucket/tier
        // resize, or an fp32 arena fed to a q8 artifact) fails fast here
        // instead of reaching XLA as an opaque executable error or a
        // silent byte reinterpretation.
        fn check_shape(name: &str, spec: &crate::runtime::manifest::InputSpec,
                       shape: &[usize], what: &str) -> Result<()> {
            if shape != spec.shape {
                bail!(
                    "{name}: {what} input {:?} shape {:?} != expected {:?} \
                     (stale literal after a bucket/tier resize?)",
                    spec.name, shape, spec.shape
                );
            }
            Ok(())
        }
        fn check_dtype(name: &str, spec: &crate::runtime::manifest::InputSpec,
                       dtype: &str) -> Result<()> {
            if spec.dtype != dtype {
                bail!(
                    "{name}: input {:?} dtype {dtype} != expected {:?} \
                     (fp32 cache literal fed to a quantized artifact, or \
                     vice versa?)",
                    spec.name, spec.dtype
                );
            }
            Ok(())
        }
        let mut owned: Vec<Option<xla::Literal>> = Vec::with_capacity(args.len());
        for (a, spec) in args.iter().zip(&entry.inputs) {
            let lit = match a {
                Arg::F(t) => {
                    check_shape(name, spec, &t.shape, "tensor")?;
                    check_dtype(name, spec, "float32")?;
                    Some(tensor_to_literal(t)?)
                }
                Arg::I(t) => {
                    check_shape(name, spec, &t.shape, "tensor")?;
                    check_dtype(name, spec, "int32")?;
                    Some(tensor_i32_to_literal(t)?)
                }
                Arg::I8(t) => {
                    check_shape(name, spec, &t.shape, "tensor")?;
                    check_dtype(name, spec, "int8")?;
                    Some(tensor_i8_to_literal(t)?)
                }
                Arg::ScalarF(v) => Some(xla::Literal::scalar(*v)),
                Arg::ScalarI(v) => Some(xla::Literal::scalar(*v)),
                Arg::L(l) => {
                    let shape = l.array_shape().map_err(|e| {
                        anyhow::anyhow!(
                            "{name}: cached literal input {:?} has no array \
                             shape: {e}",
                            spec.name
                        )
                    })?;
                    let dims: Vec<usize> =
                        shape.dims().iter().map(|&d| d as usize).collect();
                    check_shape(name, spec, &dims, "cached literal")?;
                    let want = spec_element_type(&spec.dtype)?;
                    if shape.ty() != want {
                        bail!(
                            "{name}: cached literal input {:?} element type \
                             {:?} != expected {:?} ({}) — stale fp32 arena \
                             fed to a q8 artifact?",
                            spec.name, shape.ty(), want, spec.dtype
                        );
                    }
                    None
                }
            };
            owned.push(lit);
        }
        let refs: Vec<&xla::Literal> = args
            .iter()
            .zip(&owned)
            .map(|(a, o)| match (a, o) {
                (Arg::L(l), _) => *l,
                (_, Some(lit)) => lit,
                _ => unreachable!(),
            })
            .collect();
        // Fault injection point: decided only after argument validation,
        // so injected faults model device-side failures on otherwise
        // well-formed calls (real validation bugs still surface as
        // themselves). The borrow is scoped — the injector must not stay
        // borrowed across the execute, which may re-enter metrics paths.
        let decision = self
            .fault
            .borrow_mut()
            .as_mut()
            .map(|f| f.decide(name));
        if let Some(d) = decision {
            if d.latency_us > 0 {
                std::thread::sleep(
                    std::time::Duration::from_micros(d.latency_us),
                );
            }
            if let Some(kind) = d.error {
                let fault = InjectedFault {
                    kind,
                    lane_hint: d.lane_hint,
                };
                return Err(anyhow::Error::new(fault).context(format!(
                    "injected {kind} fault before execute({name})"
                )));
            }
        }
        let exe = self.load(name)?;
        let result = exe
            .execute::<&xla::Literal>(&refs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download {name}: {e}"))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e}"))?;
        if outs.len() != entry.outputs.len() {
            bail!(
                "{name}: got {} outputs, manifest says {}",
                outs.len(),
                entry.outputs.len()
            );
        }
        // Corrupt-output fault: execution "succeeded" but the literal is
        // to be treated as garbage — drop the real outputs and error, so
        // a corrupt row can never be scattered into the host mirror.
        if let Some(d) = decision {
            if d.corrupt {
                let fault = InjectedFault {
                    kind: FaultKind::CorruptOutput,
                    lane_hint: d.lane_hint,
                };
                return Err(anyhow::Error::new(fault).context(format!(
                    "injected corrupt-output fault in execute({name})"
                )));
            }
        }
        Ok(outs)
    }
}

// --- literal conversions ---

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // scalar: reshape to rank-0
        return lit
            .reshape(&[])
            .map_err(|e| anyhow::anyhow!("reshape scalar: {e}"));
    }
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

pub fn tensor_i32_to_literal(t: &TensorI32) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        return lit
            .reshape(&[])
            .map_err(|e| anyhow::anyhow!("reshape scalar: {e}"));
    }
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

pub fn tensor_i8_to_literal(t: &TensorI8) -> Result<xla::Literal> {
    i8_slice_to_literal(&t.data, &t.shape)
}

/// Build an s8 literal straight from a byte slice + logical shape — the
/// upload path for quantized arenas (no intermediate Tensor).
pub fn i8_slice_to_literal(data: &[i8], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape i8: {e}"))
}

/// Build an f32 literal straight from a value slice + logical shape —
/// the arena/scale-plane upload path (no intermediate Tensor).
pub fn f32_slice_to_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape f32: {e}"))
}

/// Download an s8 literal's payload (quantized delta rows).
pub fn literal_to_vec_i8(lit: &xla::Literal) -> Result<Vec<i8>> {
    lit.to_vec::<i8>()
        .map_err(|e| anyhow::anyhow!("to_vec<i8>: {e}"))
}

/// Download an f32 literal's payload (delta-row scales).
pub fn literal_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec<f32>: {e}"))
}

pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec<f32>: {e}"))?;
    Ok(Tensor::new(&dims, data))
}

pub fn literal_to_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow::anyhow!("scalar: {e}"))
}
