//! AOT runtime: loads `artifacts/manifest.json` + `*.hlo.txt` produced by
//! `make artifacts` and executes them on the PJRT CPU client.
//!
//! This is the only boundary between rust and the XLA world; everything
//! above it (training harness, serving engine, experiments) works with
//! [`crate::substrate::tensor::Tensor`]s and artifact names.

pub mod manifest;
pub mod client;
pub mod faults;
pub mod params;

pub use client::Runtime;
pub use faults::{FaultInjector, FaultKind, FaultPlan, InjectedFault};
pub use manifest::{ArtifactEntry, ConfigEntry, KvQuant, Manifest,
                   ParamSpecEntry};
pub use params::ParamStore;
