//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime: model configs (with ordered parameter specs and
//! init recipes) and the artifact inventory (file, kind, geometry, exact
//! input/output signatures).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::substrate::json::Value;
pub use crate::substrate::tensor::KvQuant;

#[derive(Clone, Debug)]
pub struct ParamSpecEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String, // "normal" | "normal_scaled" | "zeros" | "ones"
    pub std: f64,
    pub wd: bool,
    pub qk: bool,
}

impl ParamSpecEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Mirror of python `configs.ModelConfig` (+ derived fields + param specs).
#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub name: String,
    pub arch: String,
    pub attn: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_select: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub d_c: usize,
    pub d_r: usize,
    pub d_qk_head: usize,
    pub d_v_head: usize,
    pub k_cache_dims: usize,
    pub v_cache_dims: usize,
    pub kv_budget: usize,
    pub train_batch: usize,
    pub train_seq: usize,
    pub params: Vec<ParamSpecEntry>,
}

impl ConfigEntry {
    pub fn n_parameters(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn qk_parameters(&self) -> usize {
        self.params.iter().filter(|p| p.qk).map(|p| p.numel()).sum()
    }

    /// GQA group size (query heads per kv head).
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }
}

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub dtype: String, // "float32" | "int32"
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub kind: String, // train | qkft | evalloss | logits | prefill | decode
    pub config: String,
    pub geom: BTreeMap<String, String>,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
    pub n_params: usize,
}

impl ArtifactEntry {
    /// Does this artifact export `name` among its outputs? The engine
    /// gates optional-output parsing on this (e.g. the `attn_mass` plane
    /// appended in ISSUE 10 — absent on legacy manifests).
    pub fn has_output(&self, name: &str) -> bool {
        self.outputs.iter().any(|o| o == name)
    }
}

#[derive(Clone, Debug)]
pub struct AdamConfig {
    pub b1: f64,
    pub b2: f64,
    pub eps: f64,
    pub weight_decay: f64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub adam: AdamConfig,
    pub decode_batches: Vec<usize>,
    /// Context-tier axis of the decode artifact grid: serving config →
    /// exported arena lengths N (ascending, last == max_seq). Decode
    /// artifacts are specialized per (batch bucket, tier) so the engine
    /// can size its arenas to the live context instead of max context.
    /// Empty for manifests exported before tiering (single max_seq tier).
    pub decode_tiers: BTreeMap<String, Vec<usize>>,
    /// Chunked-prefill axis: serving config → exported chunk lengths C
    /// (ascending). Each `prefill_{cfg}_c{C}` artifact processes C prompt
    /// positions against the `prefill_seq`-length arena, resumably — the
    /// scheduler interleaves one chunk per round with decode steps. Empty
    /// for manifests exported before chunking (monolithic prefill only).
    pub prefill_chunks: BTreeMap<String, Vec<usize>>,
    /// KV-cache quantization axis (ISSUE 4): serving config → exported
    /// quant-mode names ("fp32", "q8"). q8 decode/chunk artifacts carry
    /// int8 arenas with per-row fp32 scale planes and are named with a
    /// `_q8` suffix. Empty for manifests exported before quantization —
    /// the engine then only offers the fp32 path.
    pub kv_quant: BTreeMap<String, Vec<String>>,
    pub prefill_seq: usize,
    /// Export-contract revision stamped by `python/compile/aot.py`
    /// (`SCHEMA_VERSION`). Bumped whenever the artifact naming scheme or
    /// the manifest geometry contract changes; `thinkeys check` refuses to
    /// audit manifests older than the checker's grammar. Manifests exported
    /// before the stamp existed default to 1.
    pub schema_version: usize,
    pub configs: BTreeMap<String, ConfigEntry>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {path:?} — run `make artifacts` first \
                 (python never runs at request time, but it must run once \
                 at build time)"
            )
        })?;
        let v = Value::parse(&text)?;
        if v.get("version")?.as_usize()? != 1 {
            bail!("unsupported manifest version");
        }
        let adam_v = v.get("adam")?;
        let adam = AdamConfig {
            b1: adam_v.get("b1")?.as_f64()?,
            b2: adam_v.get("b2")?.as_f64()?,
            eps: adam_v.get("eps")?.as_f64()?,
            weight_decay: adam_v.get("weight_decay")?.as_f64()?,
        };
        let decode_batches = v
            .get("decode_batches")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let mut decode_tiers = BTreeMap::new();
        if let Some(dt) = v.opt("decode_tiers") {
            for (name, tv) in dt.as_obj()? {
                let tiers = tv
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_usize())
                    .collect::<Result<Vec<_>>>()?;
                decode_tiers.insert(name.clone(), tiers);
            }
        }
        let mut prefill_chunks = BTreeMap::new();
        if let Some(pc) = v.opt("prefill_chunks") {
            for (name, cv) in pc.as_obj()? {
                let chunks = cv
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_usize())
                    .collect::<Result<Vec<_>>>()?;
                prefill_chunks.insert(name.clone(), chunks);
            }
        }
        let mut kv_quant = BTreeMap::new();
        if let Some(kq) = v.opt("kv_quant") {
            for (name, qv) in kq.as_obj()? {
                let quants = qv
                    .as_arr()?
                    .iter()
                    .map(|x| Ok(x.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?;
                kv_quant.insert(name.clone(), quants);
            }
        }
        let prefill_seq = v.get("prefill_seq")?.as_usize()?;

        let mut configs = BTreeMap::new();
        for (name, cv) in v.get("configs")?.as_obj()? {
            let params = cv
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamSpecEntry {
                        name: p.get("name")?.as_str()?.to_string(),
                        shape: p.get("shape")?.shape_vec()?,
                        init: p.get("init")?.as_str()?.to_string(),
                        std: p.get("std")?.as_f64()?,
                        wd: p.get("wd")?.as_bool()?,
                        qk: p.get("qk")?.as_bool()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let g = |k: &str| -> Result<usize> { cv.get(k)?.as_usize() };
            configs.insert(
                name.clone(),
                ConfigEntry {
                    name: name.clone(),
                    arch: cv.get("arch")?.as_str()?.to_string(),
                    attn: cv.get("attn")?.as_str()?.to_string(),
                    vocab: g("vocab")?,
                    d_model: g("d_model")?,
                    n_layers: g("n_layers")?,
                    n_heads: g("n_heads")?,
                    n_kv_heads: g("n_kv_heads")?,
                    d_select: g("d_select")?,
                    d_ff: g("d_ff")?,
                    max_seq: g("max_seq")?,
                    d_c: g("d_c")?,
                    d_r: g("d_r")?,
                    d_qk_head: g("d_qk_head")?,
                    d_v_head: g("d_v_head")?,
                    k_cache_dims: g("k_cache_dims")?,
                    v_cache_dims: g("v_cache_dims")?,
                    kv_budget: g("kv_budget")?,
                    train_batch: g("train_batch")?,
                    train_seq: g("train_seq")?,
                    params,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for av in v.get("artifacts")?.as_arr()? {
            let inputs = av
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|i| {
                    let t = i.as_arr()?;
                    Ok(InputSpec {
                        name: t[0].as_str()?.to_string(),
                        dtype: t[1].as_str()?.to_string(),
                        shape: t[2].shape_vec()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = av
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(|o| Ok(o.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            let mut geom = BTreeMap::new();
            for (k, gv) in av.get("geom")?.as_obj()? {
                let s = match gv {
                    Value::Str(s) => s.clone(),
                    Value::Num(n) => format!("{}", *n as i64),
                    _ => bail!("bad geom value"),
                };
                geom.insert(k.clone(), s);
            }
            let name = av.get("name")?.as_str()?.to_string();
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name,
                    file: av.get("file")?.as_str()?.to_string(),
                    kind: av.get("kind")?.as_str()?.to_string(),
                    config: av.get("config")?.as_str()?.to_string(),
                    geom,
                    inputs,
                    outputs,
                    n_params: av.get("n_params")?.as_usize()?,
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            adam,
            decode_batches,
            decode_tiers,
            prefill_chunks,
            kv_quant,
            prefill_seq,
            schema_version: match v.opt("schema_version") {
                Some(sv) => sv.as_usize()?,
                None => 1,
            },
            configs,
            artifacts,
        })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigEntry> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown config {name:?}"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name:?}"))
    }

    /// Artifact naming convention helpers (mirror aot.py `add()`).
    pub fn train_name(&self, cfg: &str) -> String {
        let c = &self.configs[cfg];
        format!("train_{cfg}_b{}_s{}", c.train_batch, c.train_seq)
    }

    pub fn qkft_name(&self, cfg: &str) -> String {
        let c = &self.configs[cfg];
        format!("qkft_{cfg}_b{}_s{}", c.train_batch, c.train_seq)
    }

    pub fn evalloss_name(&self, cfg: &str) -> String {
        let c = &self.configs[cfg];
        format!("evalloss_{cfg}_b{}_s{}", c.train_batch, c.train_seq)
    }

    pub fn logits_name(&self, cfg: &str) -> String {
        let c = &self.configs[cfg];
        format!("logits_{cfg}_b{}_s{}", c.train_batch, c.train_seq)
    }

    pub fn prefill_name(&self, cfg: &str, pallas: bool) -> String {
        let suffix = if pallas { "_pallas" } else { "" };
        format!("prefill_{cfg}_s{}{suffix}", self.prefill_seq)
    }

    /// Chunk lengths exported for `cfg`'s resumable prefill artifacts,
    /// ascending. Empty on manifests exported before chunking — the
    /// engine then only offers the monolithic prefill path.
    pub fn chunks_for(&self, cfg: &str) -> Vec<usize> {
        self.prefill_chunks.get(cfg).cloned().unwrap_or_default()
    }

    /// `prefill_{cfg}_c{chunk}` / `prefill_{cfg}_c{chunk}_q8` — the
    /// resumable chunked-prefill artifact (ref impl only; there is no
    /// `_pallas` chunk column, see aot.py).
    pub fn prefill_chunk_name(&self, cfg: &str, chunk: usize,
                              quant: KvQuant) -> String {
        format!("prefill_{cfg}_c{chunk}{}", quant.suffix())
    }

    /// KV quant modes exported for `cfg`'s serving artifacts. Falls back
    /// to fp32-only for manifests exported before quantization, so the
    /// engine refuses q8 on them instead of inventing artifact names.
    pub fn kv_quants_for(&self, cfg: &str) -> Vec<KvQuant> {
        match self.kv_quant.get(cfg) {
            Some(names) if !names.is_empty() => {
                names.iter().filter_map(|n| KvQuant::parse(n)).collect()
            }
            _ => vec![KvQuant::Fp32],
        }
    }

    /// Arena-length tiers exported for `cfg`'s decode artifacts, ascending.
    /// Falls back to a single full-context tier for manifests exported
    /// before the (bucket × tier) grid existed.
    pub fn tiers_for(&self, cfg: &str) -> Vec<usize> {
        if let Some(t) = self.decode_tiers.get(cfg) {
            if !t.is_empty() {
                return t.clone();
            }
        }
        self.configs
            .get(cfg)
            .map(|c| vec![c.max_seq])
            .unwrap_or_default()
    }

    /// `decode_{cfg}_b{batch}_n{tier}[_q8][_pallas]` on tiered manifests;
    /// pre-tier manifests keep the legacy un-suffixed name (tier is then
    /// always max_seq, and only fp32 exists).
    pub fn decode_name(&self, cfg: &str, batch: usize, tier: usize,
                       pallas: bool, quant: KvQuant) -> String {
        let q = quant.suffix();
        let suffix = if pallas { "_pallas" } else { "" };
        if self.decode_tiers.contains_key(cfg) {
            format!("decode_{cfg}_b{batch}_n{tier}{q}{suffix}")
        } else {
            format!("decode_{cfg}_b{batch}{q}{suffix}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest() else { return };
        assert!(m.configs.len() >= 20, "{}", m.configs.len());
        assert!(m.artifacts.len() >= 80);
        assert_eq!(m.decode_batches, vec![1, 2, 4, 8, 16, 32]);
        let c = m.config("tinylm_ds64").unwrap();
        assert_eq!(c.d_model, 64);
        assert_eq!(c.d_qk_head, 8);
        assert_eq!(c.kv_budget, 128);
        let thin = m.config("tinylm_ds32").unwrap();
        assert_eq!(thin.d_qk_head, 4);
        assert!(thin.n_parameters() < c.n_parameters());
    }

    #[test]
    fn param_specs_ordered_and_typed() {
        let Some(m) = manifest() else { return };
        let c = m.config("llama_ds32").unwrap();
        assert_eq!(c.params[0].name, "emb.tok");
        assert_eq!(c.params[0].shape, vec![c.vocab, c.d_model]);
        assert!(c.params.iter().any(|p| p.qk && p.name.contains("wq")));
        assert!(c.params.iter().any(|p| p.init == "normal_scaled"));
        // llama has no biases / learned positions
        assert!(!c.params.iter().any(|p| p.name == "emb.pos"));
    }

    #[test]
    fn naming_helpers_resolve_to_real_artifacts() {
        let Some(m) = manifest() else { return };
        let tier = *m.tiers_for("servethin").first().unwrap();
        for n in [
            m.train_name("tinylm_ds64"),
            m.qkft_name("tinylm_ds32"),
            m.evalloss_name("tinylm_ds32"),
            m.logits_name("copyback_ds4"),
            m.prefill_name("servethin", false),
            m.decode_name("servethin", 8, tier, false, KvQuant::Fp32),
            m.decode_name("servethin", 8, tier, true, KvQuant::Fp32),
            m.decode_name("servethin", 8, tier, false, KvQuant::Q8),
            m.decode_name("servethin", 8, tier, true, KvQuant::Q8),
            m.prefill_chunk_name("servethin", 32, KvQuant::Q8),
        ] {
            assert!(m.artifacts.contains_key(&n), "missing artifact {n}");
            assert!(m.dir.join(&m.artifacts[&n].file).exists());
        }
    }

    /// Tier roundtrip: the manifest records the context-tier axis, every
    /// (bucket × tier) decode name resolves to a real artifact, and the
    /// recorded cache input shapes are sized by the tier, not max_seq.
    #[test]
    fn decode_tier_grid_resolves_for_every_tier() {
        let Some(m) = manifest() else { return };
        for cfg_name in ["servefull", "servethin", "servegqa",
                         "servegqathin"] {
            let cfg = m.config(cfg_name).unwrap();
            let tiers = m.tiers_for(cfg_name);
            assert!(!tiers.is_empty());
            assert_eq!(*tiers.last().unwrap(), cfg.max_seq);
            assert!(tiers.windows(2).all(|w| w[0] < w[1]), "{tiers:?}");
            for &b in &m.decode_batches {
                for &n in &tiers {
                    let name = m.decode_name(cfg_name, b, n, false,
                                             KvQuant::Fp32);
                    let a = m
                        .artifact(&name)
                        .unwrap_or_else(|_| panic!("missing {name}"));
                    let kc = a
                        .inputs
                        .iter()
                        .find(|i| i.name == "k_cache")
                        .unwrap();
                    assert_eq!(
                        kc.shape,
                        vec![cfg.n_layers, b, n, cfg.k_cache_dims]
                    );
                    // the delta-sync contract: per-step written rows are
                    // exported alongside the full arenas, and ISSUE 10
                    // appends the per-row attention-mass plane the
                    // eviction scorer consumes
                    assert_eq!(
                        &a.outputs[a.outputs.len() - 3..],
                        ["k_rows", "v_rows", "attn_mass"].map(String::from)
                    );
                }
            }
        }
    }

    /// q8 roundtrip: the manifest records the quant axis, every
    /// (bucket × tier) q8 decode name resolves, the recorded input specs
    /// carry int8 arenas + per-row fp32 scale planes, and the outputs end
    /// in the quantized delta rows + scales the engine mirrors.
    #[test]
    fn q8_decode_grid_resolves_with_int8_specs() {
        let Some(m) = manifest() else { return };
        for cfg_name in ["servefull", "servethin", "servegqa",
                         "servegqathin"] {
            let cfg = m.config(cfg_name).unwrap();
            assert_eq!(m.kv_quants_for(cfg_name),
                       vec![KvQuant::Fp32, KvQuant::Q8]);
            for &b in &m.decode_batches {
                for &n in &m.tiers_for(cfg_name) {
                    let name = m.decode_name(cfg_name, b, n, false,
                                             KvQuant::Q8);
                    let a = m
                        .artifact(&name)
                        .unwrap_or_else(|_| panic!("missing {name}"));
                    let by = |nm: &str| {
                        a.inputs.iter().find(|i| i.name == nm).unwrap()
                    };
                    assert_eq!(by("k_cache").dtype, "int8");
                    assert_eq!(
                        by("k_cache").shape,
                        vec![cfg.n_layers, b, n, cfg.k_cache_dims]
                    );
                    assert_eq!(by("k_scale").dtype, "float32");
                    assert_eq!(by("k_scale").shape,
                               vec![cfg.n_layers, b, n]);
                    assert_eq!(by("v_cache").dtype, "int8");
                    assert_eq!(by("v_scale").shape,
                               vec![cfg.n_layers, b, n]);
                    assert_eq!(
                        &a.outputs[a.outputs.len() - 5..],
                        ["k_rows", "k_row_scale", "v_rows", "v_row_scale",
                         "attn_mass"]
                            .map(String::from)
                    );
                }
            }
            // q8 chunk column: int8 arenas against the prefill_seq bucket
            for &c in &m.chunks_for(cfg_name) {
                let name = m.prefill_chunk_name(cfg_name, c, KvQuant::Q8);
                let a = m
                    .artifact(&name)
                    .unwrap_or_else(|_| panic!("missing {name}"));
                let kc = a.inputs.iter().find(|i| i.name == "k_cache")
                    .unwrap();
                assert_eq!(kc.dtype, "int8");
                assert_eq!(kc.shape,
                           vec![cfg.n_layers, m.prefill_seq,
                                cfg.k_cache_dims]);
            }
        }
    }

    /// Pre-quantization manifests (no `kv_quant` key) resolve to
    /// fp32-only — the engine then refuses q8 instead of inventing names.
    #[test]
    fn legacy_manifest_kv_quant_fallback() {
        let Some(mut m) = manifest() else { return };
        m.kv_quant.clear();
        assert_eq!(m.kv_quants_for("servethin"), vec![KvQuant::Fp32]);
        assert_eq!(m.kv_quants_for("no_such_config"), vec![KvQuant::Fp32]);
    }

    /// Chunk roundtrip: the manifest records the chunked-prefill axis,
    /// every chunk name resolves to a real artifact whose recorded input
    /// shapes carry the prefill_seq arena + (1, C) token window + the
    /// start/length scalars, and whose outputs end in the per-chunk delta
    /// rows the engine mirrors host-side.
    #[test]
    fn prefill_chunk_axis_resolves_for_every_chunk() {
        let Some(m) = manifest() else { return };
        for cfg_name in ["servefull", "servethin", "servegqa",
                         "servegqathin"] {
            let cfg = m.config(cfg_name).unwrap();
            let chunks = m.chunks_for(cfg_name);
            assert!(!chunks.is_empty(), "no chunk axis for {cfg_name}");
            assert!(chunks.windows(2).all(|w| w[0] < w[1]), "{chunks:?}");
            for &c in &chunks {
                let name = m.prefill_chunk_name(cfg_name, c, KvQuant::Fp32);
                let a = m
                    .artifact(&name)
                    .unwrap_or_else(|_| panic!("missing {name}"));
                let by = |n: &str| {
                    a.inputs.iter().find(|i| i.name == n).unwrap()
                };
                assert_eq!(
                    by("k_cache").shape,
                    vec![cfg.n_layers, m.prefill_seq, cfg.k_cache_dims]
                );
                assert_eq!(
                    by("v_cache").shape,
                    vec![cfg.n_layers, m.prefill_seq, cfg.v_cache_dims]
                );
                assert_eq!(by("tokens").shape, vec![1, c]);
                assert!(by("start").shape.is_empty());
                assert!(by("length").shape.is_empty());
                assert_eq!(
                    &a.outputs[a.outputs.len() - 2..],
                    ["k_rows".to_string(), "v_rows".to_string()]
                );
            }
        }
    }

    /// Pre-chunking manifests (no `prefill_chunks` key) resolve to an
    /// empty chunk list — the scheduler then refuses chunked mode instead
    /// of inventing artifact names.
    #[test]
    fn legacy_manifest_chunk_fallback() {
        let Some(mut m) = manifest() else { return };
        m.prefill_chunks.clear();
        assert_eq!(m.chunks_for("servethin"), Vec::<usize>::new());
        assert_eq!(m.chunks_for("no_such_config"), Vec::<usize>::new());
    }

    /// Pre-tier manifests (no `decode_tiers` key) keep resolving: a single
    /// max_seq tier and the legacy artifact name.
    #[test]
    fn legacy_manifest_tier_fallback() {
        let Some(mut m) = manifest() else { return };
        m.decode_tiers.clear();
        let max = m.config("servethin").unwrap().max_seq;
        assert_eq!(m.tiers_for("servethin"), vec![max]);
        assert_eq!(
            m.decode_name("servethin", 8, max, false, KvQuant::Fp32),
            "decode_servethin_b8"
        );
        assert_eq!(m.tiers_for("no_such_config"), Vec::<usize>::new());
    }

    /// The GQA serving pair (ISSUE 5): the manifest records the grouped
    /// head geometry and the cache widths are KV-head-sized — the
    /// contract every engine arena, mirror, and byte gauge is built on.
    #[test]
    fn gqa_serving_configs_record_grouped_geometry() {
        let Some(m) = manifest() else { return };
        let full = m.config("servefull").unwrap();
        assert_eq!(full.group(), 1);
        for name in ["servegqa", "servegqathin"] {
            let c = m.config(name).unwrap();
            assert_eq!(c.attn, "gqa");
            assert_eq!(c.n_heads, 8);
            assert_eq!(c.n_kv_heads, 2);
            assert_eq!(c.group(), 4);
            assert_eq!(c.k_cache_dims, c.n_kv_heads * c.d_qk_head);
            assert_eq!(c.v_cache_dims, c.n_kv_heads * c.d_v_head);
            assert_eq!(c.max_seq, full.max_seq, "tier tables must match");
            assert_eq!(m.tiers_for(name), m.tiers_for("servefull"));
            assert_eq!(m.kv_quants_for(name),
                       vec![KvQuant::Fp32, KvQuant::Q8]);
        }
        // the composed widths: group 4x, then rank 4x on K only
        let gqa = m.config("servegqa").unwrap();
        let thin = m.config("servegqathin").unwrap();
        assert_eq!(gqa.k_cache_dims * 4, full.k_cache_dims);
        assert_eq!(thin.k_cache_dims * 16, full.k_cache_dims);
        assert_eq!(thin.v_cache_dims, gqa.v_cache_dims);
    }

    #[test]
    fn artifact_inputs_start_with_params() {
        let Some(m) = manifest() else { return };
        let a = m.artifact(&m.train_name("copyback_ds4")).unwrap();
        let c = m.config("copyback_ds4").unwrap();
        assert_eq!(a.n_params, c.params.len());
        for (i, p) in c.params.iter().enumerate() {
            assert_eq!(a.inputs[i].name, p.name);
            assert_eq!(a.inputs[i].shape, p.shape);
        }
        assert_eq!(a.inputs.len(), 3 * c.params.len() + 5);
    }

    #[test]
    fn thin_param_savings_match_paper_ratio() {
        let Some(m) = manifest() else { return };
        // d_select = d_model/4 -> 75% QK parameter saving (paper §1)
        let full = m.config("tinylm_ds64").unwrap().qk_parameters() as f64;
        let thin = m.config("tinylm_ds16").unwrap().qk_parameters() as f64;
        assert!((1.0 - thin / full - 0.75).abs() < 0.01);
    }
}
