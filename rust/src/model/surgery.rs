//! Factored keys (paper §2.3): per-head truncated SVD of the pretrained key
//! projection with *query-side absorption*.
//!
//! For each kv head `j` with full per-head dim `d_h` and target rank `r`:
//!
//! ```text
//! W_K^(j) ≈ A·Bᵀ,  A = U_r Σ_r ∈ R^{d×r}  (thin key projection — CACHED)
//!                  B = V_r    ∈ R^{d_h×r}
//! W_Q^(i)' = W_Q^(i) · V_r · sqrt(r/d_h)   for every query head i in j's
//!                                          group (absorbed — EPHEMERAL)
//! ```
//!
//! The `sqrt(r/d_h)` factor corrects the softmax scale: the thin model
//! divides scores by `sqrt(r)` where the original divided by `sqrt(d_h)`,
//! so raw scores are rescaled to keep `softmax(q'k'ᵀ/√r) ==
//! softmax(qkᵀ/√d_h)` exactly (at full rank) — a subtlety the paper's
//! "scores preserved exactly" claim glosses over but any implementation
//! needs.
//!
//! Invariant (tested below + in `rust/tests/surgery_equivalence.rs`): the
//! thin deployment's attention scores equal the scores of the *same* model
//! with `W_K` replaced by its rank-r reconstruction — so Table 1's K-only
//! PPL measurements are exactly the deployed factored-key PPL.

use anyhow::{bail, Result};

use crate::runtime::manifest::ConfigEntry;
use crate::runtime::params::ParamStore;
use crate::substrate::linalg::{low_rank_approx, truncated_factor};
use crate::substrate::tensor::Tensor;

/// Which projections to compress in the Table-1 ablation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AblationMode {
    KOnly,
    QOnly,
    Both,
}

/// Split a packed projection (d, n_heads*d_head) into per-head (d, d_head).
fn split_heads(w: &Tensor, n_heads: usize) -> Vec<Tensor> {
    let dh = w.shape[1] / n_heads;
    (0..n_heads).map(|h| w.cols(h * dh, (h + 1) * dh)).collect()
}

fn join_heads(parts: &[Tensor]) -> Tensor {
    let refs: Vec<&Tensor> = parts.iter().collect();
    Tensor::hcat(&refs)
}

fn check_factorable(cfg: &ConfigEntry) -> Result<()> {
    if cfg.attn == "mla" {
        bail!("factored keys target MHA/GQA models; MLA already stores a latent");
    }
    Ok(())
}

/// Factor a pretrained full-dim model into the thin configuration.
///
/// `full` must match `full_cfg`; the result matches `thin_cfg` (same
/// architecture, smaller `d_select`). Only `W_Q`/`W_K` change — everything
/// else is copied verbatim (the paper's "nothing else in the network
/// changes").
pub fn factor_to_thin(
    full: &ParamStore,
    full_cfg: &ConfigEntry,
    thin_cfg: &ConfigEntry,
) -> Result<ParamStore> {
    check_factorable(full_cfg)?;
    full.check_matches(full_cfg)?;
    for (a, b, what) in [
        (full_cfg.arch.as_str(), thin_cfg.arch.as_str(), "arch"),
        (full_cfg.attn.as_str(), thin_cfg.attn.as_str(), "attn"),
    ] {
        if a != b {
            bail!("config mismatch: {what} {a:?} vs {b:?}");
        }
    }
    if full_cfg.d_model != thin_cfg.d_model
        || full_cfg.n_layers != thin_cfg.n_layers
        || full_cfg.n_heads != thin_cfg.n_heads
        || full_cfg.n_kv_heads != thin_cfg.n_kv_heads
        || full_cfg.vocab != thin_cfg.vocab
    {
        bail!("factor_to_thin: architectures are not surgery-compatible");
    }
    let r = thin_cfg.d_qk_head;
    let dh = full_cfg.d_qk_head;
    if r > dh {
        bail!("thin rank {r} exceeds full per-head dim {dh}");
    }
    let scale = ((r as f64) / (dh as f64)).sqrt() as f32;
    let group = full_cfg.group();

    let mut out_names = Vec::with_capacity(thin_cfg.params.len());
    let mut out_tensors = Vec::with_capacity(thin_cfg.params.len());
    for spec in &thin_cfg.params {
        let t = if spec.name.ends_with(".attn.wk") {
            let wk = full.get(&spec.name)?;
            let heads = split_heads(wk, full_cfg.n_kv_heads);
            let thin: Vec<Tensor> = heads
                .iter()
                .map(|h| truncated_factor(h, r).0)
                .collect();
            join_heads(&thin)
        } else if spec.name.ends_with(".attn.wq") {
            let layer = spec.name.trim_end_matches(".attn.wq");
            let wq = full.get(&spec.name)?;
            let wk = full.get(&format!("{layer}.attn.wk"))?;
            let k_heads = split_heads(wk, full_cfg.n_kv_heads);
            let q_heads = split_heads(wq, full_cfg.n_heads);
            let absorbed: Vec<Tensor> = q_heads
                .iter()
                .enumerate()
                .map(|(i, qh)| {
                    let (_, vr) = truncated_factor(&k_heads[i / group], r);
                    qh.matmul(&vr).scale(scale)
                })
                .collect();
            join_heads(&absorbed)
        } else {
            full.get(&spec.name)?.clone()
        };
        if t.shape != spec.shape {
            bail!(
                "surgery produced {:?} for {:?}, spec wants {:?}",
                t.shape,
                spec.name,
                spec.shape
            );
        }
        out_names.push(spec.name.clone());
        out_tensors.push(t);
    }
    let store = ParamStore { names: out_names, tensors: out_tensors };
    store.check_matches(thin_cfg)?;
    Ok(store)
}

/// Table-1 ablation: replace `W_K`/`W_Q` by their per-head rank-r
/// reconstructions, keeping shapes (and therefore artifacts) unchanged.
pub fn low_rank_ablation(
    params: &ParamStore,
    cfg: &ConfigEntry,
    rank_per_head: usize,
    mode: AblationMode,
) -> Result<ParamStore> {
    check_factorable(cfg)?;
    params.check_matches(cfg)?;
    let mut out = params.clone();
    for layer in 0..cfg.n_layers {
        if mode != AblationMode::QOnly {
            let name = format!("l{layer}.attn.wk");
            let wk = params.get(&name)?;
            let heads = split_heads(wk, cfg.n_kv_heads);
            let recon: Vec<Tensor> = heads
                .iter()
                .map(|h| low_rank_approx(h, rank_per_head))
                .collect();
            out.set(&name, join_heads(&recon))?;
        }
        if mode != AblationMode::KOnly {
            let name = format!("l{layer}.attn.wq");
            let wq = params.get(&name)?;
            let heads = split_heads(wq, cfg.n_heads);
            let recon: Vec<Tensor> = heads
                .iter()
                .map(|h| low_rank_approx(h, rank_per_head))
                .collect();
            out.set(&name, join_heads(&recon))?;
        }
    }
    Ok(out)
}

/// K-cache bytes per token per layer for a config at a given element width
/// (the physical saving the surgery buys — used by the capacity planner).
pub fn k_cache_bytes_per_token(cfg: &ConfigEntry, bytes_per_el: f64) -> f64 {
    cfg.k_cache_dims as f64 * bytes_per_el
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use crate::substrate::rng::Rng;

    fn manifest() -> Option<Manifest> {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Manifest::load(&dir).unwrap())
    }

    /// Raw attention scores for head `h` given x (n×d): (x·Wq_h)(x·Wk_h)ᵀ/√dh.
    fn head_scores(x: &Tensor, wq: &Tensor, wk: &Tensor, h: usize,
                   n_heads: usize, kv_h: usize, n_kv: usize) -> Tensor {
        let q = split_heads(wq, n_heads)[h].clone();
        let k = split_heads(wk, n_kv)[kv_h].clone();
        let dh = q.shape[1] as f32;
        let qs = x.matmul(&q);
        let ks = x.matmul(&k);
        qs.matmul(&ks.t()).scale(1.0 / dh.sqrt())
    }

    #[test]
    fn full_rank_surgery_preserves_scores_exactly() {
        let Some(m) = manifest() else { return };
        // tinylm_ds64 -> tinylm_ds16? full dh=16; full-rank check needs a
        // thin cfg with r == dh, which doesn't exist; emulate by factoring
        // to ds128 itself is identity-rank. Use ds32 (r=4) for approx and
        // verify the thin==reconstructed equivalence (the key invariant).
        let full_cfg = m.config("tinylm_ds64").unwrap();
        let thin_cfg = m.config("tinylm_ds32").unwrap();
        let full = ParamStore::init(full_cfg, 5);
        let thin = factor_to_thin(&full, full_cfg, thin_cfg).unwrap();
        let recon = low_rank_ablation(&full, full_cfg, thin_cfg.d_qk_head,
                                      AblationMode::KOnly).unwrap();
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&[6, full_cfg.d_model], 0.5, &mut rng);
        for layer in [0usize, 2] {
            for h in [0usize, 7] {
                let s_thin = head_scores(
                    &x,
                    thin.get(&format!("l{layer}.attn.wq")).unwrap(),
                    thin.get(&format!("l{layer}.attn.wk")).unwrap(),
                    h, 8, h, 8);
                let s_recon = head_scores(
                    &x,
                    recon.get(&format!("l{layer}.attn.wq")).unwrap(),
                    recon.get(&format!("l{layer}.attn.wk")).unwrap(),
                    h, 8, h, 8);
                let err = s_thin.max_abs_diff(&s_recon);
                assert!(err < 1e-3,
                        "thin vs reconstructed scores differ: {err}");
            }
        }
    }

    #[test]
    fn surgery_shrinks_only_qk() {
        let Some(m) = manifest() else { return };
        let full_cfg = m.config("tinylm_ds64").unwrap();
        let thin_cfg = m.config("tinylm_ds32").unwrap();
        let full = ParamStore::init(full_cfg, 1);
        let thin = factor_to_thin(&full, full_cfg, thin_cfg).unwrap();
        assert_eq!(thin.get("emb.tok").unwrap(), full.get("emb.tok").unwrap());
        assert_eq!(
            thin.get("l2.attn.wv").unwrap(),
            full.get("l2.attn.wv").unwrap()
        );
        assert_eq!(
            thin.get("l2.mlp.w1").unwrap(),
            full.get("l2.mlp.w1").unwrap()
        );
        assert_eq!(thin.get("l0.attn.wk").unwrap().shape, vec![64, 8 * 4]);
        assert!(thin.n_elements() < full.n_elements());
    }

    #[test]
    fn gqa_absorption_maps_groups_correctly() {
        let Some(m) = manifest() else { return };
        let full_cfg = m.config("tinygqa_ds64").unwrap();
        let thin_cfg = m.config("tinygqa_ds32").unwrap();
        let full = ParamStore::init(full_cfg, 2);
        let thin = factor_to_thin(&full, full_cfg, thin_cfg).unwrap();
        // thin == reconstructed scores for a query head in the SECOND group
        let recon = low_rank_ablation(&full, full_cfg, thin_cfg.d_qk_head,
                                      AblationMode::KOnly).unwrap();
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[5, full_cfg.d_model], 0.5, &mut rng);
        // 8 q heads, 2 kv heads -> group 4; head 6 belongs to kv head 1
        let s_thin = head_scores(
            &x,
            thin.get("l1.attn.wq").unwrap(),
            thin.get("l1.attn.wk").unwrap(),
            6, 8, 1, 2);
        let s_recon = head_scores(
            &x,
            recon.get("l1.attn.wq").unwrap(),
            recon.get("l1.attn.wk").unwrap(),
            6, 8, 1, 2);
        assert!(s_thin.max_abs_diff(&s_recon) < 1e-3);
    }

    #[test]
    fn ablation_modes_touch_expected_tensors() {
        let Some(m) = manifest() else { return };
        let cfg = m.config("tinylm_ds64").unwrap();
        let p = ParamStore::init(cfg, 4);
        let k = low_rank_ablation(&p, cfg, 4, AblationMode::KOnly).unwrap();
        assert_ne!(k.get("l0.attn.wk").unwrap(), p.get("l0.attn.wk").unwrap());
        assert_eq!(k.get("l0.attn.wq").unwrap(), p.get("l0.attn.wq").unwrap());
        let q = low_rank_ablation(&p, cfg, 4, AblationMode::QOnly).unwrap();
        assert_eq!(q.get("l0.attn.wk").unwrap(), p.get("l0.attn.wk").unwrap());
        assert_ne!(q.get("l0.attn.wq").unwrap(), p.get("l0.attn.wq").unwrap());
        let b = low_rank_ablation(&p, cfg, 4, AblationMode::Both).unwrap();
        assert_ne!(b.get("l0.attn.wk").unwrap(), p.get("l0.attn.wk").unwrap());
        assert_ne!(b.get("l0.attn.wq").unwrap(), p.get("l0.attn.wq").unwrap());
    }

    #[test]
    fn full_rank_ablation_is_identity() {
        let Some(m) = manifest() else { return };
        let cfg = m.config("tinylm_ds64").unwrap();
        let p = ParamStore::init(cfg, 6);
        let r = low_rank_ablation(&p, cfg, cfg.d_qk_head, AblationMode::Both)
            .unwrap();
        for (a, b) in p.tensors.iter().zip(&r.tensors) {
            assert!(a.max_abs_diff(b) < 1e-3);
        }
    }

    #[test]
    fn rejects_mla() {
        let Some(m) = manifest() else { return };
        let cfg = m.config("llama_mla56").unwrap();
        let p = ParamStore::init(cfg, 0);
        assert!(low_rank_ablation(&p, cfg, 4, AblationMode::KOnly).is_err());
    }
}
