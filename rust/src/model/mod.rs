//! Model-level operations on parameter stores: factored-key surgery
//! (the paper's §2.3 inference primitive) and low-rank ablation transforms
//! (Table 1's K-only / Q-only / both modes).

pub mod surgery;
