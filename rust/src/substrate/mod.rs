//! Hand-rolled substrates. The vendored crate registry carries only `xla` +
//! `anyhow`, so everything a framework normally pulls in — RNG, tensors,
//! linear algebra (truncated SVD), JSON, CLI parsing, a weights file format,
//! histograms — is implemented here from scratch, each with its own tests.

pub mod rng;
pub mod tensor;
pub mod linalg;
pub mod json;
pub mod args;
pub mod tensorfile;
pub mod histogram;
pub mod mathutil;
