//! Linear algebra substrate: one-sided Jacobi SVD (no external BLAS/LAPACK).
//!
//! This is the engine behind factored keys (paper §2.3): the coordinator
//! factors each pretrained key projection `W_K ≈ U_r Σ_r V_rᵀ` offline and
//! absorbs `V_r` into the query projection. One-sided Jacobi is simple,
//! numerically robust, and exact enough for weight matrices of the sizes we
//! handle (d_model × d_head).

use crate::substrate::tensor::Tensor;

/// Full SVD of a (m×n) matrix with m ≥ n: returns (U: m×n, S: n, V: n×n)
/// such that A = U · diag(S) · Vᵀ, with S sorted descending.
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub v: Tensor, // (n, n); columns are right singular vectors
}

/// One-sided Jacobi SVD. Panics if m < n (callers transpose as needed —
/// `svd_any` handles both orientations).
pub fn svd(a: &Tensor) -> Svd {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape[0], a.shape[1]);
    assert!(m >= n, "svd requires m >= n (got {m}x{n}); use svd_any");

    // Work on columns: u[j] is column j of the evolving A, v accumulates
    // the right rotations starting from identity.
    let mut cols: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| a.data[i * n + j] as f64).collect())
        .collect();
    let mut v: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..n).map(|i| if i == j { 1.0 } else { 0.0 }).collect())
        .collect();

    let eps = 1e-12;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                let (mut aii, mut ajj, mut aij) = (0.0f64, 0.0f64, 0.0f64);
                for t in 0..m {
                    aii += cols[i][t] * cols[i][t];
                    ajj += cols[j][t] * cols[j][t];
                    aij += cols[i][t] * cols[j][t];
                }
                if aij.abs() <= eps * (aii * ajj).sqrt() + 1e-300 {
                    continue;
                }
                off += aij.abs();
                // Jacobi rotation zeroing the (i,j) inner product.
                let tau = (ajj - aii) / (2.0 * aij);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for k in 0..m {
                    let (x, y) = (cols[i][k], cols[j][k]);
                    cols[i][k] = c * x - s * y;
                    cols[j][k] = s * x + c * y;
                }
                for k in 0..n {
                    let (x, y) = (v[i][k], v[j][k]);
                    v[i][k] = c * x - s * y;
                    v[j][k] = s * x + c * y;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }

    // Singular values = column norms; normalize U columns; sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = cols
        .iter()
        .map(|c| c.iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = Tensor::zeros(&[m, n]);
    let mut s_out = vec![0.0f32; n];
    let mut v_out = Tensor::zeros(&[n, n]);
    for (new_j, &old_j) in order.iter().enumerate() {
        let nrm = norms[old_j];
        s_out[new_j] = nrm as f32;
        for i in 0..m {
            let val = if nrm > 1e-30 { cols[old_j][i] / nrm } else { 0.0 };
            u.data[i * n + new_j] = val as f32;
        }
        for i in 0..n {
            v_out.data[i * n + new_j] = v[old_j][i] as f32;
        }
    }
    Svd { u, s: s_out, v: v_out }
}

/// SVD for any orientation; returns (U: m×k, S: k, V: n×k) with
/// k = min(m, n) and A = U diag(S) Vᵀ.
pub fn svd_any(a: &Tensor) -> Svd {
    let (m, n) = (a.shape[0], a.shape[1]);
    if m >= n {
        svd(a)
    } else {
        // A = U S Vᵀ  ⇔  Aᵀ = V S Uᵀ
        let s = svd(&a.t());
        Svd { u: s.v, s: s.s, v: s.u }
    }
}

/// Rank-r truncation: returns (A_thin = U_r·Σ_r : m×r, V_r : n×r).
/// `A ≈ A_thin · V_rᵀ` — the paper's `W_K ≈ A·B` with `B = V_rᵀ`.
pub fn truncated_factor(a: &Tensor, r: usize) -> (Tensor, Tensor) {
    let d = svd_any(a);
    let k = d.s.len();
    assert!(r <= k, "rank {r} > min dim {k}");
    let mut us = d.u.cols(0, r);
    // scale columns by singular values
    let rdim = r;
    for row in 0..us.shape[0] {
        for j in 0..rdim {
            us.data[row * rdim + j] *= d.s[j];
        }
    }
    let vr = d.v.cols(0, r);
    (us, vr)
}

/// Best rank-r approximation (Eckart–Young): U_r Σ_r V_rᵀ, same shape as A.
pub fn low_rank_approx(a: &Tensor, r: usize) -> Tensor {
    let (us, vr) = truncated_factor(a, r);
    us.matmul(&vr.t())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    fn reconstruct(d: &Svd) -> Tensor {
        let k = d.s.len();
        let mut us = d.u.clone();
        for row in 0..us.shape[0] {
            for j in 0..k {
                us.data[row * k + j] *= d.s[j];
            }
        }
        us.matmul(&d.v.t())
    }

    #[test]
    fn svd_reconstructs_random() {
        let mut rng = Rng::new(0);
        for &(m, n) in &[(8usize, 8usize), (16, 4), (64, 16), (5, 9)] {
            let a = Tensor::randn(&[m, n], 1.0, &mut rng);
            let d = svd_any(&a);
            let r = reconstruct(&d);
            let err = a.max_abs_diff(&r);
            assert!(err < 1e-4, "{m}x{n} err {err}");
        }
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[20, 10], 1.0, &mut rng);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(d.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn u_and_v_orthonormal() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[12, 6], 1.0, &mut rng);
        let d = svd(&a);
        let utu = d.u.t().matmul(&d.u);
        let vtv = d.v.t().matmul(&d.v);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(&[i, j]) - want).abs() < 1e-4);
                assert!((vtv.at(&[i, j]) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn diagonal_matrix_svd() {
        let mut a = Tensor::zeros(&[4, 4]);
        for (i, &v) in [3.0f32, 1.0, 4.0, 2.0].iter().enumerate() {
            a.set(&[i, i], v);
        }
        let d = svd(&a);
        assert!((d.s[0] - 4.0).abs() < 1e-5);
        assert!((d.s[1] - 3.0).abs() < 1e-5);
        assert!((d.s[3] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn truncation_error_matches_tail_singular_values() {
        // Eckart–Young: ||A - A_r||_F² = Σ_{i>r} σ_i².
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[32, 8], 1.0, &mut rng);
        let d = svd(&a);
        for r in [2usize, 4, 6, 8] {
            let ar = low_rank_approx(&a, r);
            let mut diff = a.clone();
            for (x, y) in diff.data.iter_mut().zip(&ar.data) {
                *x -= y;
            }
            let err = diff.frobenius();
            let want: f64 = d.s[r..]
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt();
            assert!((err - want).abs() < 1e-3, "r {r}: {err} vs {want}");
        }
    }

    #[test]
    fn truncation_error_monotone_in_rank() {
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&[24, 12], 1.0, &mut rng);
        let mut last = f64::INFINITY;
        for r in [2usize, 4, 8, 12] {
            let ar = low_rank_approx(&a, r);
            let mut diff = a.clone();
            for (x, y) in diff.data.iter_mut().zip(&ar.data) {
                *x -= y;
            }
            let err = diff.frobenius();
            assert!(err <= last + 1e-6, "rank {r}");
            last = err;
        }
        assert!(last < 1e-4); // full rank ⇒ exact
    }

    #[test]
    fn truncated_factor_shapes() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[64, 16], 1.0, &mut rng);
        let (thin, vr) = truncated_factor(&a, 4);
        assert_eq!(thin.shape, vec![64, 4]);
        assert_eq!(vr.shape, vec![16, 4]);
        // A ≈ thin · vrᵀ at the Eckart–Young error
        let approx = thin.matmul(&vr.t());
        let d = svd(&a);
        let mut diff = a.clone();
        for (x, y) in diff.data.iter_mut().zip(&approx.data) {
            *x -= y;
        }
        let want: f64 =
            d.s[4..].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        assert!((diff.frobenius() - want).abs() < 1e-3);
    }

    #[test]
    fn low_rank_matrix_recovered_exactly() {
        // Build an exactly rank-3 matrix; rank-3 truncation must be exact.
        let mut rng = Rng::new(6);
        let b = Tensor::randn(&[20, 3], 1.0, &mut rng);
        let c = Tensor::randn(&[3, 10], 1.0, &mut rng);
        let a = b.matmul(&c);
        let ar = low_rank_approx(&a, 3);
        assert!(a.max_abs_diff(&ar) < 1e-4);
    }
}

#[cfg(test)]
mod extra_tests {
    use super::*;
    use crate::substrate::rng::Rng;

    #[test]
    fn svd_of_zero_matrix() {
        let a = Tensor::zeros(&[6, 3]);
        let d = svd(&a);
        assert!(d.s.iter().all(|&x| x == 0.0));
        let r = low_rank_approx(&a, 2);
        assert!(r.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn svd_of_rank_one() {
        let mut rng = Rng::new(77);
        let u = Tensor::randn(&[10, 1], 1.0, &mut rng);
        let v = Tensor::randn(&[1, 5], 1.0, &mut rng);
        let a = u.matmul(&v);
        let d = svd(&a);
        assert!(d.s[0] > 1e-3);
        for &s in &d.s[1..] {
            assert!(s < 1e-5, "rank-1 matrix has extra singular value {s}");
        }
    }

    #[test]
    fn svd_tall_skinny_and_wide() {
        let mut rng = Rng::new(78);
        for shape in [[40usize, 3], [3, 40]] {
            let a = Tensor::randn(&shape, 1.0, &mut rng);
            let d = svd_any(&a);
            assert_eq!(d.s.len(), 3);
            let (thin, vr) = truncated_factor(&a, 3);
            let approx = thin.matmul(&vr.t());
            assert!(a.max_abs_diff(&approx) < 1e-4);
            let _ = d;
        }
    }

    #[test]
    fn singular_values_match_frobenius() {
        // ||A||_F^2 == sum sigma_i^2
        let mut rng = Rng::new(79);
        let a = Tensor::randn(&[12, 7], 1.0, &mut rng);
        let d = svd(&a);
        let fro2: f64 = a.frobenius().powi(2);
        let s2: f64 = d.s.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!((fro2 - s2).abs() / fro2 < 1e-6);
    }
}
