//! Tiny declarative CLI argument parser (no clap in the vendored registry).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generated `--help` text.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
enum Kind {
    Str(Option<String>),
    Usize(Option<usize>),
    F64(Option<f64>),
    Bool,
}

#[derive(Clone, Debug)]
struct Spec {
    name: String,
    kind: Kind,
    help: String,
}

/// Declarative parser: declare flags, then `parse()`.
pub struct Args {
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(about: &str) -> Self {
        Args {
            about: about.to_string(),
            specs: Vec::new(),
            values: BTreeMap::new(),
            bools: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    pub fn flag_str(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            kind: Kind::Str(default.map(|s| s.to_string())),
            help: help.into(),
        });
        self
    }

    pub fn flag_usize(mut self, name: &str, default: Option<usize>, help: &str) -> Self {
        self.specs.push(Spec { name: name.into(), kind: Kind::Usize(default), help: help.into() });
        self
    }

    pub fn flag_f64(mut self, name: &str, default: Option<f64>, help: &str) -> Self {
        self.specs.push(Spec { name: name.into(), kind: Kind::F64(default), help: help.into() });
        self
    }

    pub fn flag_bool(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec { name: name.into(), kind: Kind::Bool, help: help.into() });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{}\n\nFlags:\n", self.about);
        for s in &self.specs {
            let d = match &s.kind {
                Kind::Str(Some(d)) => format!(" (default: {d})"),
                Kind::Usize(Some(d)) => format!(" (default: {d})"),
                Kind::F64(Some(d)) => format!(" (default: {d})"),
                Kind::Bool => " (boolean)".to_string(),
                _ => String::new(),
            };
            out.push_str(&format!("  --{:<18} {}{}\n", s.name, s.help, d));
        }
        out
    }

    /// Parse a token stream (without argv[0]).
    pub fn parse(mut self, argv: &[String]) -> Result<Parsed> {
        let known: BTreeMap<String, Kind> =
            self.specs.iter().map(|s| (s.name.clone(), s.kind.clone())).collect();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped == "help" {
                    bail!("{}", self.usage());
                }
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let kind = match known.get(&name) {
                    Some(k) => k,
                    None => bail!("unknown flag --{name}\n{}", self.usage()),
                };
                match kind {
                    Kind::Bool => {
                        self.bools.insert(name, true);
                    }
                    _ => {
                        let v = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| {
                                        anyhow::anyhow!("--{name} needs a value")
                                    })?
                            }
                        };
                        self.values.insert(name, v);
                    }
                }
            } else {
                self.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(Parsed { specs: self.specs, values: self.values, bools: self.bools,
                    positional: self.positional })
    }
}

pub struct Parsed {
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Parsed {
    fn spec(&self, name: &str) -> Result<&Spec> {
        self.specs
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow::anyhow!("flag --{name} was never declared"))
    }

    pub fn str(&self, name: &str) -> Result<String> {
        if let Some(v) = self.values.get(name) {
            return Ok(v.clone());
        }
        match &self.spec(name)?.kind {
            Kind::Str(Some(d)) => Ok(d.clone()),
            Kind::Str(None) => bail!("missing required flag --{name}"),
            _ => bail!("--{name} is not a string flag"),
        }
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        if let Some(v) = self.values.get(name) {
            return Ok(v.parse()?);
        }
        match &self.spec(name)?.kind {
            Kind::Usize(Some(d)) => Ok(*d),
            Kind::Usize(None) => bail!("missing required flag --{name}"),
            _ => bail!("--{name} is not a usize flag"),
        }
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        if let Some(v) = self.values.get(name) {
            return Ok(v.parse()?);
        }
        match &self.spec(name)?.kind {
            Kind::F64(Some(d)) => Ok(*d),
            Kind::F64(None) => bail!("missing required flag --{name}"),
            _ => bail!("--{name} is not an f64 flag"),
        }
    }

    pub fn bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn base() -> Args {
        Args::new("test")
            .flag_str("name", Some("deflt"), "a name")
            .flag_usize("steps", Some(100), "steps")
            .flag_f64("lr", None, "learning rate")
            .flag_bool("fast", "go fast")
    }

    #[test]
    fn defaults_and_overrides() {
        let p = base().parse(&argv(&["--steps", "5", "--lr=0.1"])).unwrap();
        assert_eq!(p.str("name").unwrap(), "deflt");
        assert_eq!(p.usize("steps").unwrap(), 5);
        assert_eq!(p.f64("lr").unwrap(), 0.1);
        assert!(!p.bool("fast"));
    }

    #[test]
    fn bools_and_positional() {
        let p = base().parse(&argv(&["exp5", "--fast", "pos2"])).unwrap();
        assert!(p.bool("fast"));
        assert_eq!(p.positional, vec!["exp5", "pos2"]);
    }

    #[test]
    fn missing_required_errors() {
        let p = base().parse(&argv(&[])).unwrap();
        assert!(p.f64("lr").is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(base().parse(&argv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn equals_form() {
        let p = base().parse(&argv(&["--name=abc"])).unwrap();
        assert_eq!(p.str("name").unwrap(), "abc");
    }
}
