//! Numeric helpers used across evaluation and sampling: stable softmax /
//! log-softmax, argmax, perplexity aggregation, simple stats.

/// Stable in-place softmax.
pub fn softmax(xs: &mut [f32]) {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        z += *x;
    }
    for x in xs.iter_mut() {
        *x /= z;
    }
}

/// Stable log-sum-exp.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    m + xs.iter().map(|&x| (x - m).exp()).sum::<f32>().ln()
}

/// Log-probability of a specific class under the logits.
pub fn log_prob(logits: &[f32], class: usize) -> f32 {
    logits[class] - logsumexp(logits)
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Perplexity from accumulated (sum NLL, token count).
pub fn ppl(sum_nll: f64, count: f64) -> f64 {
    if count <= 0.0 {
        return f64::NAN;
    }
    (sum_nll / count).exp()
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Relative change in percent: 100·(new-old)/old.
pub fn rel_pct(old: f64, new: f64) -> f64 {
    100.0 * (new - old) / old
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0, -5.0];
        softmax(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0] && xs[0] > xs[3]);
    }

    #[test]
    fn softmax_stable_for_large_inputs() {
        let mut xs = vec![1000.0, 1001.0];
        softmax(&mut xs);
        assert!(xs.iter().all(|x| x.is_finite()));
        assert!((xs[1] / xs[0] - std::f32::consts::E).abs() < 1e-3);
    }

    #[test]
    fn logsumexp_matches_naive_for_small() {
        let xs = vec![0.1f32, -0.4, 0.7];
        let naive = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((logsumexp(&xs) - naive).abs() < 1e-6);
    }

    #[test]
    fn log_prob_normalizes() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let total: f32 = (0..3).map(|c| log_prob(&logits, c).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.0, 5.0, 2.0]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }

    #[test]
    fn ppl_identity() {
        // uniform over V => ppl == V
        let v = 512.0f64;
        assert!((ppl(v.ln() * 100.0, 100.0) - v).abs() < 1e-6);
    }

    #[test]
    fn stats() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
        assert!((rel_pct(20.0, 21.0) - 5.0).abs() < 1e-12);
    }
}
