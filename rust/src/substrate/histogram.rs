//! Log-bucketed latency histogram + streaming counters for the serving
//! metrics (p50/p90/p99 without storing every sample).

/// Histogram over microsecond latencies with ~4% resolution log buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
}

const BUCKETS: usize = 512;
const GROWTH: f64 = 1.04;
const BASE_US: f64 = 1.0;

fn bucket_of(us: f64) -> usize {
    if us <= BASE_US {
        return 0;
    }
    let b = (us / BASE_US).ln() / GROWTH.ln();
    (b as usize).min(BUCKETS - 1)
}

fn bucket_upper(i: usize) -> f64 {
    BASE_US * GROWTH.powi(i as i32 + 1)
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum_us: 0.0,
            min_us: f64::INFINITY,
            max_us: 0.0,
        }
    }

    pub fn record_us(&mut self, us: f64) {
        self.counts[bucket_of(us)] += 1;
        self.total += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.max_us }
    }

    pub fn min_us(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.min_us }
    }

    /// Quantile in microseconds: upper bound of the containing log
    /// bucket, clamped into `[min_us, max_us]` — a bucket bound can
    /// overshoot the largest recorded sample by up to one bucket width
    /// (~4%), and a sub-`BASE_US` sample's bucket bound undershoots
    /// nothing a real sample ever reached. No reported quantile can lie
    /// outside the observed sample range.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return bucket_upper(i).clamp(self.min_us, self.max_us);
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &Histogram) {
        if other.total == 0 {
            // an empty histogram's min_us sentinel (f64::INFINITY) must
            // never fold into a populated one's stats
            return;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}us p50={:.0}us p90={:.0}us p99={:.0}us max={:.0}us",
            self.total,
            self.mean_us(),
            self.quantile_us(0.50),
            self.quantile_us(0.90),
            self.quantile_us(0.99),
            self.max_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_roughly_correct() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!((p50 - 500.0).abs() < 500.0 * 0.08, "p50 {p50}");
        assert!((p99 - 990.0).abs() < 990.0 * 0.08, "p99 {p99}");
        assert_eq!(h.count(), 1000);
        assert!((h.mean_us() - 500.5).abs() < 1.0);
    }

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.9), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 0..100 {
            a.record_us(10.0 + i as f64);
            b.record_us(1000.0 + i as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!(a.quantile_us(0.25) < 200.0);
        assert!(a.quantile_us(0.75) > 900.0);
    }

    #[test]
    fn single_sample_quantiles_clamped_to_sample() {
        // regression (ISSUE 10): the containing bucket's upper bound
        // overshoots a lone 100us sample by ~4%; every quantile must
        // report exactly the one observed value
        let mut h = Histogram::new();
        h.record_us(100.0);
        for q in [0.01, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(h.quantile_us(q), 100.0, "q={q}");
        }
        assert!(h.quantile_us(0.5) <= h.max_us());
        assert!(h.quantile_us(0.5) >= h.min_us());
    }

    #[test]
    fn sub_base_sample_never_exceeds_max() {
        // a sample below BASE_US lands in bucket 0 (upper bound
        // BASE_US*GROWTH > the sample); the clamp must pull the
        // quantile down to the observed max
        let mut h = Histogram::new();
        h.record_us(0.5);
        assert_eq!(h.quantile_us(0.5), 0.5);
        assert!(h.quantile_us(0.99) <= h.max_us());
    }

    #[test]
    fn two_bucket_quantiles_stay_in_range() {
        let mut h = Histogram::new();
        h.record_us(10.0);
        h.record_us(1000.0);
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        // p50 resolves in the low bucket (within ~4% of 10us), p99 in
        // the high one, and both stay inside [min_us, max_us]
        assert!((9.0..=11.0).contains(&p50), "p50 {p50}");
        assert!(p99 > 900.0, "p99 {p99}");
        for q in [0.01, 0.25, 0.5, 0.75, 0.99, 1.0] {
            let v = h.quantile_us(q);
            assert!(v >= h.min_us() && v <= h.max_us(), "q={q} v={v}");
        }
    }

    #[test]
    fn merge_with_empty_keeps_min() {
        let mut a = Histogram::new();
        a.record_us(50.0);
        a.record_us(70.0);
        a.merge(&Histogram::new());
        assert_eq!(a.count(), 2);
        assert_eq!(a.min_us(), 50.0);
        assert_eq!(a.max_us(), 70.0);
        // and the empty side: merging INTO an empty histogram adopts
        // the populated stats without the INFINITY sentinel leaking
        let mut e = Histogram::new();
        e.merge(&a);
        assert_eq!(e.min_us(), 50.0);
        assert!(e.min_us().is_finite());
        // empty-empty merge stays empty with a 0.0 reported min
        let mut z = Histogram::new();
        z.merge(&Histogram::new());
        assert_eq!(z.min_us(), 0.0);
        assert_eq!(z.count(), 0);
    }

    #[test]
    fn monotone_quantiles() {
        let mut h = Histogram::new();
        let mut x = 1.0;
        for _ in 0..500 {
            h.record_us(x);
            x *= 1.01;
        }
        assert!(h.quantile_us(0.1) <= h.quantile_us(0.5));
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
    }
}
