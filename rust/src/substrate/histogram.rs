//! Log-bucketed latency histogram + streaming counters for the serving
//! metrics (p50/p90/p99 without storing every sample).

/// Histogram over microsecond latencies with ~4% resolution log buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
}

const BUCKETS: usize = 512;
const GROWTH: f64 = 1.04;
const BASE_US: f64 = 1.0;

fn bucket_of(us: f64) -> usize {
    if us <= BASE_US {
        return 0;
    }
    let b = (us / BASE_US).ln() / GROWTH.ln();
    (b as usize).min(BUCKETS - 1)
}

fn bucket_upper(i: usize) -> f64 {
    BASE_US * GROWTH.powi(i as i32 + 1)
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum_us: 0.0,
            min_us: f64::INFINITY,
            max_us: 0.0,
        }
    }

    pub fn record_us(&mut self, us: f64) {
        self.counts[bucket_of(us)] += 1;
        self.total += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.max_us }
    }

    /// Quantile in microseconds (upper bound of the containing bucket).
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return bucket_upper(i).min(self.max_us.max(BASE_US));
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}us p50={:.0}us p90={:.0}us p99={:.0}us max={:.0}us",
            self.total,
            self.mean_us(),
            self.quantile_us(0.50),
            self.quantile_us(0.90),
            self.quantile_us(0.99),
            self.max_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_roughly_correct() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!((p50 - 500.0).abs() < 500.0 * 0.08, "p50 {p50}");
        assert!((p99 - 990.0).abs() < 990.0 * 0.08, "p99 {p99}");
        assert_eq!(h.count(), 1000);
        assert!((h.mean_us() - 500.5).abs() < 1.0);
    }

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.9), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 0..100 {
            a.record_us(10.0 + i as f64);
            b.record_us(1000.0 + i as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!(a.quantile_us(0.25) < 200.0);
        assert!(a.quantile_us(0.75) > 900.0);
    }

    #[test]
    fn monotone_quantiles() {
        let mut h = Histogram::new();
        let mut x = 1.0;
        for _ in 0..500 {
            h.record_us(x);
            x *= 1.01;
        }
        assert!(h.quantile_us(0.1) <= h.quantile_us(0.5));
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
    }
}
