//! Dense row-major f32 tensors — the host-side data structure flowing
//! between the coordinator, the model surgery, and the PJRT runtime.
//!
//! This is deliberately a *small* tensor library: the heavy math runs inside
//! the AOT-compiled XLA executables; rust only needs construction, layout
//! surgery (reshape/slice/concat), matmul for the SVD/absorption path, and
//! conversions to/from `xla::Literal`.

use crate::substrate::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total bytes when stored at the given per-element width (cache
    /// accounting uses this to model bf16/int8/int4 deployments).
    pub fn nbytes(&self, bytes_per_el: f64) -> f64 {
        self.len() as f64 * bytes_per_el
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let st = self.strides();
        let off: usize = idx.iter().zip(&st).map(|(i, s)| i * s).sum();
        self.data[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let st = self.strides();
        let off: usize = idx.iter().zip(&st).map(|(i, s)| i * s).sum();
        self.data[off] = v;
    }

    /// 2-D matmul: (m,k) x (k,n) -> (m,n). Blocked i-k-j loop order (cache
    /// friendly); used only on small matrices (surgery / probes).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(&[n, m], out)
    }

    /// Select columns [lo, hi) of a 2-D tensor.
    pub fn cols(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        assert!(lo <= hi && hi <= n);
        let w = hi - lo;
        let mut out = Vec::with_capacity(m * w);
        for i in 0..m {
            out.extend_from_slice(&self.data[i * n + lo..i * n + hi]);
        }
        Tensor::new(&[m, w], out)
    }

    /// Concatenate 2-D tensors along columns.
    pub fn hcat(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let m = parts[0].shape[0];
        let n: usize = parts.iter().map(|p| p.shape[1]).sum();
        let mut out = Vec::with_capacity(m * n);
        for i in 0..m {
            for p in parts {
                let w = p.shape[1];
                out.extend_from_slice(&p.data[i * w..(i + 1) * w]);
            }
        }
        Tensor::new(&[m, n], out)
    }

    pub fn scale(mut self, c: f32) -> Tensor {
        for v in self.data.iter_mut() {
            *v *= c;
        }
        self
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// An i32 tensor (token ids, positions).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI32 { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        TensorI32 { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    pub fn scalar(v: i32) -> Self {
        TensorI32 { shape: vec![], data: vec![v] }
    }
}

/// An i8 tensor — quantized KV-cache payloads (ISSUE 4).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI8 {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
}

impl TensorI8 {
    pub fn new(shape: &[usize], data: Vec<i8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI8 { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        TensorI8 { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }
}

// ---------------------------------------------------------------------------
// KV-cache quantization (ISSUE 4)
// ---------------------------------------------------------------------------

/// KV-cache element format served by the engine. `Q8` stores arenas as
/// int8 codes with ONE fp32 scale per cache row (the flat KD/VD entry of
/// one layer/lane/position); `Fp32` is the legacy full-precision path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvQuant {
    #[default]
    Fp32,
    Q8,
}

impl KvQuant {
    /// Bytes per arena payload element (the scale planes are accounted
    /// separately — see `coordinator::metrics::ArenaSizing`).
    pub fn elem_bytes(&self) -> usize {
        match self {
            KvQuant::Fp32 => 4,
            KvQuant::Q8 => 1,
        }
    }

    /// fp32 scale bytes per cache row per arena (K or V).
    pub fn scale_bytes_per_row(&self) -> usize {
        match self {
            KvQuant::Fp32 => 0,
            KvQuant::Q8 => 4,
        }
    }

    /// Artifact-name suffix (mirrors aot.py `add()`).
    pub fn suffix(&self) -> &'static str {
        match self {
            KvQuant::Fp32 => "",
            KvQuant::Q8 => "_q8",
        }
    }

    /// Manifest / CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            KvQuant::Fp32 => "fp32",
            KvQuant::Q8 => "q8",
        }
    }

    pub fn parse(s: &str) -> Option<KvQuant> {
        match s {
            "fp32" => Some(KvQuant::Fp32),
            "q8" => Some(KvQuant::Q8),
            _ => None,
        }
    }
}

/// Scale floor for all-zero rows (python twin: `ref.Q8_SCALE_EPS`).
pub const Q8_SCALE_EPS: f32 = 1e-12;

/// Round half to even — the semantics of `jnp.round`, so host-quantized
/// rows (monolithic-prefill park) and device-quantized rows (decode /
/// chunk artifacts) agree bit for bit on ties.
pub fn rint_ties_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (r - x).abs() == 0.5 {
        if (r as i64) % 2 == 0 {
            r
        } else {
            r - x.signum()
        }
    } else {
        r
    }
}

/// Symmetric per-row int8 quantization over `rows = data.len() / d` rows
/// of `d` elements: scale = max|row|/127 (floored at [`Q8_SCALE_EPS`]),
/// codes = clip(rint(x/scale), -127, 127). Worst-case reconstruction
/// error is scale/2 per element (property-tested in tests/properties.rs;
/// python twin: `compile.kernels.ref.quantize_rows`).
pub fn quantize_rows_q8(data: &[f32], d: usize) -> (Vec<i8>, Vec<f32>) {
    assert!(d > 0 && data.len() % d == 0, "{} % {d}", data.len());
    let rows = data.len() / d;
    let mut q = vec![0i8; data.len()];
    let mut scales = vec![0f32; rows];
    for r in 0..rows {
        let row = &data[r * d..(r + 1) * d];
        let amax = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let scale = (amax / 127.0).max(Q8_SCALE_EPS);
        scales[r] = scale;
        for (o, &x) in q[r * d..(r + 1) * d].iter_mut().zip(row) {
            *o = rint_ties_even(x / scale).clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scales)
}

/// Dequantize per-row int8 codes back to fp32.
pub fn dequantize_rows_q8(q: &[i8], scales: &[f32], d: usize) -> Vec<f32> {
    assert_eq!(q.len(), scales.len() * d);
    q.iter()
        .enumerate()
        .map(|(i, &c)| c as f32 * scales[i / d])
        .collect()
}

/// Dtype-aware row storage for cache arenas and parked rows: `rows`
/// entries of `d` elements each, stored fp32 or (int8 codes + one fp32
/// scale per row). All engine cache movement (park/unpark/repack/delta
/// scatter) is row-range copies through this type, so the fp32 and q8
/// paths share the exact same index arithmetic.
#[derive(Clone, Debug, PartialEq)]
pub struct RowArena {
    pub quant: KvQuant,
    pub d: usize,
    pub rows: usize,
    /// fp32 payload (empty in q8 mode).
    pub f: Vec<f32>,
    /// int8 payload (empty in fp32 mode).
    pub q: Vec<i8>,
    /// per-row fp32 scales (empty in fp32 mode).
    pub s: Vec<f32>,
}

impl RowArena {
    pub fn zeros(quant: KvQuant, d: usize, rows: usize) -> RowArena {
        match quant {
            KvQuant::Fp32 => RowArena {
                quant,
                d,
                rows,
                f: vec![0.0; d * rows],
                q: Vec::new(),
                s: Vec::new(),
            },
            KvQuant::Q8 => RowArena {
                quant,
                d,
                rows,
                f: Vec::new(),
                q: vec![0; d * rows],
                s: vec![0.0; rows],
            },
        }
    }

    /// Payload bytes (int8 codes or fp32 values; excludes scales).
    pub fn payload_bytes(&self) -> usize {
        self.d * self.rows * self.quant.elem_bytes()
    }

    /// Scale-plane bytes (0 in fp32 mode).
    pub fn scale_bytes(&self) -> usize {
        self.rows * self.quant.scale_bytes_per_row()
    }

    /// Storage-shape invariant, consumed by the engine auditor: the
    /// populated payload vector matches `rows·d` for the arena's quant
    /// mode, the other payload is empty, and in q8 mode the scale plane
    /// carries exactly one fp32 scale per row.
    pub fn check(&self) -> Result<(), String> {
        let want = self.rows * self.d;
        match self.quant {
            KvQuant::Fp32 => {
                if self.f.len() != want {
                    return Err(format!(
                        "fp32 payload {} != rows*d {want}", self.f.len()));
                }
                if !self.q.is_empty() || !self.s.is_empty() {
                    return Err(format!(
                        "fp32 arena carries q8 storage (q {}, s {})",
                        self.q.len(), self.s.len()));
                }
            }
            KvQuant::Q8 => {
                if self.q.len() != want {
                    return Err(format!(
                        "q8 payload {} != rows*d {want}", self.q.len()));
                }
                if self.s.len() != self.rows {
                    return Err(format!(
                        "q8 scale plane {} != rows {} (one fp32 scale per \
                         row)",
                        self.s.len(), self.rows));
                }
                if !self.f.is_empty() {
                    return Err(format!(
                        "q8 arena carries fp32 storage ({})", self.f.len()));
                }
            }
        }
        Ok(())
    }

    /// Copy `n` rows from `src` starting at `src_row` into `self` at
    /// `dst_row`. Same dtype and row width required.
    pub fn copy_rows(&mut self, dst_row: usize, src: &RowArena,
                     src_row: usize, n: usize) {
        assert_eq!(self.quant, src.quant);
        assert_eq!(self.d, src.d);
        let d = self.d;
        match self.quant {
            KvQuant::Fp32 => {
                self.f[dst_row * d..(dst_row + n) * d]
                    .copy_from_slice(&src.f[src_row * d..(src_row + n) * d]);
            }
            KvQuant::Q8 => {
                self.q[dst_row * d..(dst_row + n) * d]
                    .copy_from_slice(&src.q[src_row * d..(src_row + n) * d]);
                self.s[dst_row..dst_row + n]
                    .copy_from_slice(&src.s[src_row..src_row + n]);
            }
        }
    }

    /// Write `n` rows of fp32 values at `dst_row` — copied in fp32 mode,
    /// quantized on write in q8 mode (THE host-side quantization point:
    /// monolithic prefill parks through here).
    pub fn write_f32_rows(&mut self, dst_row: usize, data: &[f32], n: usize) {
        let d = self.d;
        assert_eq!(data.len(), n * d);
        match self.quant {
            KvQuant::Fp32 => {
                self.f[dst_row * d..(dst_row + n) * d].copy_from_slice(data);
            }
            KvQuant::Q8 => {
                let (q, s) = quantize_rows_q8(data, d);
                self.q[dst_row * d..(dst_row + n) * d].copy_from_slice(&q);
                self.s[dst_row..dst_row + n].copy_from_slice(&s);
            }
        }
    }

    /// Write `n` already-quantized rows (codes + scales) at `dst_row` —
    /// the delta-sync scatter path for q8 artifact outputs.
    pub fn write_q8_rows(&mut self, dst_row: usize, q: &[i8], s: &[f32],
                         n: usize) {
        assert_eq!(self.quant, KvQuant::Q8, "q8 write into fp32 arena");
        let d = self.d;
        assert_eq!(q.len(), n * d);
        assert_eq!(s.len(), n);
        self.q[dst_row * d..(dst_row + n) * d].copy_from_slice(q);
        self.s[dst_row..dst_row + n].copy_from_slice(s);
    }

    /// The arena's values as fp32 (identity in fp32 mode, dequantized in
    /// q8 mode) — the parity-test surface.
    pub fn to_f32(&self) -> Vec<f32> {
        match self.quant {
            KvQuant::Fp32 => self.f.clone(),
            KvQuant::Q8 => dequantize_rows_q8(&self.q, &self.s, self.d),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[5, 5], 1.0, &mut rng);
        let mut eye = Tensor::zeros(&[5, 5]);
        for i in 0..5 {
            eye.set(&[i, i], 1.0);
        }
        let b = a.matmul(&eye);
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[3, 7], 1.0, &mut rng);
        assert_eq!(a, a.t().t());
        assert_eq!(a.at(&[2, 5]), a.t().at(&[5, 2]));
    }

    #[test]
    fn cols_and_hcat_roundtrip() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let l = a.cols(0, 2);
        let r = a.cols(2, 6);
        assert_eq!(Tensor::hcat(&[&l, &r]), a);
    }

    #[test]
    fn strides_and_at() {
        let t = Tensor::new(&[2, 3, 4], (0..24).map(|x| x as f32).collect());
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.at(&[1, 2, 3]), 23.0);
        assert_eq!(t.at(&[0, 1, 0]), 4.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn nbytes_models_dtypes() {
        let t = Tensor::zeros(&[10, 10]);
        assert_eq!(t.nbytes(4.0), 400.0); // f32
        assert_eq!(t.nbytes(2.0), 200.0); // bf16
        assert_eq!(t.nbytes(0.5), 50.0); // int4
    }

    #[test]
    fn rint_ties_even_matches_numpy_round() {
        assert_eq!(rint_ties_even(2.5), 2.0);
        assert_eq!(rint_ties_even(3.5), 4.0);
        assert_eq!(rint_ties_even(-2.5), -2.0);
        assert_eq!(rint_ties_even(-1.5), -2.0);
        assert_eq!(rint_ties_even(0.5), 0.0);
        assert_eq!(rint_ties_even(-0.5), 0.0);
        assert_eq!(rint_ties_even(2.49), 2.0);
        assert_eq!(rint_ties_even(-2.51), -3.0);
        assert_eq!(rint_ties_even(126.6), 127.0);
    }

    #[test]
    fn quantize_rows_scale_and_error_bound() {
        let mut rng = Rng::new(7);
        let t = Tensor::randn(&[6, 16], 1.0, &mut rng);
        let (q, s) = quantize_rows_q8(&t.data, 16);
        for r in 0..6 {
            let row = &t.data[r * 16..(r + 1) * 16];
            let amax = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
            assert!((s[r] - amax / 127.0).abs() <= f32::EPSILON * amax);
        }
        let back = dequantize_rows_q8(&q, &s, 16);
        for (i, (&x, &y)) in t.data.iter().zip(&back).enumerate() {
            assert!((x - y).abs() <= s[i / 16] * 0.5 + 1e-7,
                    "row {} err {}", i / 16, (x - y).abs());
        }
    }

    #[test]
    fn quantize_zero_row_is_exact_zero() {
        let (q, s) = quantize_rows_q8(&[0.0; 8], 8);
        assert!(q.iter().all(|&c| c == 0));
        assert_eq!(s, vec![Q8_SCALE_EPS]);
        assert!(dequantize_rows_q8(&q, &s, 8).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn quantize_outlier_row_bounded() {
        let mut row = vec![0.01f32; 8];
        row[3] = 1e4;
        let (q, s) = quantize_rows_q8(&row, 8);
        assert_eq!(q[3], 127);
        assert!(q[0].abs() <= 1);
        let back = dequantize_rows_q8(&q, &s, 8);
        for (x, y) in row.iter().zip(&back) {
            assert!((x - y).abs() <= s[0] * 0.5 + 1e-6);
        }
    }

    #[test]
    fn row_arena_copy_and_write_roundtrip() {
        for quant in [KvQuant::Fp32, KvQuant::Q8] {
            let mut a = RowArena::zeros(quant, 4, 6);
            let vals: Vec<f32> = (0..8).map(|i| i as f32 * 0.25 - 1.0).collect();
            a.write_f32_rows(2, &vals, 2);
            let mut b = RowArena::zeros(quant, 4, 3);
            b.copy_rows(0, &a, 2, 2);
            let fa = a.to_f32();
            let fb = b.to_f32();
            assert_eq!(&fa[8..16], &fb[0..8], "{quant:?}");
            // untouched rows stay exactly zero
            assert!(fa[..8].iter().all(|&x| x == 0.0));
            assert!(fa[16..].iter().all(|&x| x == 0.0));
            // fp32 mode is lossless; q8 is within scale/2
            if quant == KvQuant::Fp32 {
                assert_eq!(&fa[8..16], &vals[..]);
            } else {
                for (r, chunk) in vals.chunks(4).enumerate() {
                    for (x, y) in chunk.iter().zip(&fa[(2 + r) * 4..]) {
                        assert!((x - y).abs() <= a.s[2 + r] * 0.5 + 1e-7);
                    }
                }
            }
        }
    }

    #[test]
    fn row_arena_byte_accounting() {
        let f = RowArena::zeros(KvQuant::Fp32, 10, 8);
        assert_eq!(f.payload_bytes(), 10 * 8 * 4);
        assert_eq!(f.scale_bytes(), 0);
        let q = RowArena::zeros(KvQuant::Q8, 10, 8);
        assert_eq!(q.payload_bytes(), 10 * 8);
        assert_eq!(q.scale_bytes(), 8 * 4);
    }

    #[test]
    fn kv_quant_parse_and_names() {
        assert_eq!(KvQuant::parse("fp32"), Some(KvQuant::Fp32));
        assert_eq!(KvQuant::parse("q8"), Some(KvQuant::Q8));
        assert_eq!(KvQuant::parse("int4"), None);
        assert_eq!(KvQuant::Q8.suffix(), "_q8");
        assert_eq!(KvQuant::Fp32.suffix(), "");
        assert_eq!(KvQuant::Q8.name(), "q8");
        assert_eq!(KvQuant::Q8.elem_bytes(), 1);
        assert_eq!(KvQuant::Fp32.elem_bytes(), 4);
    }
}
