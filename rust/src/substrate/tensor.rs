//! Dense row-major f32 tensors — the host-side data structure flowing
//! between the coordinator, the model surgery, and the PJRT runtime.
//!
//! This is deliberately a *small* tensor library: the heavy math runs inside
//! the AOT-compiled XLA executables; rust only needs construction, layout
//! surgery (reshape/slice/concat), matmul for the SVD/absorption path, and
//! conversions to/from `xla::Literal`.

use crate::substrate::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total bytes when stored at the given per-element width (cache
    /// accounting uses this to model bf16/int8/int4 deployments).
    pub fn nbytes(&self, bytes_per_el: f64) -> f64 {
        self.len() as f64 * bytes_per_el
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let st = self.strides();
        let off: usize = idx.iter().zip(&st).map(|(i, s)| i * s).sum();
        self.data[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let st = self.strides();
        let off: usize = idx.iter().zip(&st).map(|(i, s)| i * s).sum();
        self.data[off] = v;
    }

    /// 2-D matmul: (m,k) x (k,n) -> (m,n). Blocked i-k-j loop order (cache
    /// friendly); used only on small matrices (surgery / probes).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(&[n, m], out)
    }

    /// Select columns [lo, hi) of a 2-D tensor.
    pub fn cols(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        assert!(lo <= hi && hi <= n);
        let w = hi - lo;
        let mut out = Vec::with_capacity(m * w);
        for i in 0..m {
            out.extend_from_slice(&self.data[i * n + lo..i * n + hi]);
        }
        Tensor::new(&[m, w], out)
    }

    /// Concatenate 2-D tensors along columns.
    pub fn hcat(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let m = parts[0].shape[0];
        let n: usize = parts.iter().map(|p| p.shape[1]).sum();
        let mut out = Vec::with_capacity(m * n);
        for i in 0..m {
            for p in parts {
                let w = p.shape[1];
                out.extend_from_slice(&p.data[i * w..(i + 1) * w]);
            }
        }
        Tensor::new(&[m, n], out)
    }

    pub fn scale(mut self, c: f32) -> Tensor {
        for v in self.data.iter_mut() {
            *v *= c;
        }
        self
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// An i32 tensor (token ids, positions).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI32 { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        TensorI32 { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    pub fn scalar(v: i32) -> Self {
        TensorI32 { shape: vec![], data: vec![v] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[5, 5], 1.0, &mut rng);
        let mut eye = Tensor::zeros(&[5, 5]);
        for i in 0..5 {
            eye.set(&[i, i], 1.0);
        }
        let b = a.matmul(&eye);
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[3, 7], 1.0, &mut rng);
        assert_eq!(a, a.t().t());
        assert_eq!(a.at(&[2, 5]), a.t().at(&[5, 2]));
    }

    #[test]
    fn cols_and_hcat_roundtrip() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let l = a.cols(0, 2);
        let r = a.cols(2, 6);
        assert_eq!(Tensor::hcat(&[&l, &r]), a);
    }

    #[test]
    fn strides_and_at() {
        let t = Tensor::new(&[2, 3, 4], (0..24).map(|x| x as f32).collect());
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.at(&[1, 2, 3]), 23.0);
        assert_eq!(t.at(&[0, 1, 0]), 4.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn nbytes_models_dtypes() {
        let t = Tensor::zeros(&[10, 10]);
        assert_eq!(t.nbytes(4.0), 400.0); // f32
        assert_eq!(t.nbytes(2.0), 200.0); // bf16
        assert_eq!(t.nbytes(0.5), 50.0); // int4
    }
}
