//! `.tkw` — the checkpoint file format (no npz/safetensors available).
//!
//! Layout: `b"TKW1"` magic, u32 LE header length, JSON header
//! `{"tensors": [{"name", "shape", "offset", "len"}...]}`, then raw f32 LE
//! data. Offsets are element offsets into the data section.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::substrate::json::{self, Value};
use crate::substrate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"TKW1";

/// Save named tensors (order preserved in the header).
pub fn save(path: &Path, tensors: &[(String, &Tensor)]) -> Result<()> {
    let mut entries = Vec::new();
    let mut offset = 0usize;
    for (name, t) in tensors {
        entries.push(json::obj(vec![
            ("name", json::s(name)),
            ("shape", json::arr(
                t.shape.iter().map(|&d| json::num(d as f64)).collect())),
            ("offset", json::num(offset as f64)),
            ("len", json::num(t.len() as f64)),
        ]));
        offset += t.len();
    }
    let header = json::obj(vec![("tensors", json::arr(entries))]).to_string();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for (_, t) in tensors {
        for &v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    f.flush()?;
    Ok(())
}

/// Load all tensors; returns (ordered names, name → tensor).
pub fn load(path: &Path) -> Result<(Vec<String>, BTreeMap<String, Tensor>)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a TKW1 file");
    }
    let mut lenb = [0u8; 4];
    f.read_exact(&mut lenb)?;
    let hlen = u32::from_le_bytes(lenb) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Value::parse(std::str::from_utf8(&hbuf)?)?;
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    if raw.len() % 4 != 0 {
        bail!("{path:?}: data section not f32-aligned");
    }
    let data: Vec<f32> = raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let mut names = Vec::new();
    let mut out = BTreeMap::new();
    for e in header.get("tensors")?.as_arr()? {
        let name = e.get("name")?.as_str()?.to_string();
        let shape = e.get("shape")?.shape_vec()?;
        let off = e.get("offset")?.as_usize()?;
        let len = e.get("len")?.as_usize()?;
        if off + len > data.len() {
            bail!("{path:?}: tensor {name} out of bounds");
        }
        let t = Tensor::new(&shape, data[off..off + len].to_vec());
        names.push(name.clone());
        out.insert(name, t);
    }
    Ok((names, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[4, 8], 1.0, &mut rng);
        let b = Tensor::randn(&[3], 0.5, &mut rng);
        let c = Tensor::scalar(7.0);
        let dir = std::env::temp_dir().join("tkw_test");
        let path = dir.join("x.tkw");
        save(&path, &[("w.a".into(), &a), ("w.b".into(), &b), ("s".into(), &c)])
            .unwrap();
        let (names, m) = load(&path).unwrap();
        assert_eq!(names, vec!["w.a", "w.b", "s"]);
        assert_eq!(m["w.a"], a);
        assert_eq!(m["w.b"], b);
        assert_eq!(m["s"], c);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("tkw_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tkw");
        std::fs::write(&path, b"NOPE1234").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn empty_tensor_list() {
        let path = std::env::temp_dir().join("tkw_test_empty.tkw");
        save(&path, &[]).unwrap();
        let (names, m) = load(&path).unwrap();
        assert!(names.is_empty() && m.is_empty());
        std::fs::remove_file(path).unwrap();
    }
}
