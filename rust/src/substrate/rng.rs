//! Deterministic pseudo-random generation: SplitMix64 seeding +
//! xoshiro256++ core, with the samplers the workload generators need
//! (uniform, normal, Poisson, Zipf, categorical, shuffle).
//!
//! Determinism is a hard requirement: every experiment is reproducible from
//! a seed recorded in EXPERIMENTS.md.

/// xoshiro256++ PRNG seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for parallel/substream use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free for our purposes (n << 2^64).
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Fill a slice with N(0, std²) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(std);
        }
    }

    /// Poisson(lambda) via Knuth (lambda expected small) or normal approx.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda > 64.0 {
            let x = lambda + lambda.sqrt() * self.normal();
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Exponential inter-arrival time with the given rate (events/sec).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Precomputed Zipf(s) sampler over [0, n) — the unigram model behind the
/// synthetic corpus (DESIGN.md: Zipf–Markov generator).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&x).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(11);
        for &lam in &[0.5, 4.0, 100.0] {
            let n = 20_000;
            let m: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((m - lam).abs() < lam.max(1.0) * 0.05, "lam {lam} m {m}");
        }
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let mut r = Rng::new(13);
        let z = Zipf::new(100, 1.1);
        let mut counts = vec![0usize; 100];
        for _ in 0..200_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(23);
        let n = 50_000;
        let m: f64 =
            (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((m - 0.25).abs() < 0.01, "m {m}");
    }
}
