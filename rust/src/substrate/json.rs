//! Minimal JSON parser/serializer (the vendored registry has no serde).
//! Parses `artifacts/manifest.json` and `kernel_report.json`; serializes
//! metrics reports. Supports the full JSON grammar incl. escapes and
//! `\uXXXX` (BMP) sequences.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // --- typed accessors ---
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a usize: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn shape_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // --- serialization ---
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(x) => {
                // JSON has no inf/NaN literal — a non-finite ratio (e.g.
                // an all-saved copyback or an empty-trace rate) must
                // degrade to null, not corrupt the whole document
                if !x.is_finite() {
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-sync for multi-byte UTF-8.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let chunk =
                            std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

/// Convenience constructors for building reports.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Value {
    Value::Num(x)
}

pub fn s(x: &str) -> Value {
    Value::Str(x.to_string())
}

pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(
            r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}, "f": null}"#,
        )
        .unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b").unwrap().as_str().unwrap(),
            "c"
        );
        assert!(!v.get("d").unwrap().get("e").unwrap().as_bool().unwrap());
    }

    #[test]
    fn escapes_roundtrip() {
        let orig = Value::Str("line\n\"quote\"\tµ→".into());
        let text = orig.to_string();
        assert_eq!(Value::parse(&text).unwrap(), orig);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Value::parse(r#""µx""#).unwrap(),
            Value::Str("µx".into())
        );
    }

    #[test]
    fn serialize_roundtrip() {
        let v = obj(vec![
            ("name", s("decode_b8")),
            ("shape", arr(vec![num(4.0), num(8.0)])),
            ("ok", Value::Bool(true)),
        ]);
        assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn errors_on_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    /// Satellite-3 regression: pre-fix, `write!` printed `inf`/`NaN`
    /// verbatim — the appended BENCH_serving.json then failed to parse
    /// and the whole perf-trajectory series was silently restarted.
    #[test]
    fn non_finite_numbers_serialize_as_null() {
        let v = obj(vec![
            ("ok", num(1.5)),
            ("ratio", num(f64::INFINITY)),
            ("neg", num(f64::NEG_INFINITY)),
            ("nan", num(f64::NAN)),
        ]);
        let text = v.to_string();
        assert!(!text.contains("inf") && !text.contains("NaN"), "{text}");
        let back = Value::parse(&text).expect("must stay valid JSON");
        assert_eq!(back.get("ratio").unwrap(), &Value::Null);
        assert_eq!(back.get("nan").unwrap(), &Value::Null);
        assert_eq!(back.get("ok").unwrap(), &Value::Num(1.5));
    }

    #[test]
    fn shape_vec_helper() {
        let v = Value::parse("[4, 8, 256, 32]").unwrap();
        assert_eq!(v.shape_vec().unwrap(), vec![4, 8, 256, 32]);
        assert!(Value::parse("[1.5]").unwrap().shape_vec().is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = crate::artifacts_dir().join("manifest.json");
        if let Ok(text) = std::fs::read_to_string(&p) {
            let v = Value::parse(&text).unwrap();
            assert!(v.get("configs").unwrap().as_obj().unwrap().len() > 5);
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() > 50);
        }
    }
}
