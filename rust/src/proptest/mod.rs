//! Mini property-testing framework (the registry has no proptest crate).
//!
//! A property is a closure over a seeded [`Rng`]; the runner executes it for
//! N seeds and reports the first failing seed so failures reproduce exactly:
//!
//! ```
//! use thinkeys::proptest::property;
//! property("sort is idempotent", 100, |rng| {
//!     let mut v: Vec<u64> = (0..rng.below(50)).map(|_| rng.next_u64()).collect();
//!     v.sort(); let w = { let mut w = v.clone(); w.sort(); w };
//!     if v == w { Ok(()) } else { Err("not idempotent".into()) }
//! });
//! ```
//!
//! No shrinking: cases are generated from a seed, so a failing case is
//! already minimal to *reproduce* (rerun that seed); generators below are
//! kept small-biased instead.

use crate::substrate::rng::Rng;

/// Run `cases` instances of the property; panics with the failing seed.
pub fn property<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Rng::new(0xBEEF_0000 ^ seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property {name:?} failed at seed {seed}: {msg}");
        }
    }
}

/// Small-biased size: ~half the time < 8, otherwise up to `max`.
pub fn small_size(rng: &mut Rng, max: usize) -> usize {
    if rng.below(2) == 0 {
        1 + rng.below(8.min(max))
    } else {
        1 + rng.below(max)
    }
}

/// Check two f32 slices elementwise within atol+rtol; returns Err with the
/// worst offender formatted.
pub fn check_close(a: &[f32], b: &[f32], rtol: f32, atol: f32)
    -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("len {} vs {}", a.len(), b.len()));
    }
    let mut worst = (0usize, 0.0f32);
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let err = (x - y).abs();
        let tol = atol + rtol * y.abs().max(x.abs());
        if err > tol && err > worst.1 {
            worst = (i, err);
        }
    }
    if worst.1 > 0.0 {
        return Err(format!(
            "mismatch at [{}]: {} vs {} (err {})",
            worst.0, a[worst.0], b[worst.0], worst.1
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        property("trivial", 25, |_rng| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn failing_property_reports_seed() {
        property("always-fails", 3, |_rng| Err("boom".into()));
    }

    #[test]
    fn check_close_catches_mismatch() {
        assert!(check_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6).is_ok());
        assert!(check_close(&[1.0, 2.0], &[1.0, 2.1], 1e-6, 1e-6).is_err());
        assert!(check_close(&[1.0], &[1.0, 2.0], 0.1, 0.1).is_err());
    }

    #[test]
    fn properties_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        property("record", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        property("record", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
