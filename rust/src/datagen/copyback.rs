//! Experiment 1 — the copy-back task (paper §8.1): `y_t = x_{t-K}`.
//! Purely positional selection; the source offset is fixed regardless of
//! content. Loss/accuracy are masked to positions t >= K.

use crate::datagen::Batch;
use crate::substrate::rng::Rng;

pub const OFFSET_K: usize = 8;

/// Vocabulary: ids 0..16 (matches the `copyback_*` configs' vocab of 32
/// with headroom; the paper uses 16 random tokens).
pub const TOKENS: i32 = 16;

pub fn batch(b: usize, s: usize, rng: &mut Rng) -> Batch {
    let mut out = Batch::zeros(b, s);
    for i in 0..b {
        for t in 0..s {
            out.tokens[i * s + t] = rng.below(TOKENS as usize) as i32;
        }
        for t in 0..s {
            if t >= OFFSET_K {
                out.targets[i * s + t] = out.tokens[i * s + t - OFFSET_K];
                out.mask[i * s + t] = 1.0;
            }
        }
    }
    out
}

/// Accuracy of predictions (B,S,V logits flattened) under the task mask.
pub fn accuracy(logits: &[f32], vocab: usize, batch: &Batch) -> f64 {
    let (b, s) = (batch.batch, batch.seq);
    assert_eq!(logits.len(), b * s * vocab);
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..b {
        for t in 0..s {
            if batch.mask[i * s + t] == 0.0 {
                continue;
            }
            let row = &logits[(i * s + t) * vocab..(i * s + t + 1) * vocab];
            if crate::substrate::mathutil::argmax(row) as i32
                == batch.targets[i * s + t]
            {
                correct += 1;
            }
            total += 1;
        }
    }
    correct as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn targets_are_shifted_inputs() {
        let mut rng = Rng::new(0);
        let b = batch(4, 32, &mut rng);
        for i in 0..4 {
            for t in OFFSET_K..32 {
                assert_eq!(b.targets[i * 32 + t], b.tokens[i * 32 + t - OFFSET_K]);
                assert_eq!(b.mask[i * 32 + t], 1.0);
            }
            for t in 0..OFFSET_K {
                assert_eq!(b.mask[i * 32 + t], 0.0);
            }
        }
    }

    #[test]
    fn tokens_in_vocab() {
        let mut rng = Rng::new(1);
        let b = batch(2, 64, &mut rng);
        assert!(b.tokens.iter().all(|&t| (0..TOKENS).contains(&t)));
    }

    #[test]
    fn oracle_accuracy_is_one() {
        // Construct logits that put all mass on the true target.
        let mut rng = Rng::new(2);
        let b = batch(2, 16, &mut rng);
        let v = 32usize;
        let mut logits = vec![0.0f32; 2 * 16 * v];
        for i in 0..2 {
            for t in 0..16 {
                logits[(i * 16 + t) * v + b.targets[i * 16 + t] as usize] = 9.0;
            }
        }
        assert_eq!(accuracy(&logits, v, &b), 1.0);
    }

    #[test]
    fn chance_accuracy_is_low() {
        let mut rng = Rng::new(3);
        let b = batch(8, 64, &mut rng);
        let v = 32usize;
        let logits = vec![0.0f32; 8 * 64 * v]; // argmax -> 0 everywhere
        assert!(accuracy(&logits, v, &b) < 0.2);
    }
}
