//! Downstream probe suite — synthetic stand-ins for the paper's five
//! benchmarks (Tables 5/8). Each probe is a multiple-choice item scored by
//! length-normalized sequence log-probability (the `acc_norm` protocol).
//!
//! | paper task   | probe here                                            |
//! |--------------|-------------------------------------------------------|
//! | Hellaswag    | `cloze`: true 8-token continuation vs 3 random spans  |
//! | ARC          | `bigram`: most plausible next window by local syntax  |
//! | WinoGrande   | `induction`: resolve `a→b` binding seen earlier       |
//! | MMLU         | `topic`: pick the token cluster matching the context  |
//! | GSM8K        | handled separately by generation (datagen::gsm_mini)  |

use crate::datagen::corpus::CorpusModel;
use crate::substrate::rng::Rng;

#[derive(Clone, Debug)]
pub struct ProbeItem {
    pub context: Vec<i32>,
    pub options: Vec<Vec<i32>>,
    pub answer: usize,
}

/// Hellaswag-mini: context from the corpus stream; the true continuation vs
/// 3 spans sampled from elsewhere in the stream.
pub fn cloze(model: &CorpusModel, n_items: usize, ctx: usize, cont: usize,
             seed: u64) -> Vec<ProbeItem> {
    let mut rng = Rng::new(seed);
    let stream = model.generate(n_items * (ctx + cont) * 4 + 4096, &mut rng);
    let mut items = Vec::with_capacity(n_items);
    for i in 0..n_items {
        let base = i * (ctx + cont) * 2;
        let context = stream[base..base + ctx].to_vec();
        let truth = stream[base + ctx..base + ctx + cont].to_vec();
        let mut options = vec![truth];
        for _ in 0..3 {
            let off = rng.below(stream.len() - cont);
            options.push(stream[off..off + cont].to_vec());
        }
        let answer = rng.below(4);
        options.swap(0, answer);
        items.push(ProbeItem { context, options, answer });
    }
    items
}

/// ARC-mini: the true continuation is the *immediate* next window (locally
/// coherent); distractors are reversed/shuffled copies of it (locally
/// incoherent) — tests sensitivity to local syntax.
pub fn bigram(model: &CorpusModel, n_items: usize, ctx: usize, seed: u64)
    -> Vec<ProbeItem> {
    let cont = 6;
    let mut rng = Rng::new(seed);
    let stream = model.generate(n_items * (ctx + cont) * 2 + 4096, &mut rng);
    let mut items = Vec::with_capacity(n_items);
    for i in 0..n_items {
        let base = i * (ctx + cont);
        let context = stream[base..base + ctx].to_vec();
        let truth = stream[base + ctx..base + ctx + cont].to_vec();
        let mut rev = truth.clone();
        rev.reverse();
        let mut shuf = truth.clone();
        rng.shuffle(&mut shuf);
        let mut shuf2 = truth.clone();
        shuf2.swap(0, cont - 1);
        shuf2.swap(1, cont - 2);
        let mut options = vec![truth, rev, shuf, shuf2];
        let answer = rng.below(4);
        options.swap(0, answer);
        items.push(ProbeItem { context, options, answer });
    }
    items
}

/// WinoGrande-mini: a binding `x y` appears in context; later `x` recurs
/// and the correct option continues with `y` (induction/coreference).
pub fn induction(model: &CorpusModel, n_items: usize, ctx: usize, seed: u64)
    -> Vec<ProbeItem> {
    let mut rng = Rng::new(seed);
    let stream = model.generate(n_items * ctx * 2 + 4096, &mut rng);
    let mut items = Vec::with_capacity(n_items);
    for i in 0..n_items {
        let base = i * ctx;
        let mut context = stream[base..base + ctx].to_vec();
        // plant the binding twice: [.. x y .. x y .. x] -> ? y
        let x = context[2];
        let mut y = context[3];
        if y == x {
            // guarantee a non-degenerate binding
            y = *stream[base + ctx..].iter().find(|&&t| t != x).unwrap();
        }
        let mid = ctx / 2;
        context[3] = y;
        context[mid] = x;
        context[mid + 1] = y;
        *context.last_mut().unwrap() = x;
        // every occurrence of x inside the context must be followed by y
        // (or be the trailing query) so the binding is unambiguous
        for i in 0..ctx - 1 {
            if context[i] == x {
                context[i + 1] = y;
            }
        }
        let mut options: Vec<Vec<i32>> = vec![vec![y]];
        let mut used = vec![y];
        while options.len() < 4 {
            let d = stream[rng.below(stream.len())];
            if !used.contains(&d) {
                used.push(d);
                options.push(vec![d]);
            }
        }
        let answer = rng.below(4);
        options.swap(0, answer);
        items.push(ProbeItem { context, options, answer });
    }
    items
}

/// MMLU-mini: context drawn from one topic cluster; options are
/// characteristic tokens of 4 different topics — pick the matching one.
pub fn topic(model: &CorpusModel, n_items: usize, ctx: usize, seed: u64)
    -> Vec<ProbeItem> {
    let mut rng = Rng::new(seed);
    let mut items = Vec::with_capacity(n_items);
    for _ in 0..n_items {
        let t_true = rng.below(model.n_topics());
        // context saturated with the true topic's cluster tokens
        let context: Vec<i32> =
            (0..ctx).map(|j| model.topic_token(t_true, rng.below(24) + j)).collect();
        let mut topics = vec![t_true];
        while topics.len() < 4 {
            let t = rng.below(model.n_topics());
            if !topics.contains(&t) {
                topics.push(t);
            }
        }
        let mut options: Vec<Vec<i32>> = topics
            .iter()
            .map(|&t| (0..4).map(|j| model.topic_token(t, j)).collect())
            .collect();
        let answer = rng.below(4);
        options.swap(0, answer);
        items.push(ProbeItem { context, options, answer });
    }
    items
}

/// All four ranking probes, keyed by the paper task they stand in for.
pub fn standard_suite(model: &CorpusModel, n_items: usize, seed: u64)
    -> Vec<(&'static str, Vec<ProbeItem>)> {
    vec![
        ("hellaswag_mini", cloze(model, n_items, 24, 8, seed ^ 0x01)),
        ("arc_mini", bigram(model, n_items, 24, seed ^ 0x02)),
        ("winogrande_mini", induction(model, n_items, 24, seed ^ 0x03)),
        ("mmlu_mini", topic(model, n_items, 24, seed ^ 0x04)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CorpusModel {
        CorpusModel::new(42, 512)
    }

    #[test]
    fn items_have_four_options_and_valid_answer() {
        let m = model();
        for (name, items) in standard_suite(&m, 10, 0) {
            assert_eq!(items.len(), 10, "{name}");
            for it in &items {
                assert_eq!(it.options.len(), 4);
                assert!(it.answer < 4);
                assert!(!it.context.is_empty());
                assert!(it.options.iter().all(|o| !o.is_empty()));
            }
        }
    }

    #[test]
    fn answers_are_uniformly_placed() {
        let m = model();
        let items = cloze(&m, 200, 16, 8, 1);
        let mut counts = [0usize; 4];
        for it in &items {
            counts[it.answer] += 1;
        }
        assert!(counts.iter().all(|&c| c > 20), "{counts:?}");
    }

    #[test]
    fn induction_truth_is_bound_token() {
        let m = model();
        for it in induction(&m, 20, 24, 3) {
            let x = *it.context.last().unwrap();
            // find the binding in context
            let mut want = None;
            for w in it.context.windows(2) {
                if w[0] == x {
                    want = Some(w[1]);
                    break;
                }
            }
            assert_eq!(it.options[it.answer][0], want.unwrap());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let m = model();
        let a = cloze(&m, 5, 8, 4, 9);
        let b = cloze(&m, 5, 8, 4, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.answer, y.answer);
        }
    }
}
