//! Poisson request-arrival traces for the serving benchmarks (Table 11 and
//! the capacity experiment): arrival times with exponential gaps, prompt
//! and generation lengths from bounded log-normal-ish distributions, and
//! the mixed chat+doc trace exercising the chunked-prefill scheduler.

use crate::coordinator::sequence::Priority;
use crate::substrate::rng::Rng;

#[derive(Clone, Debug)]
pub struct RequestSpec {
    pub arrive_s: f64,
    pub prompt_len: usize,
    pub gen_len: usize,
    /// Scheduling class the router submits this request under
    /// (Interactive by default; Batch marks document-ingestion traffic).
    pub priority: Priority,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub rate_per_s: f64,
    pub n_requests: usize,
    pub prompt_mean: usize,
    pub prompt_max: usize,
    pub gen_mean: usize,
    pub gen_max: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate_per_s: 4.0,
            n_requests: 64,
            prompt_mean: 48,
            prompt_max: 120,
            gen_mean: 24,
            gen_max: 64,
        }
    }
}

fn bounded_len(rng: &mut Rng, mean: usize, max: usize) -> usize {
    // log-normal-ish: exp of a scaled normal, clamped to [1, max]
    let x = (mean as f64) * (0.5 * rng.normal()).exp();
    (x.round() as usize).clamp(1, max)
}

pub fn poisson_trace(cfg: &TraceConfig, seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for _ in 0..cfg.n_requests {
        t += rng.exponential(cfg.rate_per_s);
        out.push(RequestSpec {
            arrive_s: t,
            prompt_len: bounded_len(&mut rng, cfg.prompt_mean, cfg.prompt_max),
            gen_len: bounded_len(&mut rng, cfg.gen_mean, cfg.gen_max),
            priority: Priority::Interactive,
        });
    }
    out
}

/// A closed-loop trace: all requests available at t=0 (for steady-state
/// throughput measurement at a fixed batch size).
pub fn closed_loop(n: usize, prompt_len: usize, gen_len: usize)
    -> Vec<RequestSpec> {
    (0..n)
        .map(|_| RequestSpec {
            arrive_s: 0.0,
            prompt_len,
            gen_len,
            priority: Priority::Interactive,
        })
        .collect()
}

/// The mixed chat+doc trace (ISSUE 3): `n_docs` Batch-class document
/// ingestions (long prompt, short generation) arriving first, with
/// `n_chats` Interactive chats (short prompt) arriving `chat_gap_s` apart
/// starting at `chat_start_s` — i.e. WHILE the documents are being
/// prefilled. This is the workload where chunked prefill bounds
/// interactive TTFT: monolithically, every chat arriving mid-document
/// waits out the whole document prompt; chunked, it waits at most one
/// chunk boundary.
pub fn mixed_chat_doc_trace(n_chats: usize, n_docs: usize,
                            chat_start_s: f64, chat_gap_s: f64)
    -> Vec<RequestSpec> {
    let mut out = Vec::with_capacity(n_chats + n_docs);
    for _ in 0..n_docs {
        out.push(RequestSpec {
            arrive_s: 0.0,
            prompt_len: 120,
            gen_len: 8,
            priority: Priority::Batch,
        });
    }
    for i in 0..n_chats {
        out.push(RequestSpec {
            arrive_s: chat_start_s + i as f64 * chat_gap_s,
            prompt_len: 8,
            gen_len: 8,
            priority: Priority::Interactive,
        });
    }
    out
}

/// The infinite-chat / log-summarization trace (ISSUE 10): `n_streams`
/// Interactive chats with tiny prompts and generations long enough that
/// each stream's FULL reservation (`prompt + gen` tokens) would exceed a
/// bounded block pool on its own. Without eviction the admission gate
/// rejects these outright (`CacheOverflow`); with `--eviction` active the
/// capped reservation admits them and each stream self-funds its growth
/// by evicting its own middle. Streams arrive `gap_s` apart so admission
/// pressure ramps rather than spikes.
pub fn infinite_chat_trace(n_streams: usize, gen_len: usize, gap_s: f64)
    -> Vec<RequestSpec> {
    (0..n_streams)
        .map(|i| RequestSpec {
            arrive_s: i as f64 * gap_s,
            prompt_len: 8,
            gen_len,
            priority: Priority::Interactive,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_rate_reasonable() {
        let cfg = TraceConfig { n_requests: 2000, rate_per_s: 10.0,
                                ..Default::default() };
        let tr = poisson_trace(&cfg, 0);
        for w in tr.windows(2) {
            assert!(w[1].arrive_s >= w[0].arrive_s);
        }
        let span = tr.last().unwrap().arrive_s;
        let rate = tr.len() as f64 / span;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn lengths_bounded() {
        let cfg = TraceConfig::default();
        for r in poisson_trace(&cfg, 1) {
            assert!(r.prompt_len >= 1 && r.prompt_len <= cfg.prompt_max);
            assert!(r.gen_len >= 1 && r.gen_len <= cfg.gen_max);
        }
    }

    #[test]
    fn closed_loop_uniform() {
        let tr = closed_loop(8, 32, 16);
        assert_eq!(tr.len(), 8);
        assert!(tr.iter().all(|r| r.arrive_s == 0.0 && r.prompt_len == 32
                              && r.gen_len == 16));
    }

    #[test]
    fn mixed_trace_classes_and_ordering() {
        let tr = mixed_chat_doc_trace(6, 2, 0.001, 0.0005);
        assert_eq!(tr.len(), 8);
        assert!(tr[..2].iter().all(|r| r.priority == Priority::Batch
                                   && r.arrive_s == 0.0
                                   && r.prompt_len > 64));
        assert!(tr[2..].iter().all(|r| r.priority == Priority::Interactive
                                   && r.arrive_s > 0.0
                                   && r.prompt_len <= 16));
        // chats arrive strictly after the docs, spaced apart
        assert!(tr[2..].windows(2).all(|w| w[1].arrive_s > w[0].arrive_s));
    }

    #[test]
    fn infinite_chat_streams_outgrow_small_pools() {
        let tr = infinite_chat_trace(4, 192, 0.001);
        assert_eq!(tr.len(), 4);
        for (i, r) in tr.iter().enumerate() {
            assert_eq!(r.priority, Priority::Interactive);
            assert!(r.prompt_len <= 16, "prompt fits one block");
            // full reservation exceeds a 8-block (128-token) pool
            assert!(r.prompt_len + r.gen_len > 8 * 16);
            assert!((r.arrive_s - i as f64 * 0.001).abs() < 1e-12);
        }
    }

    #[test]
    fn deterministic() {
        let cfg = TraceConfig::default();
        let a = poisson_trace(&cfg, 7);
        let b = poisson_trace(&cfg, 7);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrive_s == y.arrive_s));
    }
}
