//! Poisson request-arrival traces for the serving benchmarks (Table 11 and
//! the capacity experiment): arrival times with exponential gaps, prompt
//! and generation lengths from bounded log-normal-ish distributions.

use crate::substrate::rng::Rng;

#[derive(Clone, Debug)]
pub struct RequestSpec {
    pub arrive_s: f64,
    pub prompt_len: usize,
    pub gen_len: usize,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub rate_per_s: f64,
    pub n_requests: usize,
    pub prompt_mean: usize,
    pub prompt_max: usize,
    pub gen_mean: usize,
    pub gen_max: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate_per_s: 4.0,
            n_requests: 64,
            prompt_mean: 48,
            prompt_max: 120,
            gen_mean: 24,
            gen_max: 64,
        }
    }
}

fn bounded_len(rng: &mut Rng, mean: usize, max: usize) -> usize {
    // log-normal-ish: exp of a scaled normal, clamped to [1, max]
    let x = (mean as f64) * (0.5 * rng.normal()).exp();
    (x.round() as usize).clamp(1, max)
}

pub fn poisson_trace(cfg: &TraceConfig, seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for _ in 0..cfg.n_requests {
        t += rng.exponential(cfg.rate_per_s);
        out.push(RequestSpec {
            arrive_s: t,
            prompt_len: bounded_len(&mut rng, cfg.prompt_mean, cfg.prompt_max),
            gen_len: bounded_len(&mut rng, cfg.gen_mean, cfg.gen_max),
        });
    }
    out
}

/// A closed-loop trace: all requests available at t=0 (for steady-state
/// throughput measurement at a fixed batch size).
pub fn closed_loop(n: usize, prompt_len: usize, gen_len: usize)
    -> Vec<RequestSpec> {
    (0..n)
        .map(|_| RequestSpec { arrive_s: 0.0, prompt_len, gen_len })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_rate_reasonable() {
        let cfg = TraceConfig { n_requests: 2000, rate_per_s: 10.0,
                                ..Default::default() };
        let tr = poisson_trace(&cfg, 0);
        for w in tr.windows(2) {
            assert!(w[1].arrive_s >= w[0].arrive_s);
        }
        let span = tr.last().unwrap().arrive_s;
        let rate = tr.len() as f64 / span;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn lengths_bounded() {
        let cfg = TraceConfig::default();
        for r in poisson_trace(&cfg, 1) {
            assert!(r.prompt_len >= 1 && r.prompt_len <= cfg.prompt_max);
            assert!(r.gen_len >= 1 && r.gen_len <= cfg.gen_max);
        }
    }

    #[test]
    fn closed_loop_uniform() {
        let tr = closed_loop(8, 32, 16);
        assert_eq!(tr.len(), 8);
        assert!(tr.iter().all(|r| r.arrive_s == 0.0 && r.prompt_len == 32
                              && r.gen_len == 16));
    }

    #[test]
    fn deterministic() {
        let cfg = TraceConfig::default();
        let a = poisson_trace(&cfg, 7);
        let b = poisson_trace(&cfg, 7);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrive_s == y.arrive_s));
    }
}
