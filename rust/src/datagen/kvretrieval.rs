//! Experiment 2 — content-based key-value retrieval (paper §8.2).
//!
//! A sequence of `N_PAIRS` random (key, value) pairs followed by a query
//! key; the model must emit the value bound to that key. Pair positions are
//! shuffled every sample so positional shortcuts are useless — selection
//! must match *content*. Loss/accuracy masked to the answer position only.
//!
//! Token layout per sequence (length = 2*N_PAIRS + 2 = 18, padded to the
//! artifact seq of 24):  k1 v1 k2 v2 ... k8 v8 <query-key> <answer-slot>

use crate::datagen::Batch;
use crate::substrate::rng::Rng;

pub const N_PAIRS: usize = 8;
/// Key tokens use ids [0, 16); value tokens use ids [16, 32).
pub const N_KEYS: i32 = 16;
pub const VALUE_BASE: i32 = 16;

pub fn seq_len() -> usize {
    2 * N_PAIRS + 2
}

pub fn batch(b: usize, s: usize, rng: &mut Rng) -> Batch {
    assert!(s >= seq_len(), "artifact seq {s} < task seq {}", seq_len());
    let mut out = Batch::zeros(b, s);
    for i in 0..b {
        // distinct keys, random values
        let mut keys: Vec<i32> = (0..N_KEYS).collect();
        rng.shuffle(&mut keys);
        let keys = &keys[..N_PAIRS];
        let values: Vec<i32> =
            (0..N_PAIRS).map(|_| VALUE_BASE + rng.below(16) as i32).collect();
        let mut order: Vec<usize> = (0..N_PAIRS).collect();
        rng.shuffle(&mut order);
        for (slot, &pi) in order.iter().enumerate() {
            out.tokens[i * s + 2 * slot] = keys[pi];
            out.tokens[i * s + 2 * slot + 1] = values[pi];
        }
        let qi = rng.below(N_PAIRS);
        let qpos = 2 * N_PAIRS;
        out.tokens[i * s + qpos] = keys[qi];
        // The model predicts the value at the query position (next-token).
        out.targets[i * s + qpos] = values[qi];
        out.mask[i * s + qpos] = 1.0;
    }
    out
}

/// Accuracy at the answer position.
pub fn accuracy(logits: &[f32], vocab: usize, batch: &Batch) -> f64 {
    crate::datagen::copyback::accuracy(logits, vocab, batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_one_masked_position_per_row() {
        let mut rng = Rng::new(0);
        let b = batch(16, 24, &mut rng);
        for i in 0..16 {
            let m: f32 = b.mask[i * 24..(i + 1) * 24].iter().sum();
            assert_eq!(m, 1.0);
        }
    }

    #[test]
    fn query_key_appears_among_pairs_and_target_is_its_value() {
        let mut rng = Rng::new(1);
        let b = batch(8, 24, &mut rng);
        let s = 24;
        for i in 0..8 {
            let qpos = 2 * N_PAIRS;
            let qk = b.tokens[i * s + qpos];
            let want = b.targets[i * s + qpos];
            let mut found = false;
            for p in 0..N_PAIRS {
                if b.tokens[i * s + 2 * p] == qk {
                    assert_eq!(b.tokens[i * s + 2 * p + 1], want);
                    found = true;
                }
            }
            assert!(found, "query key not among pairs");
        }
    }

    #[test]
    fn keys_and_values_in_disjoint_ranges() {
        let mut rng = Rng::new(2);
        let b = batch(8, 24, &mut rng);
        for i in 0..8 {
            for p in 0..N_PAIRS {
                assert!(b.tokens[i * 24 + 2 * p] < N_KEYS);
                assert!(b.tokens[i * 24 + 2 * p + 1] >= VALUE_BASE);
            }
        }
    }

    #[test]
    fn positions_shuffle_across_samples() {
        // The same key should not always land at slot 0.
        let mut rng = Rng::new(3);
        let mut first_tokens = std::collections::HashSet::new();
        for _ in 0..32 {
            let b = batch(1, 24, &mut rng);
            first_tokens.insert(b.tokens[0]);
        }
        assert!(first_tokens.len() > 4);
    }
}
