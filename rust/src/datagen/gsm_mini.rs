//! gsm-mini — multi-step arithmetic with chain-of-thought, the GSM8K
//! stand-in for Table 19 (domain-matched fine-tuning recovery).
//!
//! A problem is `a OP1 b OP2 c` over small integers; the CoT trace shows
//! the intermediate result before the final answer:
//!
//! ```text
//! <Q> a OP1 b OP2 c <A> (a OP1 b) <STEP> answer <EOS>
//! ```
//!
//! Numbers are emitted as digit tokens (base 10, most significant first,
//! `-` sign token for negatives). Exact-match evaluation decodes greedily
//! after `<STEP>` and compares the digit string.

use crate::datagen::Batch;
use crate::substrate::rng::Rng;

// Token ids live in the 300.. range of the shared vocab (512).
pub const DIGIT_BASE: i32 = 300; // 300..310 = digits 0..9
pub const T_PLUS: i32 = 310;
pub const T_MINUS: i32 = 311;
pub const T_MUL: i32 = 312;
pub const T_Q: i32 = 313;
pub const T_A: i32 = 314;
pub const T_STEP: i32 = 315;
pub const T_END: i32 = 316;
pub const T_NEG: i32 = 317;

#[derive(Clone, Debug, PartialEq)]
pub struct Problem {
    pub a: i64,
    pub b: i64,
    pub c: i64,
    pub op1: char,
    pub op2: char,
}

impl Problem {
    pub fn sample(rng: &mut Rng) -> Problem {
        let ops = ['+', '-', '*'];
        Problem {
            a: rng.range(1, 50) as i64,
            b: rng.range(1, 50) as i64,
            c: rng.range(1, 20) as i64,
            op1: ops[rng.below(3)],
            op2: ops[rng.below(3)],
        }
    }

    fn apply(op: char, x: i64, y: i64) -> i64 {
        match op {
            '+' => x + y,
            '-' => x - y,
            '*' => x * y,
            _ => unreachable!(),
        }
    }

    /// Left-to-right evaluation (the CoT convention here, kept simple).
    pub fn intermediate(&self) -> i64 {
        Self::apply(self.op1, self.a, self.b)
    }

    pub fn answer(&self) -> i64 {
        Self::apply(self.op2, self.intermediate(), self.c)
    }
}

fn op_token(op: char) -> i32 {
    match op {
        '+' => T_PLUS,
        '-' => T_MINUS,
        '*' => T_MUL,
        _ => unreachable!(),
    }
}

/// Digit-token encoding of an integer.
pub fn encode_number(x: i64) -> Vec<i32> {
    let mut out = Vec::new();
    if x < 0 {
        out.push(T_NEG);
    }
    for ch in x.abs().to_string().bytes() {
        out.push(DIGIT_BASE + (ch - b'0') as i32);
    }
    out
}

pub fn decode_number(toks: &[i32]) -> Option<i64> {
    let mut s = String::new();
    for &t in toks {
        if t == T_NEG {
            s.push('-');
        } else if (DIGIT_BASE..DIGIT_BASE + 10).contains(&t) {
            s.push((b'0' + (t - DIGIT_BASE) as u8) as char);
        } else {
            break;
        }
    }
    s.parse().ok()
}

/// Full CoT sequence for a problem.
pub fn encode_sequence(p: &Problem) -> Vec<i32> {
    let mut seq = vec![T_Q];
    seq.extend(encode_number(p.a));
    seq.push(op_token(p.op1));
    seq.extend(encode_number(p.b));
    seq.push(op_token(p.op2));
    seq.extend(encode_number(p.c));
    seq.push(T_A);
    seq.extend(encode_number(p.intermediate()));
    seq.push(T_STEP);
    seq.extend(encode_number(p.answer()));
    seq.push(T_END);
    seq
}

/// The prompt prefix (everything through `<A>`), for generation-based eval.
pub fn encode_prompt(p: &Problem) -> Vec<i32> {
    let mut seq = vec![T_Q];
    seq.extend(encode_number(p.a));
    seq.push(op_token(p.op1));
    seq.extend(encode_number(p.b));
    seq.push(op_token(p.op2));
    seq.extend(encode_number(p.c));
    seq.push(T_A);
    seq
}

/// Fine-tuning batch: CoT sequences packed left-aligned; loss masked to the
/// CoT+answer region (after `<A>`), mirroring instruction-tuning practice.
pub fn batch(b: usize, s: usize, rng: &mut Rng) -> Batch {
    let mut out = Batch::zeros(b, s);
    for i in 0..b {
        let p = Problem::sample(rng);
        let seq = encode_sequence(&p);
        let n = seq.len().min(s);
        let a_pos = seq.iter().position(|&t| t == T_A).unwrap();
        for t in 0..n {
            out.tokens[i * s + t] = seq[t];
        }
        for t in 0..n.saturating_sub(1) {
            out.targets[i * s + t] = seq[t + 1];
            // train on predictions from <A> onward
            if t >= a_pos {
                out.mask[i * s + t] = 1.0;
            }
        }
    }
    out
}

/// Extract the predicted answer from a greedy-decoded continuation: tokens
/// after the first `<STEP>`.
pub fn parse_answer(generated: &[i32]) -> Option<i64> {
    let pos = generated.iter().position(|&t| t == T_STEP)?;
    decode_number(&generated[pos + 1..])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_is_left_to_right() {
        let p = Problem { a: 2, b: 3, c: 4, op1: '+', op2: '*' };
        assert_eq!(p.intermediate(), 5);
        assert_eq!(p.answer(), 20);
    }

    #[test]
    fn number_roundtrip() {
        for x in [-120i64, -1, 0, 7, 42, 2401] {
            assert_eq!(decode_number(&encode_number(x)), Some(x));
        }
    }

    #[test]
    fn sequence_contains_cot_then_answer() {
        let p = Problem { a: 10, b: 4, c: 3, op1: '-', op2: '*' };
        let seq = encode_sequence(&p);
        assert_eq!(seq[0], T_Q);
        let a_pos = seq.iter().position(|&t| t == T_A).unwrap();
        let step_pos = seq.iter().position(|&t| t == T_STEP).unwrap();
        assert!(a_pos < step_pos);
        assert_eq!(decode_number(&seq[a_pos + 1..step_pos]), Some(6));
        assert_eq!(parse_answer(&seq[a_pos..]), Some(18));
        assert_eq!(*seq.last().unwrap(), T_END);
    }

    #[test]
    fn prompt_is_prefix_of_sequence() {
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let p = Problem::sample(&mut rng);
            let full = encode_sequence(&p);
            let prompt = encode_prompt(&p);
            assert_eq!(&full[..prompt.len()], &prompt[..]);
        }
    }

    #[test]
    fn batch_masks_only_after_answer_marker() {
        let mut rng = Rng::new(1);
        let b = batch(8, 32, &mut rng);
        for i in 0..8 {
            let row_tokens = &b.tokens[i * 32..(i + 1) * 32];
            let a_pos = row_tokens.iter().position(|&t| t == T_A).unwrap();
            for t in 0..a_pos {
                assert_eq!(b.mask[i * 32 + t], 0.0);
            }
            assert!(b.mask[i * 32 + a_pos] == 1.0);
        }
    }

    #[test]
    fn tokens_fit_shared_vocab() {
        let mut rng = Rng::new(2);
        let b = batch(4, 32, &mut rng);
        assert!(b.tokens.iter().all(|&t| t < 512));
    }
}
