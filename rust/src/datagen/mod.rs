//! Workload generators — every dataset/task the paper evaluates on, rebuilt
//! as deterministic synthetic equivalents (DESIGN.md §2 substitution table):
//!
//! - [`copyback`] — Experiment 1 positional-selection task (y_t = x_{t-K}).
//! - [`kvretrieval`] — Experiment 2 content-based key-value retrieval.
//! - [`corpus`] — Zipf–Markov synthetic language (WikiText/OpenWebText
//!   stand-in, with a size knob that switches overfit/underfit regimes).
//! - [`gsm_mini`] — multi-step arithmetic with chain-of-thought traces
//!   (GSM8K stand-in for Table 19 domain-matched fine-tuning).
//! - [`probes`] — multiple-choice downstream probes (Tables 5/8 stand-ins).
//! - [`arrival`] — Poisson request traces for the serving benches.

pub mod copyback;
pub mod kvretrieval;
pub mod corpus;
pub mod gsm_mini;
pub mod probes;
pub mod arrival;

/// One training/eval batch in the exact layout the AOT artifacts expect:
/// `tokens`/`targets` are (B, S) i32 row-major, `mask` is (B, S) f32.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
}

impl Batch {
    pub fn zeros(batch: usize, seq: usize) -> Self {
        Batch {
            batch,
            seq,
            tokens: vec![0; batch * seq],
            targets: vec![0; batch * seq],
            mask: vec![0.0; batch * seq],
        }
    }

    pub fn masked_tokens(&self) -> f64 {
        self.mask.iter().map(|&x| x as f64).sum()
    }
}
