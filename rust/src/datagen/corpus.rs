//! Zipf–Markov synthetic language — the WikiText/OpenWebText stand-in.
//!
//! A deterministic generative "language" with learnable structure at
//! several orders, so thin-key sweeps produce the paper-shaped PPL curves:
//!
//! - **unigram**: Zipfian token frequencies (like natural text);
//! - **bigram**: each token has a sparse successor table (syntax analog);
//! - **topics**: a slow hidden topic state biases emission toward a topic
//!   cluster (long-range semantic analog) — topic switches are rare;
//! - **noise**: a uniform floor so the entropy is bounded away from zero.
//!
//! Corpus *size* is the regime knob: a small corpus with a big model
//! overfits (WikiText-2-like, Exp 3); a large one underfits (WT-103-like,
//! Exp 4). Token ids start at `tokenizer::N_SPECIALS`.

use crate::datagen::Batch;
use crate::substrate::rng::{Rng, Zipf};
use crate::tokenizer::N_SPECIALS;

pub struct CorpusModel {
    vocab: usize,
    usable: usize,
    n_topics: usize,
    succ: Vec<Vec<(i32, f64)>>,    // per-token successor table
    topic_tokens: Vec<Vec<i32>>,   // per-topic characteristic cluster
    zipf: Zipf,
    topic_stay: f64,
}

impl CorpusModel {
    /// `seed` determines the whole language; `vocab` must match the model
    /// config's vocab (e.g. 512).
    pub fn new(seed: u64, vocab: usize) -> Self {
        let mut rng = Rng::new(seed);
        let usable = vocab - N_SPECIALS;
        let n_topics = 8;
        let zipf = Zipf::new(usable, 1.05);
        // sparse successor tables: 12 preferred successors per token
        let mut succ = Vec::with_capacity(usable);
        for _ in 0..usable {
            let mut table = Vec::with_capacity(12);
            for _ in 0..12 {
                let t = zipf.sample(&mut rng) as i32;
                let w = 0.2 + rng.f64();
                table.push((t + N_SPECIALS as i32, w));
            }
            succ.push(table);
        }
        // topic clusters: 24 characteristic tokens each
        let mut topic_tokens = Vec::with_capacity(n_topics);
        for _ in 0..n_topics {
            let toks: Vec<i32> = (0..24)
                .map(|_| (rng.below(usable) + N_SPECIALS) as i32)
                .collect();
            topic_tokens.push(toks);
        }
        CorpusModel { vocab, usable, n_topics, succ, topic_tokens, zipf,
                      topic_stay: 0.98 }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Generate a token stream of length `n` (deterministic given `rng`).
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        let mut topic = rng.below(self.n_topics);
        let mut prev: i32 = (self.zipf.sample(rng) + N_SPECIALS) as i32;
        out.push(prev);
        while out.len() < n {
            if rng.f64() > self.topic_stay {
                topic = rng.below(self.n_topics);
            }
            let r = rng.f64();
            let tok = if r < 0.55 {
                // bigram: weighted successor of prev
                let table = &self.succ[(prev as usize) - N_SPECIALS];
                let weights: Vec<f64> = table.iter().map(|&(_, w)| w).collect();
                table[rng.categorical(&weights)].0
            } else if r < 0.75 {
                // topic cluster token
                let cluster = &self.topic_tokens[topic];
                cluster[rng.below(cluster.len())]
            } else if r < 0.97 {
                // Zipf unigram
                (self.zipf.sample(rng) + N_SPECIALS) as i32
            } else {
                // uniform noise floor
                (rng.below(self.usable) + N_SPECIALS) as i32
            };
            out.push(tok);
            prev = tok;
        }
        out
    }

    /// Characteristic token of a topic (for the topic probe).
    pub fn topic_token(&self, topic: usize, i: usize) -> i32 {
        self.topic_tokens[topic][i % self.topic_tokens[topic].len()]
    }

    pub fn n_topics(&self) -> usize {
        self.n_topics
    }
}

/// A tokenized corpus with train/val/test splits and batch iteration.
pub struct Corpus {
    pub train: Vec<i32>,
    pub val: Vec<i32>,
    pub test: Vec<i32>,
}

impl Corpus {
    /// `n_train` tokens of train data; val/test are 10% each (min 4k).
    pub fn generate(model: &CorpusModel, n_train: usize, seed: u64) -> Self {
        let n_eval = (n_train / 10).max(4096);
        let mut rng = Rng::new(seed);
        Corpus {
            train: model.generate(n_train, &mut rng),
            val: model.generate(n_eval, &mut rng),
            test: model.generate(n_eval, &mut rng),
        }
    }

    /// Deterministic epoch iterator: contiguous (seq+1)-token windows,
    /// shuffled, packed into batches (next-token targets, full mask).
    pub fn batches(&self, split: &[i32], b: usize, s: usize, seed: u64)
        -> Vec<Batch> {
        let window = s + 1;
        let n_windows = split.len() / window;
        let mut order: Vec<usize> = (0..n_windows).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut order);
        let mut out = Vec::new();
        for chunk in order.chunks(b) {
            if chunk.len() < b {
                break;
            }
            let mut batch = Batch::zeros(b, s);
            for (row, &w) in chunk.iter().enumerate() {
                let base = w * window;
                for t in 0..s {
                    batch.tokens[row * s + t] = split[base + t];
                    batch.targets[row * s + t] = split[base + t + 1];
                    batch.mask[row * s + t] = 1.0;
                }
            }
            out.push(batch);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let m = CorpusModel::new(7, 512);
        let a = m.generate(1000, &mut Rng::new(1));
        let b = m.generate(1000, &mut Rng::new(1));
        assert_eq!(a, b);
        let c = m.generate(1000, &mut Rng::new(2));
        assert_ne!(a, c);
    }

    #[test]
    fn tokens_in_range() {
        let m = CorpusModel::new(3, 512);
        let xs = m.generate(5000, &mut Rng::new(0));
        assert!(xs.iter().all(|&t| (N_SPECIALS as i32..512).contains(&t)));
    }

    #[test]
    fn zipfian_head_dominates() {
        let m = CorpusModel::new(5, 512);
        let xs = m.generate(50_000, &mut Rng::new(0));
        let mut counts = vec![0usize; 512];
        for &t in &xs {
            counts[t as usize] += 1;
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top50: usize = sorted[..50].iter().sum();
        assert!(top50 as f64 > 0.35 * xs.len() as f64, "head {top50}");
    }

    #[test]
    fn bigram_structure_exists() {
        // The most common successor of a frequent token should beat chance
        // by a wide margin — that's what the LM learns.
        let m = CorpusModel::new(9, 512);
        let xs = m.generate(100_000, &mut Rng::new(0));
        let mut counts = vec![0usize; 512];
        for &t in &xs {
            counts[t as usize] += 1;
        }
        let top = (0..512).max_by_key(|&i| counts[i]).unwrap() as i32;
        let mut succ = vec![0usize; 512];
        let mut total = 0usize;
        for w in xs.windows(2) {
            if w[0] == top {
                succ[w[1] as usize] += 1;
                total += 1;
            }
        }
        let best = succ.iter().max().unwrap();
        assert!(*best as f64 > 0.05 * total as f64,
                "best successor {best}/{total}");
    }

    #[test]
    fn batches_are_next_token_aligned() {
        let m = CorpusModel::new(11, 512);
        let c = Corpus::generate(&m, 20_000, 1);
        let bs = c.batches(&c.train, 4, 32, 0);
        assert!(!bs.is_empty());
        for b in &bs {
            for row in 0..4 {
                for t in 0..31 {
                    assert_eq!(b.targets[row * 32 + t], b.tokens[row * 32 + t + 1]);
                }
            }
            assert!(b.mask.iter().all(|&x| x == 1.0));
        }
    }

    #[test]
    fn splits_disjoint_and_sized() {
        let m = CorpusModel::new(13, 512);
        let c = Corpus::generate(&m, 50_000, 1);
        assert_eq!(c.train.len(), 50_000);
        assert!(c.val.len() >= 4096 && c.test.len() >= 4096);
        assert_ne!(c.train[..100], c.val[..100]);
    }
}
