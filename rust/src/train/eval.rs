//! Evaluation: perplexity (via `evalloss` artifacts), task accuracy and
//! multiple-choice probe scoring (via `logits` artifacts), and batched
//! greedy generation for exact-match tasks (gsm-mini).

use anyhow::Result;

use crate::datagen::probes::ProbeItem;
use crate::datagen::Batch;
use crate::runtime::client::{literal_to_f32, literal_to_tensor, Arg, Runtime};
use crate::runtime::manifest::ConfigEntry;
use crate::runtime::params::ParamStore;
use crate::substrate::mathutil::{argmax, log_prob, ppl};
use crate::substrate::tensor::{Tensor, TensorI32};

fn param_args<'a>(params: &'a ParamStore) -> Vec<Arg<'a>> {
    params.tensors.iter().map(Arg::F).collect()
}

/// Mean perplexity over batches (exact masked-token aggregation).
pub fn eval_ppl(rt: &Runtime, cfg: &ConfigEntry, params: &ParamStore,
                batches: &[Batch]) -> Result<f64> {
    let artifact = rt.manifest().evalloss_name(&cfg.name);
    let (b, s) = (cfg.train_batch, cfg.train_seq);
    let mut sum_nll = 0.0f64;
    let mut count = 0.0f64;
    for batch in batches {
        let tokens = TensorI32::new(&[b, s], batch.tokens.clone());
        let targets = TensorI32::new(&[b, s], batch.targets.clone());
        let mask = Tensor::new(&[b, s], batch.mask.clone());
        let mut args = param_args(params);
        args.push(Arg::I(&tokens));
        args.push(Arg::I(&targets));
        args.push(Arg::F(&mask));
        let outs = rt.execute(&artifact, &args)?;
        sum_nll += literal_to_f32(&outs[0])? as f64;
        count += literal_to_f32(&outs[1])? as f64;
    }
    Ok(ppl(sum_nll, count))
}

/// Full logits (B,S,V) for a batch.
pub fn logits_for(rt: &Runtime, cfg: &ConfigEntry, params: &ParamStore,
                  batch: &Batch) -> Result<Tensor> {
    let artifact = rt.manifest().logits_name(&cfg.name);
    let (b, s) = (cfg.train_batch, cfg.train_seq);
    let tokens = TensorI32::new(&[b, s], batch.tokens.clone());
    let mut args = param_args(params);
    args.push(Arg::I(&tokens));
    let outs = rt.execute(&artifact, &args)?;
    literal_to_tensor(&outs[0])
}

/// Accuracy under a task mask (argmax == target at masked positions),
/// averaged over the provided batches.
pub fn eval_accuracy(rt: &Runtime, cfg: &ConfigEntry, params: &ParamStore,
                     batches: &[Batch]) -> Result<f64> {
    let mut correct = 0usize;
    let mut total = 0usize;
    for batch in batches {
        let logits = logits_for(rt, cfg, params, batch)?;
        let v = cfg.vocab;
        let s = cfg.train_seq;
        for i in 0..batch.batch {
            for t in 0..s {
                if batch.mask[i * s + t] == 0.0 {
                    continue;
                }
                let row = &logits.data[(i * s + t) * v..(i * s + t + 1) * v];
                if argmax(row) as i32 == batch.targets[i * s + t] {
                    correct += 1;
                }
                total += 1;
            }
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Length-normalized option log-probability (the `acc_norm` protocol).
/// Each (context, option) pair occupies one row of a logits batch.
pub fn probe_accuracy(rt: &Runtime, cfg: &ConfigEntry, params: &ParamStore,
                      items: &[ProbeItem]) -> Result<f64> {
    let (b, s) = (cfg.train_batch, cfg.train_seq);
    let v = cfg.vocab;
    // flatten items x options into rows
    struct Row {
        item: usize,
        option: usize,
        ctx_len: usize,
        opt_len: usize,
        tokens: Vec<i32>,
    }
    let mut rows = Vec::new();
    for (ii, it) in items.iter().enumerate() {
        for (oi, opt) in it.options.iter().enumerate() {
            let mut toks = it.context.clone();
            toks.extend_from_slice(opt);
            assert!(toks.len() <= s, "probe row {} > seq {s}", toks.len());
            rows.push(Row {
                item: ii,
                option: oi,
                ctx_len: it.context.len(),
                opt_len: opt.len(),
                tokens: toks,
            });
        }
    }
    let mut scores = vec![vec![f64::NEG_INFINITY; 4]; items.len()];
    for chunk in rows.chunks(b) {
        let mut batch = Batch::zeros(b, s);
        for (r, row) in chunk.iter().enumerate() {
            for (t, &tok) in row.tokens.iter().enumerate() {
                batch.tokens[r * s + t] = tok;
            }
        }
        let logits = logits_for(rt, cfg, params, &batch)?;
        for (r, row) in chunk.iter().enumerate() {
            let mut lp = 0.0f64;
            for j in 0..row.opt_len {
                // token at position ctx_len+j is predicted at ctx_len+j-1
                let pos = row.ctx_len + j - 1;
                let lrow = &logits.data[(r * s + pos) * v..(r * s + pos + 1) * v];
                lp += log_prob(lrow, row.tokens[row.ctx_len + j] as usize) as f64;
            }
            scores[row.item][row.option] = lp / row.opt_len as f64;
        }
    }
    let mut correct = 0usize;
    for (it, sc) in items.iter().zip(&scores) {
        let best = sc
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if best == it.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len().max(1) as f64)
}

/// Batched greedy generation via the logits artifact (teacher-forced
/// re-scoring each step — O(new_tokens) forward passes, used only for the
/// short gsm-mini answers).
pub fn greedy_generate(rt: &Runtime, cfg: &ConfigEntry, params: &ParamStore,
                       prompts: &[Vec<i32>], max_new: usize, stop: i32)
    -> Result<Vec<Vec<i32>>> {
    let (b, s) = (cfg.train_batch, cfg.train_seq);
    let v = cfg.vocab;
    let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
    for (chunk_idx, chunk) in prompts.chunks(b).enumerate() {
        let mut seqs: Vec<Vec<i32>> = chunk.to_vec();
        let mut done = vec![false; chunk.len()];
        for _ in 0..max_new {
            let mut batch = Batch::zeros(b, s);
            for (r, seq) in seqs.iter().enumerate() {
                for (t, &tok) in seq.iter().take(s).enumerate() {
                    batch.tokens[r * s + t] = tok;
                }
            }
            let logits = logits_for(rt, cfg, params, &batch)?;
            let mut all_done = true;
            for (r, seq) in seqs.iter_mut().enumerate() {
                if done[r] || seq.len() >= s {
                    done[r] = true;
                    continue;
                }
                let pos = seq.len() - 1;
                let lrow = &logits.data[(r * s + pos) * v..(r * s + pos + 1) * v];
                let next = argmax(lrow) as i32;
                seq.push(next);
                if next == stop {
                    done[r] = true;
                } else {
                    all_done = false;
                }
            }
            if all_done {
                break;
            }
        }
        for (r, seq) in seqs.into_iter().enumerate() {
            let prompt_len = chunk[r].len();
            outputs[chunk_idx * b + r] = seq[prompt_len..].to_vec();
        }
    }
    Ok(outputs)
}
