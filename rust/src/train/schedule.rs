//! Learning-rate schedules: linear warmup + cosine decay (the paper's
//! training protocol), plus constant for fine-tuning.

#[derive(Clone, Debug)]
pub enum Schedule {
    Constant { lr: f64 },
    WarmupCosine { base: f64, min: f64, warmup: usize, total: usize },
}

impl Schedule {
    pub fn warmup_cosine(base: f64, warmup: usize, total: usize) -> Schedule {
        Schedule::WarmupCosine { base, min: base * 0.1, warmup, total }
    }

    /// LR at a 0-based step index.
    pub fn lr(&self, step: usize) -> f64 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::WarmupCosine { base, min, warmup, total } => {
                if warmup > 0 && step < warmup {
                    return base * (step + 1) as f64 / warmup as f64;
                }
                let t = (step - warmup) as f64
                    / (total.saturating_sub(warmup)).max(1) as f64;
                let t = t.min(1.0);
                min + 0.5 * (base - min) * (1.0 + (std::f64::consts::PI * t).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::warmup_cosine(1.0, 10, 100);
        assert!((s.lr(0) - 0.1).abs() < 1e-12);
        assert!((s.lr(4) - 0.5).abs() < 1e-12);
        assert!((s.lr(9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = Schedule::warmup_cosine(1.0, 10, 100);
        assert!((s.lr(10) - 1.0).abs() < 1e-3);
        let mid = s.lr(55);
        assert!(mid < 1.0 && mid > 0.1);
        assert!((s.lr(99) - 0.1).abs() < 0.01);
        // past the end: stays at min
        assert!((s.lr(500) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn monotone_after_warmup() {
        let s = Schedule::warmup_cosine(3e-3, 20, 300);
        let mut last = f64::INFINITY;
        for step in 20..300 {
            let lr = s.lr(step);
            assert!(lr <= last + 1e-12);
            last = lr;
        }
    }

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { lr: 5e-5 };
        assert_eq!(s.lr(0), 5e-5);
        assert_eq!(s.lr(12345), 5e-5);
    }
}
