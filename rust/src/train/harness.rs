//! The train loop: state threading through the AOT train-step artifact.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::datagen::Batch;
use crate::runtime::client::{literal_to_f32, literal_to_tensor, Arg, Runtime};
use crate::runtime::manifest::ConfigEntry;
use crate::runtime::params::ParamStore;
use crate::substrate::tensor::{Tensor, TensorI32};
use crate::train::schedule::Schedule;

/// Optimizer-carrying training state.
#[derive(Clone)]
pub struct TrainState {
    pub params: ParamStore,
    pub m: ParamStore,
    pub v: ParamStore,
    pub step: usize,
}

impl TrainState {
    pub fn new(cfg: &ConfigEntry, seed: u64) -> TrainState {
        let params = ParamStore::init(cfg, seed);
        let m = params.zeros_like();
        let v = params.zeros_like();
        TrainState { params, m, v, step: 0 }
    }

    /// Fresh optimizer state around existing parameters (fine-tuning).
    pub fn from_params(params: ParamStore) -> TrainState {
        let m = params.zeros_like();
        let v = params.zeros_like();
        TrainState { params, m, v, step: 0 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainOutcome {
    pub losses: Vec<f64>,
    pub seconds: f64,
    pub tokens: f64,
}

impl TrainOutcome {
    pub fn final_loss(&self) -> f64 {
        *self.losses.last().unwrap_or(&f64::NAN)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        if self.seconds > 0.0 { self.tokens / self.seconds } else { 0.0 }
    }
}

/// Drives one artifact (train or qkft) for one config.
pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub artifact: String,
    pub cfg: ConfigEntry,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cfg_name: &str, qk_only: bool) -> Result<Self> {
        let m = rt.manifest();
        let cfg = m.config(cfg_name)?.clone();
        let artifact = if qk_only {
            m.qkft_name(cfg_name)
        } else {
            m.train_name(cfg_name)
        };
        if !m.artifacts.contains_key(&artifact) {
            bail!("no artifact {artifact} (re-run `make artifacts`)");
        }
        Ok(Trainer { rt, artifact, cfg })
    }

    /// One optimizer step; returns the loss. `state.step` increments.
    pub fn step(&self, state: &mut TrainState, batch: &Batch, lr: f64)
        -> Result<f64> {
        let (b, s) = (self.cfg.train_batch, self.cfg.train_seq);
        if batch.batch != b || batch.seq != s {
            bail!(
                "batch geometry ({}, {}) != artifact ({b}, {s})",
                batch.batch, batch.seq
            );
        }
        let tokens = TensorI32::new(&[b, s], batch.tokens.clone());
        let targets = TensorI32::new(&[b, s], batch.targets.clone());
        let mask = Tensor::new(&[b, s], batch.mask.clone());

        let n = state.params.tensors.len();
        let mut args: Vec<Arg> = Vec::with_capacity(3 * n + 5);
        for t in &state.params.tensors {
            args.push(Arg::F(t));
        }
        for t in &state.m.tensors {
            args.push(Arg::F(t));
        }
        for t in &state.v.tensors {
            args.push(Arg::F(t));
        }
        args.push(Arg::I(&tokens));
        args.push(Arg::I(&targets));
        args.push(Arg::F(&mask));
        args.push(Arg::ScalarF(lr as f32));
        args.push(Arg::ScalarF((state.step + 1) as f32));

        let outs = self.rt.execute(&self.artifact, &args)?;
        let loss = literal_to_f32(&outs[0])? as f64;
        let mut tensors = Vec::with_capacity(3 * n);
        for lit in &outs[1..] {
            tensors.push(literal_to_tensor(lit)?);
        }
        let vs = tensors.split_off(2 * n);
        let ms = tensors.split_off(n);
        state.params.replace_from(tensors)?;
        state.m.replace_from(ms)?;
        state.v.replace_from(vs)?;
        state.step += 1;
        if !loss.is_finite() {
            bail!("non-finite loss at step {}", state.step);
        }
        Ok(loss)
    }

    /// Run `n_steps` pulling batches from `next_batch`.
    pub fn run<F>(&self, state: &mut TrainState, n_steps: usize,
                  sched: &Schedule, mut next_batch: F) -> Result<TrainOutcome>
    where
        F: FnMut(usize) -> Batch,
    {
        let t0 = Instant::now();
        let mut out = TrainOutcome::default();
        for i in 0..n_steps {
            let batch = next_batch(i);
            let lr = sched.lr(state.step);
            let ntok = batch.masked_tokens();
            let loss = self.step(state, &batch, lr)?;
            out.losses.push(loss);
            out.tokens += ntok;
        }
        out.seconds = t0.elapsed().as_secs_f64();
        Ok(out)
    }
}
