//! Training harness — drives the AOT train-step executables from rust.
//!
//! The optimizer (AdamW) lives *inside* the HLO artifact; rust owns the
//! state between steps (params + first/second moments), the learning-rate
//! schedule, data order, and evaluation cadence. `kind = train` updates all
//! parameters; `kind = qkft` updates only the QK projections (paper's
//! 3-epoch recovery fine-tuning).

pub mod schedule;
pub mod harness;
pub mod eval;

pub use harness::{TrainOutcome, TrainState, Trainer};
pub use schedule::Schedule;
