//! Perf-trajectory persistence: the read/parse/append/write cycle behind
//! `BENCH_serving.json` (ROADMAP open item), pulled out of the bench
//! binary so the empty-report path is unit-testable end to end (ISSUE 8
//! satellite). The file accumulates one entry per bench run; a perf
//! regression shows up as a kink in the series rather than a silent
//! drift, so CORRUPTING the file (e.g. by serializing a non-finite rate
//! as the literal `inf`) silently restarts the series and erases the
//! baseline — exactly the failure this module and `substrate::json`'s
//! null-degradation guard close off.

use std::path::Path;

use crate::substrate::json::{arr, num, obj, Value};
use crate::Result;

/// One run entry: wrap `rows` (per-config measurement objects) with the
/// caller-supplied unix timestamp, append to the `runs` series in the
/// JSON document at `path`, and write it back. A missing file starts a
/// new series; an unparseable file restarts it (with a warning on
/// stderr, so a corrupted baseline is loud). Returns the serialized
/// document so callers/tests can assert on exactly what was written.
pub fn append_run(path: &Path, rows: Vec<Value>, unix_time: u64)
    -> Result<String> {
    let mut runs: Vec<Value> = match std::fs::read_to_string(path) {
        Ok(text) => match Value::parse(&text) {
            Ok(v) => v
                .opt("runs")
                .and_then(|r| r.as_arr().ok().map(|a| a.to_vec()))
                .unwrap_or_default(),
            Err(e) => {
                eprintln!(
                    "{} unreadable ({e}); restarting the series",
                    path.display());
                Vec::new()
            }
        },
        Err(_) => Vec::new(),
    };
    runs.push(obj(vec![
        ("unix_time", num(unix_time as f64)),
        ("configs", arr(rows)),
    ]));
    let doc = obj(vec![
        ("bench", crate::substrate::json::s("serving")),
        ("runs", arr(runs)),
    ]);
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write(path, &text)?;
    Ok(text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::{EngineMetrics, ServeReport};
    use crate::substrate::json::s;

    /// Build a trajectory row the way the bench binary does, straight
    /// off a report + metrics pair — including the ratio accessors that
    /// can go non-finite.
    fn row_for(cfg: &str, report: &ServeReport, m: &EngineMetrics)
        -> Value {
        obj(vec![
            ("config", s(cfg)),
            ("gen_tok_per_s", num(report.gen_tokens_per_sec())),
            ("req_per_s", num(report.requests_per_sec())),
            ("ttft_p50_us", num(report.ttft.quantile_us(0.5))),
            ("ttft_p99_us", num(report.ttft.quantile_us(0.99))),
            ("occupancy", num(m.mean_occupancy())),
            ("copyback_savings",
             num(m.copyback_savings().unwrap_or(f64::NAN))),
        ])
    }

    /// The satellite regression: an EMPTY ServeReport (nothing served,
    /// `total_s == 0`) driven end to end through the append must yield a
    /// document that parses back — rates 0 (not NaN), the undefined
    /// copyback ratio degraded to null (not the literal `NaN`/`inf` that
    /// used to corrupt the file) — and appending again must EXTEND the
    /// series rather than restart it.
    #[test]
    fn empty_report_appends_a_parseable_run_twice() {
        let dir = std::env::temp_dir().join(format!(
            "thinkeys_traj_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos())
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serving.json");

        let report = ServeReport::default();
        // the historical hazard: work saved entirely -> ratio INFINITY
        let metrics = EngineMetrics {
            copyback_bytes_full: 512,
            ..EngineMetrics::default()
        };
        assert_eq!(metrics.copyback_savings(), Some(f64::INFINITY));

        let text1 = append_run(
            &path, vec![row_for("servethin", &report, &metrics)], 1_000)
            .unwrap();
        let doc1 = Value::parse(&text1).expect("first append must parse");
        assert_eq!(doc1.opt("runs").unwrap().as_arr().unwrap().len(), 1);

        // second append: the series EXTENDS — proof the first write was
        // not silently corrupt (a parse failure would restart at len 1)
        let text2 = append_run(
            &path, vec![row_for("servethin", &report, &metrics)], 2_000)
            .unwrap();
        let doc2 = Value::parse(&text2).expect("second append must parse");
        let runs = doc2.opt("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2, "series restarted instead of extending");

        // the empty report's rates are finite zeros, and the non-finite
        // ratio degraded to null in the document
        let cfgs = runs[1].opt("configs").unwrap().as_arr().unwrap();
        assert_eq!(cfgs[0].opt("gen_tok_per_s"), Some(&Value::Num(0.0)));
        assert_eq!(cfgs[0].opt("req_per_s"), Some(&Value::Num(0.0)));
        assert_eq!(cfgs[0].opt("ttft_p50_us"), Some(&Value::Num(0.0)));
        assert_eq!(cfgs[0].opt("copyback_savings"), Some(&Value::Null));
        assert!(!text2.contains("inf") && !text2.contains("NaN"),
                "non-finite literal leaked into the document: {text2}");

        std::fs::remove_dir_all(&dir).ok();
    }

    /// A pre-existing series written by an older bench run survives the
    /// refactor: its entries are preserved and extended in order.
    #[test]
    fn existing_series_is_extended_in_order() {
        let dir = std::env::temp_dir().join(format!(
            "thinkeys_traj_ord_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serving.json");
        std::fs::write(
            &path,
            "{\"bench\": \"serving\", \"runs\": [{\"unix_time\": 7, \
             \"configs\": []}]}\n",
        )
        .unwrap();
        let text = append_run(&path, vec![], 9).unwrap();
        let doc = Value::parse(&text).unwrap();
        let runs = doc.opt("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].opt("unix_time"), Some(&Value::Num(7.0)));
        assert_eq!(runs[1].opt("unix_time"), Some(&Value::Num(9.0)));
        std::fs::remove_dir_all(&dir).ok();
    }
}
