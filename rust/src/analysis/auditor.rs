//! Runtime invariant auditor: cross-checks engine state against the block
//! accounting after every scheduler round.
//!
//! The engine and the KV-cache manager deliberately keep *independent* views
//! of the same physical truth — the engine owns lanes, arenas, and the
//! per-sequence committed-row mirror; the manager owns block tables and
//! admission budgets. The scheduler keeps them in sync by construction
//! (`commit_rows` after every prefill chunk and decode step, `release`
//! paired with `drop_seq` on retirement). This module re-derives that sync
//! from scratch each round and fails loudly the moment the two views
//! diverge, instead of letting a drift corrupt outputs thousands of steps
//! later.
//!
//! Compiled into the scheduler loop under
//! `#[cfg(any(debug_assertions, feature = "audit"))]` — debug and test
//! builds always audit; release builds opt in with `--features audit`
//! (~microseconds per round, no allocation on the success path beyond the
//! violation vec).
//!
//! Checks per round:
//! - every engine self-invariant from `Engine::invariant_violations` (lane
//!   map bijectivity, arena payload/scale bytes == `ArenaSizing`
//!   predictions, bucket/tier membership in the exported grid, parked and
//!   chunking arena geometry, no orphaned row entries);
//! - every engine-tracked sequence has a block table whose committed row
//!   count equals the engine's row mirror, within its reserved capacity;
//! - every block table with committed rows is engine-tracked (no leaked
//!   tables after retirement);
//! - the paged-block accounting is self-consistent (ISSUE 8,
//!   `KvCacheManager::refcount_violations`): refcounts equal the number
//!   of tables holding each block, the free list is exactly the ref==0
//!   blocks, every prefix-tree registration points at a live block, and
//!   blocks past a table's `shared_rows` are private (CoW safety);
//! - the evicted-rows ledger reconciles bidirectionally (ISSUE 10):
//!   the engine's count of physically zeroed rows per sequence equals the
//!   block accounting's evicted-slot holes × block_tokens, and committed
//!   rows never exceed live-block capacity + evicted rows — committed
//!   rows may legally be evicted, so the audit reasons in terms of slot
//!   conservation rather than contiguous block coverage;
//! - the engine's shared-prefix view matches the block accounting
//!   bidirectionally: per sequence, adopted prefix rows equal the
//!   table's `shared_rows`; every resident store block is still live in
//!   the pool (a freed-but-resident block is a missed `drop_blocks`);
//! - `sync_download_bytes == 0`: steady-state serving never round-trips an
//!   arena through host memory (device-resident KV is the whole point).

use crate::coordinator::engine::Engine;
use crate::coordinator::kvcache::KvCacheManager;
use crate::Result;
use std::collections::BTreeSet;

/// Run every cross-check once and return human-readable violations
/// (empty == all invariants hold). Read-only; usable from tests against
/// any engine + manager pair, not just mid-serving.
pub fn audit(engine: &Engine, kv: &KvCacheManager) -> Vec<String> {
    let mut v = engine.invariant_violations();

    // Engine row mirror ↔ block accounting, per sequence.
    let tracked = engine.tracked_rows();
    let mut tracked_ids: BTreeSet<_> = BTreeSet::new();
    for (id, rows) in &tracked {
        tracked_ids.insert(*id);
        match kv.rows_written(*id) {
            None => v.push(format!(
                "seq {id:?}: engine tracks {rows} committed rows but the \
                 block accounting has no table for it"
            )),
            Some(committed) if committed != *rows => v.push(format!(
                "seq {id:?}: engine row mirror says {rows} rows but block \
                 accounting committed {committed}"
            )),
            Some(_) => {}
        }
        if let Some(cap) = kv.seq_tokens(*id) {
            if *rows > cap {
                v.push(format!(
                    "seq {id:?}: {rows} committed rows exceed the reserved \
                     capacity of {cap} tokens"
                ));
            }
        }
    }

    // Reverse direction: a block table holding committed rows must belong
    // to a sequence the engine still knows about. (Tables with zero rows
    // are legal: reserved at admission, first chunk not yet executed.)
    for id in kv.live_seqs() {
        if kv.rows_written(id).unwrap_or(0) > 0 && !tracked_ids.contains(&id) {
            v.push(format!(
                "seq {id:?}: block accounting holds committed rows for a \
                 sequence the engine no longer tracks (leaked table?)"
            ));
        }
    }

    // Paged-block self-consistency: refcounts ↔ tables ↔ free list ↔
    // prefix tree, plus the CoW privacy invariant (ISSUE 8).
    v.extend(kv.refcount_violations());

    // Evicted-rows ledger: the engine's count of physically zeroed rows
    // must match the block accounting's slot holes, and the committed rows
    // must still fit in live blocks + holes (a table can never have more
    // rows written than slots that ever existed for them). Committed rows
    // may legally exceed live-block capacity once eviction has punched
    // holes — that is the whole point of bounded-cache streaming — so this
    // replaces naive `rows <= live_blocks * bt` reasoning.
    for (id, rows) in &tracked {
        let ledger = engine.evicted_rows_of(*id);
        let holes = kv.evicted_rows(*id).unwrap_or(0);
        if ledger != holes {
            v.push(format!(
                "seq {id:?}: engine evicted-rows ledger says {ledger} but \
                 block accounting has {holes} rows of evicted slots"
            ));
        }
        if let Some(table_rows) = kv.rows_written(*id) {
            let bt = kv.cfg.block_tokens;
            let live = kv.live_blocks(*id).unwrap_or(0);
            if table_rows != *rows {
                continue; // already reported above
            }
            if *rows > live * bt + holes {
                v.push(format!(
                    "seq {id:?}: {rows} committed rows exceed live-block \
                     capacity {} + evicted rows {holes}",
                    live * bt
                ));
            }
        }
    }

    // Engine shared-prefix view ↔ block accounting, both directions.
    for (id, _) in &tracked {
        let adopted = engine.prefix_rows(*id);
        let shared = kv.shared_rows(*id).unwrap_or(0);
        if adopted != shared {
            v.push(format!(
                "seq {id:?}: engine holds {adopted} shared prefix rows but \
                 the block table says shared_rows = {shared}"
            ));
        }
    }
    for b in engine.resident_prefix_blocks() {
        if kv.block_ref(b) == 0 {
            v.push(format!(
                "block {b}: resident in the engine's shared store but free \
                 in the pool (missed drop_blocks after release?)"
            ));
        }
    }

    // Device-residency tripwire.
    if engine.metrics.sync_download_bytes != 0 {
        v.push(format!(
            "sync_download_bytes = {} — a serving round downloaded an arena \
             to host memory; the KV cache must stay device-resident",
            engine.metrics.sync_download_bytes
        ));
    }

    v
}

/// Scheduler hook: audit one round, count it in
/// `metrics.audit_checks`, and fail the step on any violation.
pub fn audit_step(engine: &mut Engine, kv: &KvCacheManager) -> Result<()> {
    engine.metrics.audit_checks += 1;
    let violations = audit(engine, kv);
    if violations.is_empty() {
        Ok(())
    } else {
        anyhow::bail!(
            "engine invariant audit failed ({} violation(s)):\n  {}",
            violations.len(),
            violations.join("\n  ")
        )
    }
}
