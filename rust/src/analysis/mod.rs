//! Static and runtime analysis for the thin-keys serving stack.
//!
//! Two complementary checkers live here, both built on the idea that the
//! artifact grid and the engine state are *algebraically constrained* — every
//! shape, byte count, and (bucket, tier, quant) cell is derivable from the
//! config table and the scheduler's hysteresis rules, so divergence is always
//! a bug, never a judgment call:
//!
//! - [`grid`] — the **static grid auditor** behind `thinkeys check`. It
//!   verifies a `manifest.json` without executing a single artifact: the
//!   config algebra (`k_cache_dims == n_kv_heads * d_qk_head`, MLA joint
//!   dims, integral GQA groups), tier/chunk ladder well-formedness, per-kind
//!   artifact geometry (including the q8 scale-plane contract), cross-variant
//!   agreement (q8 vs fp32, ref vs pallas), and — the load-bearing rule —
//!   that every (bucket, tier, quant) cell *reachable* by the scheduler's
//!   actual hysteresis state machines has an exported artifact.
//! - [`auditor`] — the **runtime invariant auditor**. In debug builds (and
//!   release builds with the `audit` cargo feature) the scheduler ends every
//!   round by cross-checking the lane map, the row arenas, the engine's
//!   committed-row mirror, and the block accounting against each other, and
//!   asserting the steady-state contract `sync_download_bytes == 0`.
//!
//! The split mirrors how the checks run: `grid` at build/CI time against the
//! cached artifact grid, `auditor` continuously inside the e2e churn suites.

//! A third, smaller member — [`trajectory`] — owns the perf-trajectory
//! file (`BENCH_serving.json`) append cycle, so the bench binary and the
//! empty-report regression test share one implementation.

pub mod auditor;
pub mod grid;
pub mod trajectory;
