//! Static grid auditor (`thinkeys check`, layer 1 of ISSUE 6).
//!
//! Proves — without executing a single artifact — that the five-axis
//! artifact grid (config × batch-bucket × context-tier × prefill-chunk ×
//! kv_quant) is closed under the scheduler's state machines and that the
//! shape/dtype algebra holds everywhere. Every bug class the serving
//! stack has shipped fixes for (PR 1's lane misalignment, PR 2's stale
//! literal shapes, PR 4's dtype mismatches) was a *consistency* violation
//! that only surfaced as corrupted logits at runtime; these rules catch
//! the same classes at manifest-load time.
//!
//! Rules (each [`Violation`] names the rule and the offending artifact):
//!
//! - `schema-version`   — manifest stamped with the grid schema this
//!   checker understands ([`GRID_SCHEMA_VERSION`]).
//! - `config-algebra`   — `k_cache_dims == n_kv_heads·d_qk_head` (MLA:
//!   `d_c + d_r`), `kv_budget == k + v`, GQA group integral,
//!   `d_select % n_heads == 0`.
//! - `tier-ladder`      — tiers strictly ascending, non-final tiers
//!   power-of-two, last tier == max_seq.
//! - `chunk-ladder`     — chunks strictly ascending, each divides
//!   prefill_seq evenly (chunked prefill fills the prefill_seq arena).
//! - `grid-missing`     — every (bucket, tier, quant) decode cell, the
//!   b=8 Pallas column, both monolithic prefill impls, and every
//!   (chunk, quant) cell resolve to an artifact.
//! - `artifact-geometry`— recorded input shapes/dtypes match the cache
//!   contract (int8 arenas + one fp32 scale per (layer, lane, position)
//!   row under q8; scale-free fp32; chunk/prefill token windows).
//! - `variant-geometry` — q8/fp32 and ref/Pallas variants of the same
//!   logical artifact agree on payload geometry; the serve family shares
//!   quant/chunk/tier axes; monolithic prefill stays fp32-only.
//! - `reachability`     — the closure of the *live* hysteresis state
//!   machines ([`lanes::target_bucket`], [`lanes::target_tier`]) never
//!   reaches a (bucket, tier) cell the manifest lacks. The checker calls
//!   the scheduler's own transition functions, so the model matches the
//!   engine by construction.
//! - `file-missing`     — ([`check_files`]) every manifest entry's HLO
//!   file exists on disk.

use std::collections::BTreeSet;
use std::fmt;

use crate::coordinator::lanes;
use crate::runtime::manifest::{
    ArtifactEntry, ConfigEntry, InputSpec, KvQuant, Manifest,
};

/// The manifest grid schema this checker understands. aot.py stamps the
/// same constant (`SCHEMA_VERSION`); manifests exported before ISSUE 6
/// carry no stamp and load as version 1.
pub const GRID_SCHEMA_VERSION: usize = 2;

/// One violated rule, anchored to the artifact (or config) it names.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub artifact: String,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}: {}", self.rule, self.artifact, self.detail)
    }
}

fn fail(out: &mut Vec<Violation>, rule: &'static str, artifact: &str,
        detail: String) {
    out.push(Violation { rule, artifact: artifact.to_string(), detail });
}

/// Serving configs = the configs the decode grid was exported for.
fn serve_configs(m: &Manifest) -> Vec<&ConfigEntry> {
    m.decode_tiers
        .keys()
        .filter_map(|name| m.configs.get(name))
        .collect()
}

fn input<'a>(a: &'a ArtifactEntry, name: &str) -> Option<&'a InputSpec> {
    a.inputs.iter().find(|i| i.name == name)
}

fn expect_input(a: &ArtifactEntry, name: &str, dtype: &str, shape: &[usize],
                out: &mut Vec<Violation>) {
    match input(a, name) {
        None => fail(out, "artifact-geometry", &a.name,
                     format!("missing input {name:?}")),
        Some(i) => {
            if i.dtype != dtype {
                fail(out, "artifact-geometry", &a.name,
                     format!("input {name:?} dtype {} != {dtype}", i.dtype));
            }
            if i.shape != shape {
                fail(out, "artifact-geometry", &a.name,
                     format!("input {name:?} shape {:?} != {shape:?}",
                             i.shape));
            }
        }
    }
}

fn forbid_input(a: &ArtifactEntry, name: &str, out: &mut Vec<Violation>) {
    if input(a, name).is_some() {
        fail(out, "artifact-geometry", &a.name,
             format!("fp32 artifact carries quant input {name:?}"));
    }
}

fn expect_output_tail(a: &ArtifactEntry, tail: &[&str],
                      out: &mut Vec<Violation>) {
    let got: Vec<&str> = a.outputs.iter().map(String::as_str).collect();
    if got.len() < tail.len() || &got[got.len() - tail.len()..] != tail {
        fail(out, "artifact-geometry", &a.name,
             format!("outputs {:?} do not end in {tail:?}", a.outputs));
    }
}

// --- rule: schema-version ---

fn check_schema(m: &Manifest, out: &mut Vec<Violation>) {
    if m.schema_version < GRID_SCHEMA_VERSION {
        fail(out, "schema-version", "manifest.json",
             format!("schema_version {} < {GRID_SCHEMA_VERSION} — legacy \
                      manifest, re-run `make artifacts`",
                     m.schema_version));
    } else if m.schema_version > GRID_SCHEMA_VERSION {
        fail(out, "schema-version", "manifest.json",
             format!("schema_version {} > {GRID_SCHEMA_VERSION} — manifest \
                      newer than this checker",
                     m.schema_version));
    }
}

// --- rule: config-algebra ---

fn check_config_algebra(c: &ConfigEntry, out: &mut Vec<Violation>) {
    let name = &c.name;
    if c.n_kv_heads == 0 || c.n_heads % c.n_kv_heads != 0 {
        fail(out, "config-algebra", name,
             format!("GQA group n_heads {} / n_kv_heads {} not integral",
                     c.n_heads, c.n_kv_heads));
        return; // the width algebra below would divide by zero / mislead
    }
    if c.n_heads == 0 || c.d_select % c.n_heads != 0 {
        fail(out, "config-algebra", name,
             format!("d_select {} not divisible by n_heads {}",
                     c.d_select, c.n_heads));
    }
    let (want_k, want_v) = if c.attn == "mla" {
        (c.d_c + c.d_r, 0)
    } else {
        (c.n_kv_heads * c.d_qk_head, c.n_kv_heads * c.d_v_head)
    };
    if c.k_cache_dims != want_k {
        fail(out, "config-algebra", name,
             format!("k_cache_dims {} != {want_k} \
                      (attn {:?}, n_kv_heads {}, d_qk_head {})",
                     c.k_cache_dims, c.attn, c.n_kv_heads, c.d_qk_head));
    }
    if c.v_cache_dims != want_v {
        fail(out, "config-algebra", name,
             format!("v_cache_dims {} != {want_v}", c.v_cache_dims));
    }
    if c.kv_budget != c.k_cache_dims + c.v_cache_dims {
        fail(out, "config-algebra", name,
             format!("kv_budget {} != k {} + v {}",
                     c.kv_budget, c.k_cache_dims, c.v_cache_dims));
    }
}

// --- rules: tier-ladder / chunk-ladder ---

fn is_pow2(x: usize) -> bool {
    x != 0 && x & (x - 1) == 0
}

fn check_ladders(m: &Manifest, out: &mut Vec<Violation>) {
    for (name, tiers) in &m.decode_tiers {
        let label = format!("decode_tiers[{name}]");
        if tiers.is_empty() {
            fail(out, "tier-ladder", &label, "empty tier ladder".into());
            continue;
        }
        if !tiers.windows(2).all(|w| w[0] < w[1]) {
            fail(out, "tier-ladder", &label,
                 format!("tiers {tiers:?} not strictly ascending"));
        }
        for &t in &tiers[..tiers.len() - 1] {
            if !is_pow2(t) {
                fail(out, "tier-ladder", &label,
                     format!("non-final tier {t} is not a power of two"));
            }
        }
        if let Some(c) = m.configs.get(name) {
            let last = *tiers.last().expect("ladder checked non-empty");
            if last != c.max_seq {
                fail(out, "tier-ladder", &label,
                     format!("last tier {last} != max_seq {}", c.max_seq));
            }
        }
    }
    for (name, chunks) in &m.prefill_chunks {
        let label = format!("prefill_chunks[{name}]");
        if !chunks.windows(2).all(|w| w[0] < w[1]) {
            fail(out, "chunk-ladder", &label,
                 format!("chunks {chunks:?} not strictly ascending"));
        }
        for &c in chunks {
            if c == 0 || m.prefill_seq % c != 0 {
                fail(out, "chunk-ladder", &label,
                     format!("chunk {c} does not divide prefill_seq {} \
                              evenly",
                             m.prefill_seq));
            }
        }
    }
}

// --- rules: grid-missing + artifact-geometry ---

fn check_decode_geometry(cfg: &ConfigEntry, a: &ArtifactEntry, b: usize,
                         n: usize, q: KvQuant, out: &mut Vec<Violation>) {
    let (l, kd, vd) = (cfg.n_layers, cfg.k_cache_dims, cfg.v_cache_dims);
    let payload = match q {
        KvQuant::Q8 => "int8",
        KvQuant::Fp32 => "float32",
    };
    expect_input(a, "k_cache", payload, &[l, b, n, kd], out);
    expect_input(a, "v_cache", payload, &[l, b, n, vd], out);
    expect_input(a, "tokens", "int32", &[b], out);
    expect_input(a, "pos", "int32", &[b], out);
    match q {
        KvQuant::Q8 => {
            // one fp32 scale per (layer, lane, position) row
            expect_input(a, "k_scale", "float32", &[l, b, n], out);
            expect_input(a, "v_scale", "float32", &[l, b, n], out);
            // decode also exports the per-row attention-mass plane the
            // eviction scorer consumes (ISSUE 10)
            expect_output_tail(
                a,
                &["k_rows", "k_row_scale", "v_rows", "v_row_scale",
                  "attn_mass"],
                out);
        }
        KvQuant::Fp32 => {
            forbid_input(a, "k_scale", out);
            forbid_input(a, "v_scale", out);
            expect_output_tail(a, &["k_rows", "v_rows", "attn_mass"], out);
        }
    }
}

fn check_chunk_geometry(m: &Manifest, cfg: &ConfigEntry, a: &ArtifactEntry,
                        chunk: usize, q: KvQuant, out: &mut Vec<Violation>) {
    let (l, s) = (cfg.n_layers, m.prefill_seq);
    let (kd, vd) = (cfg.k_cache_dims, cfg.v_cache_dims);
    let payload = match q {
        KvQuant::Q8 => "int8",
        KvQuant::Fp32 => "float32",
    };
    expect_input(a, "k_cache", payload, &[l, s, kd], out);
    expect_input(a, "v_cache", payload, &[l, s, vd], out);
    expect_input(a, "tokens", "int32", &[1, chunk], out);
    expect_input(a, "start", "int32", &[], out);
    expect_input(a, "length", "int32", &[], out);
    match q {
        KvQuant::Q8 => {
            expect_input(a, "k_scale", "float32", &[l, s], out);
            expect_input(a, "v_scale", "float32", &[l, s], out);
            expect_output_tail(
                a, &["k_rows", "k_row_scale", "v_rows", "v_row_scale"], out);
        }
        KvQuant::Fp32 => {
            forbid_input(a, "k_scale", out);
            forbid_input(a, "v_scale", out);
            expect_output_tail(a, &["k_rows", "v_rows"], out);
        }
    }
}

fn check_prefill_geometry(m: &Manifest, a: &ArtifactEntry,
                          out: &mut Vec<Violation>) {
    expect_input(a, "tokens", "int32", &[1, m.prefill_seq], out);
    expect_input(a, "length", "int32", &[], out);
    expect_output_tail(a, &["last_logits", "k_cache", "v_cache"], out);
}

fn check_grid(m: &Manifest, out: &mut Vec<Violation>) {
    for cfg in serve_configs(m) {
        let name = &cfg.name;
        let tiers = m.tiers_for(name);
        let quants = m.kv_quants_for(name);
        for &b in &m.decode_batches {
            for &n in &tiers {
                for &q in &quants {
                    let ref_name = m.decode_name(name, b, n, false, q);
                    match m.artifacts.get(&ref_name) {
                        None => fail(out, "grid-missing", &ref_name,
                                     format!("decode cell (b={b}, n={n}, \
                                              {}) has no artifact",
                                             q.name())),
                        Some(a) => {
                            check_decode_geometry(cfg, a, b, n, q, out)
                        }
                    }
                    if b == 8 {
                        let pl = m.decode_name(name, b, n, true, q);
                        match m.artifacts.get(&pl) {
                            None => fail(out, "grid-missing", &pl,
                                         format!("Pallas decode column \
                                                  (b=8, n={n}, {}) has no \
                                                  artifact",
                                                 q.name())),
                            Some(a) => {
                                check_decode_geometry(cfg, a, b, n, q, out)
                            }
                        }
                    }
                }
            }
        }
        for pallas in [false, true] {
            let pf = m.prefill_name(name, pallas);
            match m.artifacts.get(&pf) {
                None => fail(out, "grid-missing", &pf,
                             "monolithic prefill has no artifact".into()),
                Some(a) => check_prefill_geometry(m, a, out),
            }
        }
        for &c in &m.chunks_for(name) {
            for &q in &quants {
                let cn = m.prefill_chunk_name(name, c, q);
                match m.artifacts.get(&cn) {
                    None => fail(out, "grid-missing", &cn,
                                 format!("chunk cell (c={c}, {}) has no \
                                          artifact",
                                         q.name())),
                    Some(a) => check_chunk_geometry(m, cfg, a, c, q, out),
                }
            }
        }
    }
}

// --- rule: variant-geometry ---

fn check_variants(m: &Manifest, out: &mut Vec<Violation>) {
    let serves = serve_configs(m);
    // the serve family shares the quant and chunk axes (the exporter
    // stamps global KV_QUANTS / PREFILL_CHUNKS); a config that drifted
    // would silently lose grid columns
    if let Some(first) = serves.first() {
        let q0 = m.kv_quants_for(&first.name);
        let c0 = m.chunks_for(&first.name);
        for cfg in &serves[1..] {
            if m.kv_quants_for(&cfg.name) != q0 {
                fail(out, "variant-geometry", &cfg.name,
                     format!("kv_quant axis differs from {}", first.name));
            }
            if m.chunks_for(&cfg.name) != c0 {
                fail(out, "variant-geometry", &cfg.name,
                     format!("chunk ladder differs from {}", first.name));
            }
        }
    }
    // equal-max_seq serve configs must share tier ladders (the router
    // moves sequences between configs at the same context budget)
    for a in &serves {
        for b in &serves {
            if a.name < b.name && a.max_seq == b.max_seq
                && m.tiers_for(&a.name) != m.tiers_for(&b.name)
            {
                fail(out, "variant-geometry", &b.name,
                     format!("tier ladder differs from {} at equal \
                              max_seq {}",
                             a.name, a.max_seq));
            }
        }
    }
    for cfg in &serves {
        let name = &cfg.name;
        // monolithic prefill is fp32-only by design (compute-bound, §12)
        let q8_prefill = format!("prefill_{name}_s{}_q8", m.prefill_seq);
        if m.artifacts.contains_key(&q8_prefill) {
            fail(out, "variant-geometry", &q8_prefill,
                 "monolithic prefill must stay fp32-only".into());
        }
        for &b in &m.decode_batches {
            for &n in &m.tiers_for(name) {
                // q8 and fp32 agree on payload geometry
                let f = m.artifacts.get(
                    &m.decode_name(name, b, n, false, KvQuant::Fp32));
                let q = m.artifacts.get(
                    &m.decode_name(name, b, n, false, KvQuant::Q8));
                if let (Some(f), Some(q)) = (f, q) {
                    for arena in ["k_cache", "v_cache"] {
                        let (fs, qs) = (input(f, arena), input(q, arena));
                        if let (Some(fs), Some(qs)) = (fs, qs) {
                            if fs.shape != qs.shape {
                                fail(out, "variant-geometry", &q.name,
                                     format!("{arena} shape {:?} != fp32 \
                                              twin {:?}",
                                             qs.shape, fs.shape));
                            }
                        }
                    }
                }
                // ref and Pallas lower the identical signature
                if b == 8 {
                    for &quant in &m.kv_quants_for(name) {
                        let r = m.artifacts.get(
                            &m.decode_name(name, b, n, false, quant));
                        let p = m.artifacts.get(
                            &m.decode_name(name, b, n, true, quant));
                        if let (Some(r), Some(p)) = (r, p) {
                            for ri in &r.inputs {
                                match input(p, &ri.name) {
                                    Some(pi) if pi.shape == ri.shape
                                        && pi.dtype == ri.dtype => {}
                                    _ => fail(
                                        out, "variant-geometry", &p.name,
                                        format!("input {:?} differs from \
                                                 ref twin {}",
                                                ri.name, r.name)),
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

// --- rule: reachability ---

/// Closure of [`lanes::target_bucket`] over every admissible active-set
/// size from every reachable current bucket. Errors when the state
/// machine steps outside the exported bucket list.
pub fn reachable_buckets(buckets: &[usize])
    -> Result<BTreeSet<usize>, String> {
    let Some(&max) = buckets.iter().max() else {
        return Err("empty bucket list".into());
    };
    let mut reached = BTreeSet::new();
    let mut frontier = vec![0usize];
    let mut visited: BTreeSet<usize> = frontier.iter().copied().collect();
    while let Some(cur) = frontier.pop() {
        for n in 1..=max {
            let Some(b) = lanes::target_bucket(buckets, n, cur) else {
                return Err(format!(
                    "target_bucket({buckets:?}, n={n}, current={cur}) \
                     has no bucket"));
            };
            if !buckets.contains(&b) {
                return Err(format!(
                    "target_bucket reached {b}, not an exported bucket \
                     of {buckets:?}"));
            }
            reached.insert(b);
            if visited.insert(b) {
                frontier.push(b);
            }
        }
    }
    Ok(reached)
}

/// Closure of [`lanes::target_tier`] over every context length up to
/// `max_seq` from every reachable current tier.
pub fn reachable_tiers(tiers: &[usize], max_seq: usize)
    -> Result<BTreeSet<usize>, String> {
    if tiers.is_empty() {
        return Err("empty tier ladder".into());
    }
    let mut reached = BTreeSet::new();
    let mut frontier = vec![0usize];
    let mut visited: BTreeSet<usize> = frontier.iter().copied().collect();
    while let Some(cur) = frontier.pop() {
        for need in 1..=max_seq {
            let Some(t) = lanes::target_tier(tiers, need, cur) else {
                return Err(format!(
                    "target_tier({tiers:?}, need={need}, current={cur}) \
                     has no tier — ladder does not cover max_seq \
                     {max_seq}"));
            };
            if !tiers.contains(&t) {
                return Err(format!(
                    "target_tier reached {t}, not an exported tier of \
                     {tiers:?}"));
            }
            reached.insert(t);
            if visited.insert(t) {
                frontier.push(t);
            }
        }
    }
    Ok(reached)
}

fn check_reachability(m: &Manifest, out: &mut Vec<Violation>) {
    let buckets = match reachable_buckets(&m.decode_batches) {
        Ok(b) => b,
        Err(e) => {
            fail(out, "reachability", "decode_batches", e);
            return;
        }
    };
    for cfg in serve_configs(m) {
        let name = &cfg.name;
        let tiers = match reachable_tiers(&m.tiers_for(name), cfg.max_seq) {
            Ok(t) => t,
            Err(e) => {
                fail(out, "reachability",
                     &format!("decode_tiers[{name}]"), e);
                continue;
            }
        };
        for &b in &buckets {
            for &n in &tiers {
                for &q in &m.kv_quants_for(name) {
                    let an = m.decode_name(name, b, n, false, q);
                    if !m.artifacts.contains_key(&an) {
                        fail(out, "reachability", &an,
                             format!("cell (b={b}, n={n}, {}) is reachable \
                                      by the hysteresis state machines but \
                                      has no artifact",
                                     q.name()));
                    }
                }
            }
        }
    }
}

/// Every rule name this checker can emit, in roughly the order the rules
/// run. Kept as data so `thinkeys check` can report coverage and docs can
/// stay honest about what is (and is not) audited.
pub const RULES: &[&str] = &[
    "schema-version",
    "config-algebra",
    "tier-ladder",
    "chunk-ladder",
    "grid-missing",
    "artifact-geometry",
    "variant-geometry",
    "reachability",
    "file-missing",
];

/// Run every static rule against a loaded manifest. Empty == grid proven
/// consistent.
pub fn check_manifest(m: &Manifest) -> Vec<Violation> {
    let mut out = Vec::new();
    check_schema(m, &mut out);
    for c in m.configs.values() {
        check_config_algebra(c, &mut out);
    }
    check_ladders(m, &mut out);
    check_grid(m, &mut out);
    check_variants(m, &mut out);
    check_reachability(m, &mut out);
    out
}

/// Every manifest entry's HLO file exists on disk (separate from
/// [`check_manifest`] so synthetic manifests can be checked file-free).
pub fn check_files(m: &Manifest) -> Vec<Violation> {
    let mut out = Vec::new();
    for a in m.artifacts.values() {
        if !m.dir.join(&a.file).exists() {
            fail(&mut out, "file-missing", &a.name,
                 format!("{} not found under {:?}", a.file, m.dir));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::AdamConfig;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    const L: usize = 2;
    const KD: usize = 8;
    const VD: usize = 16;
    const MAX_SEQ: usize = 64;
    const PREFILL: usize = 32;

    fn mini_config() -> ConfigEntry {
        ConfigEntry {
            name: "mini".into(),
            arch: "llama".into(),
            attn: "gqa".into(),
            vocab: 32,
            d_model: 16,
            n_layers: L,
            n_heads: 4,
            n_kv_heads: 2,
            d_select: 16,
            d_ff: 32,
            max_seq: MAX_SEQ,
            d_c: 0,
            d_r: 0,
            d_qk_head: 4,
            d_v_head: 8,
            k_cache_dims: KD,
            v_cache_dims: VD,
            kv_budget: KD + VD,
            train_batch: 2,
            train_seq: 16,
            params: vec![],
        }
    }

    fn inp(name: &str, dtype: &str, shape: Vec<usize>) -> InputSpec {
        InputSpec { name: name.into(), dtype: dtype.into(), shape }
    }

    fn art(name: &str, kind: &str, inputs: Vec<InputSpec>,
           outputs: &[&str]) -> ArtifactEntry {
        ArtifactEntry {
            name: name.into(),
            file: format!("{name}.hlo.txt"),
            kind: kind.into(),
            config: "mini".into(),
            geom: BTreeMap::new(),
            inputs,
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
            n_params: 0,
        }
    }

    fn decode_art(b: usize, n: usize, q8: bool, pallas: bool)
        -> ArtifactEntry {
        let pd = if q8 { "int8" } else { "float32" };
        let mut inputs = vec![inp("k_cache", pd, vec![L, b, n, KD])];
        if q8 {
            inputs.push(inp("k_scale", "float32", vec![L, b, n]));
        }
        inputs.push(inp("v_cache", pd, vec![L, b, n, VD]));
        if q8 {
            inputs.push(inp("v_scale", "float32", vec![L, b, n]));
        }
        inputs.push(inp("tokens", "int32", vec![b]));
        inputs.push(inp("pos", "int32", vec![b]));
        let q = if q8 { "_q8" } else { "" };
        let p = if pallas { "_pallas" } else { "" };
        let outs: &[&str] = if q8 {
            &["logits", "k_cache", "k_scale", "v_cache", "v_scale",
              "k_rows", "k_row_scale", "v_rows", "v_row_scale", "attn_mass"]
        } else {
            &["logits", "k_cache", "v_cache", "k_rows", "v_rows",
              "attn_mass"]
        };
        art(&format!("decode_mini_b{b}_n{n}{q}{p}"), "decode", inputs, outs)
    }

    fn chunk_art(c: usize, q8: bool) -> ArtifactEntry {
        let pd = if q8 { "int8" } else { "float32" };
        let mut inputs = vec![inp("k_cache", pd, vec![L, PREFILL, KD])];
        if q8 {
            inputs.push(inp("k_scale", "float32", vec![L, PREFILL]));
        }
        inputs.push(inp("v_cache", pd, vec![L, PREFILL, VD]));
        if q8 {
            inputs.push(inp("v_scale", "float32", vec![L, PREFILL]));
        }
        inputs.push(inp("tokens", "int32", vec![1, c]));
        inputs.push(inp("start", "int32", vec![]));
        inputs.push(inp("length", "int32", vec![]));
        let q = if q8 { "_q8" } else { "" };
        let outs: &[&str] = if q8 {
            &["last_logits", "k_cache", "k_scale", "v_cache", "v_scale",
              "k_rows", "k_row_scale", "v_rows", "v_row_scale"]
        } else {
            &["last_logits", "k_cache", "v_cache", "k_rows", "v_rows"]
        };
        art(&format!("prefill_mini_c{c}{q}"), "prefill", inputs, outs)
    }

    fn prefill_art(pallas: bool) -> ArtifactEntry {
        let p = if pallas { "_pallas" } else { "" };
        art(&format!("prefill_mini_s{PREFILL}{p}"), "prefill",
            vec![inp("tokens", "int32", vec![1, PREFILL]),
                 inp("length", "int32", vec![])],
            &["last_logits", "k_cache", "v_cache"])
    }

    fn mini_manifest() -> Manifest {
        let tiers = vec![32, MAX_SEQ];
        let chunks = vec![8, 16];
        let batches = vec![1, 2, 8];
        let mut artifacts = BTreeMap::new();
        let mut put = |a: ArtifactEntry| {
            artifacts.insert(a.name.clone(), a);
        };
        for &b in &batches {
            for &n in &tiers {
                for q8 in [false, true] {
                    put(decode_art(b, n, q8, false));
                    if b == 8 {
                        put(decode_art(b, n, q8, true));
                    }
                }
            }
        }
        for &c in &chunks {
            for q8 in [false, true] {
                put(chunk_art(c, q8));
            }
        }
        put(prefill_art(false));
        put(prefill_art(true));
        Manifest {
            dir: PathBuf::from("/nonexistent"),
            schema_version: GRID_SCHEMA_VERSION,
            adam: AdamConfig {
                b1: 0.9, b2: 0.95, eps: 1e-8, weight_decay: 0.0,
            },
            decode_batches: batches,
            decode_tiers: [("mini".to_string(), tiers)].into(),
            prefill_chunks: [("mini".to_string(), chunks)].into(),
            kv_quant: [("mini".to_string(),
                        vec!["fp32".to_string(), "q8".to_string()])].into(),
            prefill_seq: PREFILL,
            configs: [("mini".to_string(), mini_config())].into(),
            artifacts,
        }
    }

    fn rules(v: &[Violation]) -> BTreeSet<&'static str> {
        v.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn clean_mini_manifest_passes() {
        let m = mini_manifest();
        let v = check_manifest(&m);
        assert!(v.is_empty(), "{v:?}");
    }

    /// Seeded corruption 1: a reachable decode tier cell goes missing.
    #[test]
    fn missing_tier_artifact_fails_grid_and_reachability() {
        let mut m = mini_manifest();
        m.artifacts.remove("decode_mini_b2_n64_q8");
        let v = check_manifest(&m);
        assert!(rules(&v).contains("grid-missing"), "{v:?}");
        assert!(rules(&v).contains("reachability"), "{v:?}");
        assert!(v.iter().any(|x| x.artifact == "decode_mini_b2_n64_q8"));
    }

    /// Seeded corruption 2: k_cache_dims drifts from the head algebra.
    #[test]
    fn mismatched_k_cache_dims_fails_config_algebra() {
        let mut m = mini_manifest();
        m.configs.get_mut("mini").expect("mini config").k_cache_dims += 1;
        let v = check_manifest(&m);
        assert!(rules(&v).contains("config-algebra"), "{v:?}");
        assert!(v.iter().any(|x| x.artifact == "mini"
                            && x.detail.contains("k_cache_dims")));
    }

    /// Seeded corruption 3: a q8 variant loses its scale plane.
    #[test]
    fn q8_missing_scale_plane_fails_geometry() {
        let mut m = mini_manifest();
        let a = m.artifacts.get_mut("decode_mini_b1_n32_q8")
            .expect("q8 artifact");
        a.inputs.retain(|i| i.name != "k_scale");
        let v = check_manifest(&m);
        assert!(v.iter().any(|x| x.rule == "artifact-geometry"
                            && x.artifact == "decode_mini_b1_n32_q8"
                            && x.detail.contains("k_scale")),
                "{v:?}");
    }

    #[test]
    fn non_pow2_tier_fails_ladder() {
        let mut m = mini_manifest();
        m.decode_tiers.insert("mini".into(), vec![48, MAX_SEQ]);
        let v = check_manifest(&m);
        assert!(v.iter().any(|x| x.rule == "tier-ladder"
                            && x.detail.contains("48")),
                "{v:?}");
    }

    #[test]
    fn non_dividing_chunk_fails_ladder() {
        let mut m = mini_manifest();
        m.prefill_chunks.insert("mini".into(), vec![24]);
        let v = check_manifest(&m);
        assert!(v.iter().any(|x| x.rule == "chunk-ladder"
                            && x.detail.contains("24")),
                "{v:?}");
    }

    #[test]
    fn legacy_schema_fails_schema_version() {
        let mut m = mini_manifest();
        m.schema_version = 1;
        let v = check_manifest(&m);
        assert!(v.iter().any(|x| x.rule == "schema-version"
                            && x.detail.contains("legacy")),
                "{v:?}");
    }

    #[test]
    fn tier_ladder_not_covering_max_seq_fails_reachability() {
        let mut m = mini_manifest();
        // drop the max_seq tier: lengths past 32 have no arena
        m.decode_tiers.insert("mini".into(), vec![32]);
        let v = check_manifest(&m);
        assert!(rules(&v).contains("tier-ladder"), "{v:?}");
        assert!(rules(&v).contains("reachability"), "{v:?}");
    }

    #[test]
    fn check_files_flags_absent_hlo() {
        let m = mini_manifest();
        let v = check_files(&m);
        assert!(!v.is_empty());
        assert!(v.iter().all(|x| x.rule == "file-missing"));
    }

    #[test]
    fn reachable_sets_cover_exported_axes() {
        assert_eq!(
            reachable_buckets(&[1, 2, 8]).expect("buckets reachable"),
            BTreeSet::from([1, 2, 8]));
        assert_eq!(
            reachable_tiers(&[32, 64], 64).expect("tiers reachable"),
            BTreeSet::from([32, 64]));
        assert!(reachable_tiers(&[32], 64).is_err());
        assert!(reachable_buckets(&[]).is_err());
    }

    /// The real grid, when present and stamped, is proven consistent —
    /// the `thinkeys check` happy path.
    #[test]
    fn real_manifest_passes_all_rules() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).expect("manifest loads");
        if m.schema_version < GRID_SCHEMA_VERSION {
            return; // stale pre-ISSUE-6 export on disk
        }
        let v = check_manifest(&m);
        assert!(v.is_empty(), "{v:#?}");
        let f = check_files(&m);
        assert!(f.is_empty(), "{f:#?}");
    }
}
