//! Benchmark harness (criterion is not in the vendored registry).
//!
//! Warmup + timed iterations with mean/p50/p99, plus an aligned table
//! printer shared by all `rust/benches/bench_table*.rs` targets so their
//! output mirrors the paper's tables.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        if self.mean_s == 0.0 {
            0.0
        } else {
            items_per_iter / self.mean_s
        }
    }
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len().max(1) as f64;
    let q = |p: f64| times[(p * (times.len() - 1) as f64).round() as usize];
    Stats {
        iters,
        mean_s: mean,
        p50_s: q(0.50),
        p99_s: q(0.99),
        min_s: times[0],
    }
}

/// Time a single run of `f` (for long experiment steps).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Markdown-ish aligned table printer used by every bench target.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n## {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        let _ = ncol;
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds human-readably.
pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let st = bench(2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(st.iters, 10);
        assert!(st.min_s <= st.p50_s && st.p50_s <= st.p99_s);
    }

    #[test]
    fn throughput() {
        let st = Stats { iters: 1, mean_s: 0.5, p50_s: 0.5, p99_s: 0.5, min_s: 0.5 };
        assert!((st.throughput(100.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["a", "bbbb"]);
        t.rowf(&["x", "y"]);
        t.rowf(&["long", "z"]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("| long | z    |"));
    }

    #[test]
    fn fmt_s_ranges() {
        assert!(fmt_s(2e-9).ends_with("ns"));
        assert!(fmt_s(5e-5).ends_with("us"));
        assert!(fmt_s(5e-2).ends_with("ms"));
        assert!(fmt_s(2.0).ends_with('s'));
    }
}
