//! Tokenizers: word-level with min-frequency vocabulary truncation (the
//! protocol of paper Experiments 3/4/6) and a byte-level fallback.
//!
//! IDs 0..N_SPECIALS are reserved: `<pad>`, `<unk>`, `<bos>`, `<eos>`.

use std::collections::{BTreeMap, HashMap};

pub const PAD: i32 = 0;
pub const UNK: i32 = 1;
pub const BOS: i32 = 2;
pub const EOS: i32 = 3;
pub const N_SPECIALS: usize = 4;

pub trait Tokenizer {
    fn vocab_size(&self) -> usize;
    fn encode(&self, text: &str) -> Vec<i32>;
    fn decode(&self, ids: &[i32]) -> String;
}

/// Word-level tokenizer built from a corpus with min-frequency truncation.
pub struct WordTokenizer {
    word_to_id: HashMap<String, i32>,
    id_to_word: Vec<String>,
}

impl WordTokenizer {
    /// Build from whitespace-tokenized text. Words with count < `min_freq`
    /// map to `<unk>`. `max_vocab` caps the vocabulary (most frequent kept).
    pub fn build(corpus: &str, min_freq: usize, max_vocab: usize) -> Self {
        let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
        for w in corpus.split_whitespace() {
            *counts.entry(w).or_default() += 1;
        }
        let mut items: Vec<(&str, usize)> = counts
            .into_iter()
            .filter(|&(_, c)| c >= min_freq)
            .collect();
        // Sort by (-count, word) for deterministic ids.
        items.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        items.truncate(max_vocab.saturating_sub(N_SPECIALS));

        let mut id_to_word: Vec<String> =
            ["<pad>", "<unk>", "<bos>", "<eos>"].iter().map(|s| s.to_string()).collect();
        let mut word_to_id = HashMap::new();
        for (w, _) in items {
            word_to_id.insert(w.to_string(), id_to_word.len() as i32);
            id_to_word.push(w.to_string());
        }
        WordTokenizer { word_to_id, id_to_word }
    }
}

impl Tokenizer for WordTokenizer {
    fn vocab_size(&self) -> usize {
        self.id_to_word.len()
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace()
            .map(|w| self.word_to_id.get(w).copied().unwrap_or(UNK))
            .collect()
    }

    fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&i| {
                self.id_to_word
                    .get(i as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<oov>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Byte-level tokenizer: ids are 4 + byte value (vocab 260).
pub struct ByteTokenizer;

impl Tokenizer for ByteTokenizer {
    fn vocab_size(&self) -> usize {
        N_SPECIALS + 256
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| N_SPECIALS as i32 + b as i32).collect()
    }

    fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&i| i >= N_SPECIALS as i32 && i < (N_SPECIALS + 256) as i32)
            .map(|&i| (i - N_SPECIALS as i32) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_tokenizer_roundtrip_known_words() {
        let t = WordTokenizer::build("a b c a b a", 1, 100);
        assert_eq!(t.vocab_size(), N_SPECIALS + 3);
        let ids = t.encode("a c b");
        assert_eq!(t.decode(&ids), "a c b");
        // most frequent word gets the first id
        assert_eq!(t.encode("a")[0], N_SPECIALS as i32);
    }

    #[test]
    fn min_freq_maps_rare_to_unk() {
        let t = WordTokenizer::build("x x x rare", 2, 100);
        assert_eq!(t.encode("rare"), vec![UNK]);
        assert_eq!(t.encode("x"), vec![N_SPECIALS as i32]);
    }

    #[test]
    fn max_vocab_truncates_by_frequency() {
        let t = WordTokenizer::build("a a a b b c", 1, N_SPECIALS + 2);
        assert_eq!(t.vocab_size(), N_SPECIALS + 2);
        assert_ne!(t.encode("a"), vec![UNK]);
        assert_ne!(t.encode("b"), vec![UNK]);
        assert_eq!(t.encode("c"), vec![UNK]);
    }

    #[test]
    fn deterministic_ids() {
        let a = WordTokenizer::build("z y x z y z", 1, 100);
        let b = WordTokenizer::build("z y x z y z", 1, 100);
        assert_eq!(a.encode("x y z"), b.encode("x y z"));
    }

    #[test]
    fn byte_tokenizer_roundtrip() {
        let t = ByteTokenizer;
        let ids = t.encode("hello µ");
        assert_eq!(t.decode(&ids), "hello µ");
        assert_eq!(t.vocab_size(), 260);
    }
}
