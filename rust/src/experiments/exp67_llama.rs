//! Experiments 6/7/7b (paper §3.2-3.3 + §9, Tables 3/4/5/16/17, Figs 1/2):
//! LLaMA-style architecture — d_select sweep, full-vs-thin from-scratch
//! training trajectories at two token budgets, downstream probe parity,
//! and the GQA/MLA comparison trained from scratch.

use anyhow::Result;

use crate::bench::Table;
use crate::datagen::probes;
use crate::experiments::common::{self, Opts, LARGE_CORPUS};
use crate::runtime::Runtime;
use crate::substrate::mathutil::{mean, std_dev};
use crate::train::{eval, Schedule, Trainer, TrainState};

/// Table 16: LLaMA-arch d_select sweep (same protocol as exp34 large).
pub fn table16(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let corpus = common::corpus_for(rt, "llama_ds64", LARGE_CORPUS);
    let steps = opts.steps(260);
    let mut rows = Vec::new();
    for ds in [8usize, 16, 32, 64] {
        let cfg_name = format!("llama_ds{ds}");
        let pre = common::pretrain_lm(rt, &cfg_name, &corpus, "lmlarge",
                                      steps, opts.seeds[0])?;
        let ppl = common::val_ppl(rt, &cfg_name, &pre.params, &corpus)?;
        let cfg = rt.manifest().config(&cfg_name)?;
        rows.push((ds, cfg.n_parameters(), ppl));
    }
    let base = rows.last().unwrap().2;
    let mut t = Table::new(
        "Table 16 — LLaMA-style architecture, d_select sweep (from scratch)",
        &["d_select", "per head", "params", "val PPL", "dPPL", "QK saved"],
    );
    for (ds, params, ppl) in rows {
        t.row(&[
            ds.to_string(),
            (ds / 4).to_string(),
            format!("{:.2}M", params as f64 / 1e6),
            common::fmt(ppl, 2),
            common::fmt_pct(100.0 * (ppl - base) / base),
            format!("{:.0}%", 100.0 * (1.0 - ds as f64 / 64.0)),
        ]);
    }
    Ok(t)
}

/// Table 17: MHA vs thin keys vs GQA vs MLA, all from scratch.
pub fn table17(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let corpus = common::corpus_for(rt, "llama_ds64", LARGE_CORPUS);
    let steps = opts.steps(260);
    let variants: &[(&str, &str)] = &[
        ("llama_ds64", "MHA (baseline)"),
        ("llama_ds32", "Thin keys d_select=d/2"),
        ("llama_ds16", "Thin keys d_select=d/4"),
        ("llama_gqa2", "GQA 2 kv heads"),
        ("llama_gqa1", "GQA 1 kv head (MQA)"),
        ("llama_mla56", "MLA d_c=56"),
        ("llama_mla36", "MLA d_c=36"),
    ];
    let mut rows = Vec::new();
    for (cfg_name, label) in variants {
        let pre = common::pretrain_lm(rt, cfg_name, &corpus, "lmlarge",
                                      steps, opts.seeds[0])?;
        let ppl = common::val_ppl(rt, cfg_name, &pre.params, &corpus)?;
        let cfg = rt.manifest().config(cfg_name)?;
        rows.push((label.to_string(), cfg.n_parameters(), cfg.kv_budget, ppl));
    }
    let (base_budget, base_ppl) = (rows[0].2, rows[0].3);
    let mut t = Table::new(
        "Table 17 — KV compression methods trained from scratch (LLaMA arch)",
        &["method", "params", "KV budget", "KV saved", "val PPL", "dPPL"],
    );
    for (label, params, budget, ppl) in rows {
        t.row(&[
            label,
            format!("{:.2}M", params as f64 / 1e6),
            budget.to_string(),
            format!("{:.1}%", 100.0 * (1.0 - budget as f64 / base_budget as f64)),
            common::fmt(ppl, 2),
            common::fmt_pct(100.0 * (ppl - base_ppl) / base_ppl),
        ]);
    }
    Ok(t)
}

pub struct Trajectory {
    pub cfg: String,
    pub seed: u64,
    pub checkpoints: Vec<(usize, f64)>, // (step, val ppl)
    pub seconds: f64,
    pub params: usize,
}

/// Train with periodic validation snapshots (Figures 1/2).
pub fn trajectory(rt: &Runtime, cfg_name: &str, steps: usize, every: usize,
                  seed: u64) -> Result<Trajectory> {
    let corpus = common::corpus_for(rt, cfg_name, LARGE_CORPUS);
    let trainer = Trainer::new(rt, cfg_name, false)?;
    let cfg = trainer.cfg.clone();
    let mut st = TrainState::new(&cfg, seed);
    let sched = Schedule::warmup_cosine(3e-3, steps / 10, steps);
    let batches =
        corpus.batches(&corpus.train, cfg.train_batch, cfg.train_seq, seed);
    let mut checkpoints = Vec::new();
    let mut done = 0usize;
    let mut train_secs = 0.0;
    while done < steps {
        let chunk = every.min(steps - done);
        let out = trainer.run(&mut st, chunk, &sched, |i| {
            batches[(done + i) % batches.len()].clone()
        })?;
        train_secs += out.seconds;
        done += chunk;
        let ppl = common::val_ppl(rt, cfg_name, &st.params, &corpus)?;
        checkpoints.push((done, ppl));
    }
    // persist final weights for the probe evaluation
    st.params
        .save(&crate::artifacts_dir().join("ckpt")
              .join(format!("{cfg_name}_traj{steps}_s{seed}.tkw")))?;
    Ok(Trajectory {
        cfg: cfg_name.to_string(),
        seed,
        checkpoints,
        seconds: train_secs,
        params: cfg.n_parameters(),
    })
}

/// Tables 3/4 + Figures 1/2: full vs thin at two token budgets, 2 seeds.
pub fn tables_3_4_figs(rt: &Runtime, opts: &Opts) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for (label, base_steps) in
        [("Table 3 + Fig 1 (short budget, tokens:params ~ 0.3)", 160usize),
         ("Table 4 + Fig 2 (long budget, tokens:params ~ 1.9)", 640usize)]
    {
        let steps = opts.steps(base_steps);
        let every = (steps / 8).max(1);
        let mut results: Vec<(String, Vec<Trajectory>)> = Vec::new();
        for cfg_name in ["llama_ds64", "llama_ds16"] {
            let mut trajs = Vec::new();
            for &seed in &opts.seeds {
                trajs.push(trajectory(rt, cfg_name, steps, every, seed)?);
            }
            results.push((cfg_name.to_string(), trajs));
        }
        // summary table
        let mut t = Table::new(label,
            &["model", "params", "final PPL (mean±std)", "wall-clock (s)"]);
        for (name, trajs) in &results {
            let finals: Vec<f64> =
                trajs.iter().map(|tr| tr.checkpoints.last().unwrap().1).collect();
            let secs: Vec<f64> = trajs.iter().map(|tr| tr.seconds).collect();
            t.row(&[
                name.clone(),
                format!("{:.2}M", trajs[0].params as f64 / 1e6),
                format!("{:.2} ± {:.2}", mean(&finals), std_dev(&finals)),
                format!("{:.1}", mean(&secs)),
            ]);
        }
        tables.push(t);
        // trajectory table (the Figure as a series)
        let mut f = Table::new(
            &format!("{label} — PPL trajectory (seed {})", opts.seeds[0]),
            &["step", "full", "thin d/4"],
        );
        let full = &results[0].1[0];
        let thin = &results[1].1[0];
        for (i, &(step, ppl)) in full.checkpoints.iter().enumerate() {
            f.row(&[
                step.to_string(),
                common::fmt(ppl, 2),
                common::fmt(thin.checkpoints[i].1, 2),
            ]);
        }
        tables.push(f);
    }
    Ok(tables)
}

/// Table 5: downstream probe parity of the long-budget from-scratch models.
pub fn table5(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let steps = opts.steps(640);
    let seed = opts.seeds[0];
    let model = common::corpus_model(rt, "llama_ds64");
    let mut t = Table::new(
        "Table 5 — downstream probes, from-scratch full vs thin (d/4)",
        &["probe", "full", "thin", "delta"],
    );
    let load = |cfg_name: &str| -> Result<crate::runtime::ParamStore> {
        let p = crate::artifacts_dir().join("ckpt")
            .join(format!("{cfg_name}_traj{steps}_s{seed}.tkw"));
        crate::runtime::ParamStore::load(&p)
    };
    let full = load("llama_ds64")?;
    let thin = load("llama_ds16")?;
    let full_cfg = rt.manifest().config("llama_ds64")?.clone();
    let thin_cfg = rt.manifest().config("llama_ds16")?.clone();
    let n_items = (100.0 * opts.scale).max(20.0) as usize;
    for (name, items) in probes::standard_suite(&model, n_items, 1234) {
        let a = eval::probe_accuracy(rt, &full_cfg, &full, &items)?;
        let b = eval::probe_accuracy(rt, &thin_cfg, &thin, &items)?;
        t.row(&[
            name.to_string(),
            format!("{:.1}", 100.0 * a),
            format!("{:.1}", 100.0 * b),
            format!("{:+.1}", 100.0 * (b - a)),
        ]);
    }
    Ok(t)
}
