//! Tables 6 and 10 + the §12 prefill roofline — pure analytical
//! reproductions (these match the paper's numbers exactly; see the unit
//! tests in coordinator::roofline that pin them).

use crate::bench::Table;
use crate::coordinator::roofline;

pub fn table6() -> Table {
    let mut t = Table::new(
        "Table 6 — analytical KV cache @ LLaMA-7B, 128K ctx, bf16 (GiB)",
        &["method", "K cache", "V cache", "KV total", "KV saved"],
    );
    for (label, k, v, total, saved) in roofline::table6_rows() {
        t.row(&[
            label.to_string(),
            format!("{k:.1}"),
            format!("{v:.1}"),
            format!("{total:.1}"),
            format!("{saved:.1}%"),
        ]);
    }
    t
}

pub fn table10() -> Table {
    let mut t = Table::new(
        "Table 10 — KV cache per user (d_model 4096, 32 layers, fp16, GB)",
        &["config", "K cache", "V cache", "total", "saved GB", "saved %"],
    );
    for (label, k, v, total, saved_gb, saved_pct) in roofline::table10_rows() {
        t.row(&[
            label,
            format!("{k:.1}"),
            format!("{v:.1}"),
            format!("{total:.1}"),
            format!("{saved_gb:.1}"),
            format!("{saved_pct:.1}%"),
        ]);
    }
    t
}

pub fn quantized_composition() -> Table {
    let mut t = Table::new(
        "§6 composition — key-cache bytes/token @ 7B geometry (d 4096, \
         32 layers): rank x GQA x int8 (per-row fp32 scales included)",
        &["stack", "K bytes/token", "vs fp32 MHA"],
    );
    for (label, bytes, x) in roofline::quantized_composition_rows() {
        t.row(&[
            label.to_string(),
            format!("{bytes:.0}"),
            format!("{x:.2}x"),
        ]);
    }
    t
}

pub fn prefill_roofline() -> Table {
    let mut t = Table::new(
        "§12 — prefill arithmetic intensity (FLOP/byte of KV), H100 ridge ~295",
        &["context", "intensity", "regime", "QK^T FLOP ratio full/thin(d/4)"],
    );
    for s in [512usize, 4096, 131072] {
        let i = roofline::prefill_intensity(s, 32, 128, 128, 2.0);
        let full = roofline::prefill_attention_flops(s, 32, 128, 0);
        let thin = roofline::prefill_attention_flops(s, 32, 32, 0);
        t.row(&[
            s.to_string(),
            format!("{i:.0}"),
            if i > 295.0 { "compute-bound".into() } else { "bandwidth-bound".to_string() },
            format!("{:.1}x", full / thin),
        ]);
    }
    t
}

pub fn run() -> Vec<Table> {
    vec![table6(), table10(), quantized_composition(), prefill_roofline()]
}
