//! Experiment 5 (paper §3.1, Tables 1/2): post-training SVD compression of
//! the pretrained tinylm (the GPT-2 stand-in).
//!
//! Table 1: rank sweep × {both, K-only, Q-only}. Expected shape: K-only is
//! far more forgiving than Q-only (the paper's 7x asymmetry at mid rank),
//! and compressing both compounds catastrophically.
//!
//! Table 2: K-only SVD at rank r + QK-only fine-tuning recovers to within
//! low single digits of an identically fine-tuned uncompressed control.

use anyhow::Result;

use crate::bench::Table;
use crate::experiments::common::{self, Opts, LARGE_CORPUS};
use crate::model::surgery::{self, AblationMode};
use crate::runtime::{ParamStore, Runtime};

pub const PRETRAIN_STEPS: usize = 360;

/// Pretrain (or load) the deployed base model for Exp 5/8 experiments.
pub fn base_model(rt: &Runtime, opts: &Opts)
    -> Result<(ParamStore, crate::datagen::corpus::Corpus)> {
    let corpus = common::corpus_for(rt, "tinylm_ds64", LARGE_CORPUS);
    let pre = common::pretrain_lm(rt, "tinylm_ds64", &corpus, "base",
                                  opts.steps(PRETRAIN_STEPS), opts.seeds[0])?;
    Ok((pre.params, corpus))
}

pub fn table1(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let (params, corpus) = base_model(rt, opts)?;
    let cfg = rt.manifest().config("tinylm_ds64")?.clone();
    let baseline = common::val_ppl(rt, "tinylm_ds64", &params, &corpus)?;
    let mut t = Table::new(
        &format!(
            "Table 1 — SVD compression of pretrained tinylm \
             (baseline PPL {:.2}); d_qk_head = {}",
            baseline, cfg.d_qk_head
        ),
        &["rank/head", "Both Q+K", "K-only", "Q-only"],
    );
    for r in [1usize, 2, 4, 6] {
        let mut cells = vec![r.to_string()];
        for mode in
            [AblationMode::Both, AblationMode::KOnly, AblationMode::QOnly]
        {
            let ab = surgery::low_rank_ablation(&params, &cfg, r, mode)?;
            let ppl = common::val_ppl(rt, "tinylm_ds64", &ab, &corpus)?;
            cells.push(format!(
                "{:.2} ({})",
                ppl,
                common::fmt_pct(100.0 * (ppl - baseline) / baseline)
            ));
        }
        t.row(&cells);
    }
    Ok(t)
}

pub fn table2(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let (params, corpus) = base_model(rt, opts)?;
    let full_cfg = rt.manifest().config("tinylm_ds64")?.clone();
    let ft_steps = opts.steps(140);
    let (b, s) = (full_cfg.train_batch, full_cfg.train_seq);
    let batches = corpus.batches(&corpus.train, b, s, 99);

    // identically fine-tuned uncompressed control
    let control = common::qk_finetune(rt, "tinylm_ds64", params.clone(),
                                      ft_steps,
                                      |i| batches[i % batches.len()].clone())?;
    let control_ppl = common::val_ppl(rt, "tinylm_ds64", &control, &corpus)?;

    let mut t = Table::new(
        &format!(
            "Table 2 — K-only SVD + QK fine-tuning (control after FT: {:.2})",
            control_ppl
        ),
        &["rank", "before FT", "after FT", "vs control", "K cache saved"],
    );
    for ds in [32usize, 16, 8] {
        let thin_name = format!("tinylm_ds{ds}");
        let thin_cfg = rt.manifest().config(&thin_name)?.clone();
        let thin = surgery::factor_to_thin(&params, &full_cfg, &thin_cfg)?;
        let before = common::val_ppl(rt, &thin_name, &thin, &corpus)?;
        let tuned = common::qk_finetune(rt, &thin_name, thin, ft_steps,
                                        |i| batches[i % batches.len()].clone())?;
        let after = common::val_ppl(rt, &thin_name, &tuned, &corpus)?;
        t.row(&[
            format!("{} (d_K/{})", ds, 64 / ds),
            common::fmt(before, 2),
            common::fmt(after, 2),
            common::fmt_pct(100.0 * (after - control_ppl) / control_ppl),
            format!("{:.0}%", 100.0 * (1.0 - ds as f64 / 64.0)),
        ]);
    }
    Ok(t)
}

pub fn run(rt: &Runtime, opts: &Opts) -> Result<Vec<Table>> {
    Ok(vec![table1(rt, opts)?, table2(rt, opts)?])
}
