//! Shared experiment infrastructure: step budgets, corpora, checkpoint
//! caching, and the pretrain/fine-tune protocols every experiment reuses.

use std::path::PathBuf;

use anyhow::Result;

use crate::datagen::corpus::{Corpus, CorpusModel};
use crate::datagen::Batch;
use crate::runtime::{ParamStore, Runtime};
use crate::train::{eval, Schedule, Trainer, TrainState};

/// The corpus seed shared by all LM experiments (one "language").
pub const CORPUS_SEED: u64 = 7;
/// Overfit-regime corpus (WikiText-2 stand-in): ~23 windows/epoch.
pub const SMALL_CORPUS: usize = 12_000;
/// Underfit-regime corpus (WikiText-103 stand-in): > 1 epoch never seen.
pub const LARGE_CORPUS: usize = 400_000;

#[derive(Clone, Debug)]
pub struct Opts {
    /// Multiplier on every step budget (benches use ~0.1).
    pub scale: f64,
    pub seeds: Vec<u64>,
}

impl Default for Opts {
    fn default() -> Self {
        Opts { scale: 1.0, seeds: vec![137, 138] }
    }
}

impl Opts {
    pub fn quick() -> Self {
        Opts { scale: 0.05, seeds: vec![137] }
    }

    pub fn steps(&self, base: usize) -> usize {
        ((base as f64 * self.scale) as usize).max(8)
    }
}

pub fn corpus_for(rt: &Runtime, cfg_name: &str, n_train: usize) -> Corpus {
    let vocab = rt.manifest().config(cfg_name).unwrap().vocab;
    let model = CorpusModel::new(CORPUS_SEED, vocab);
    Corpus::generate(&model, n_train, 1)
}

pub fn corpus_model(rt: &Runtime, cfg_name: &str) -> CorpusModel {
    let vocab = rt.manifest().config(cfg_name).unwrap().vocab;
    CorpusModel::new(CORPUS_SEED, vocab)
}

fn ckpt_path(tag: &str) -> PathBuf {
    crate::artifacts_dir().join("ckpt").join(format!("{tag}.tkw"))
}

/// Result of a (possibly cached) pretraining run.
pub struct Pretrained {
    pub params: ParamStore,
    pub seconds: f64,
    pub final_loss: f64,
    pub cached: bool,
}

/// Standard LM pretraining protocol: cosine schedule, warmup 10%,
/// lr 3e-3. Checkpoints cache on (cfg, steps, corpus size, seed).
pub fn pretrain_lm(rt: &Runtime, cfg_name: &str, corpus: &Corpus,
                   corpus_tag: &str, steps: usize, seed: u64)
    -> Result<Pretrained> {
    let tag = format!("{cfg_name}_{corpus_tag}_st{steps}_s{seed}");
    let path = ckpt_path(&tag);
    let cfg = rt.manifest().config(cfg_name)?.clone();
    if path.exists() {
        let params = ParamStore::load(&path)?;
        if params.check_matches(&cfg).is_ok() {
            return Ok(Pretrained {
                params,
                seconds: 0.0,
                final_loss: f64::NAN,
                cached: true,
            });
        }
    }
    let trainer = Trainer::new(rt, cfg_name, false)?;
    let mut st = TrainState::new(&cfg, seed);
    let sched = Schedule::warmup_cosine(3e-3, steps / 10, steps);
    let batches =
        corpus.batches(&corpus.train, cfg.train_batch, cfg.train_seq, seed);
    let out = trainer.run(&mut st, steps, &sched, |i| {
        batches[i % batches.len()].clone()
    })?;
    st.params.save(&path)?;
    Ok(Pretrained {
        params: st.params,
        seconds: out.seconds,
        final_loss: out.final_loss(),
        cached: false,
    })
}

/// QK-only fine-tuning protocol (the paper's 3-epoch recovery), over
/// arbitrary batch sources.
pub fn qk_finetune<F>(rt: &Runtime, cfg_name: &str, params: ParamStore,
                      steps: usize, mut next_batch: F) -> Result<ParamStore>
where
    F: FnMut(usize) -> Batch,
{
    let trainer = Trainer::new(rt, cfg_name, true)?;
    let mut st = TrainState::from_params(params);
    let sched = Schedule::Constant { lr: 1e-3 };
    trainer.run(&mut st, steps, &sched, |i| next_batch(i))?;
    Ok(st.params)
}

/// Validation PPL with the standard eval slice (up to 8 batches).
pub fn val_ppl(rt: &Runtime, cfg_name: &str, params: &ParamStore,
               corpus: &Corpus) -> Result<f64> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let batches =
        corpus.batches(&corpus.val, cfg.train_batch, cfg.train_seq, 0);
    let n = batches.len().min(8);
    eval::eval_ppl(rt, &cfg, params, &batches[..n])
}

pub fn fmt(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:+.1}%", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opts_scaling() {
        let o = Opts::quick();
        assert!(o.steps(240) >= 8 && o.steps(240) < 240);
        assert_eq!(Opts::default().steps(240), 240);
    }
}
