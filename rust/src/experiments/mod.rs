//! Experiment reproductions — one module per paper table/figure family
//! (the per-experiment index lives in DESIGN.md §6).
//!
//! Every experiment is a pure function `run(&Runtime, &Opts) -> Vec<Table>`
//! that trains/evaluates at the scaled-down geometry and prints the same
//! rows the paper reports. Benches call these with `Opts::quick()`; the
//! full protocol (recorded in EXPERIMENTS.md) uses `Opts::default()`.
//! Pretrained checkpoints are cached under `artifacts/ckpt/` keyed by
//! (config, protocol hash) so repeated invocations don't retrain.

pub mod common;
pub mod exp1_copyback;
pub mod exp2_kvret;
pub mod exp34_lm_sweep;
pub mod exp5_svd;
pub mod exp67_llama;
pub mod exp8_gqa;
pub mod exp19_domain_ft;
pub mod serving;
pub mod analytical;

pub use common::Opts;
