//! Experiments 3/4 (paper §8.3-8.4, Tables 14/15): d_select sweeps on the
//! synthetic corpus in two regimes.
//!
//! - **small corpus** (overfit, WikiText-2-like): reducing QK capacity acts
//!   as a regularizer — thin keys look costless or better.
//! - **large corpus** (underfit, WikiText-103-like): the true, smooth,
//!   monotone cost of d_select appears.

use anyhow::Result;

use crate::bench::Table;
use crate::experiments::common::{self, Opts, LARGE_CORPUS, SMALL_CORPUS};
use crate::runtime::Runtime;

pub struct SweepRow {
    pub d_select: usize,
    pub val_ppl: f64,
    pub train_loss: f64,
    pub qk_saved_pct: f64,
}

pub fn sweep(rt: &Runtime, regime: &str, steps: usize, seed: u64)
    -> Result<Vec<SweepRow>> {
    let n_train = if regime == "small" { SMALL_CORPUS } else { LARGE_CORPUS };
    let corpus = common::corpus_for(rt, "tinylm_ds64", n_train);
    let full_qk =
        rt.manifest().config("tinylm_ds64")?.qk_parameters() as f64;
    let mut rows = Vec::new();
    for ds in [8usize, 16, 32, 64] {
        let cfg_name = format!("tinylm_ds{ds}");
        let pre = common::pretrain_lm(rt, &cfg_name, &corpus,
                                      &format!("lm{regime}"), steps, seed)?;
        let ppl = common::val_ppl(rt, &cfg_name, &pre.params, &corpus)?;
        let qk = rt.manifest().config(&cfg_name)?.qk_parameters() as f64;
        rows.push(SweepRow {
            d_select: ds,
            val_ppl: ppl,
            train_loss: pre.final_loss,
            qk_saved_pct: 100.0 * (1.0 - qk / full_qk),
        });
    }
    Ok(rows)
}

pub fn run(rt: &Runtime, opts: &Opts) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for (regime, title, steps) in [
        ("small", "Table 14 — d_select sweep, SMALL corpus (overfit regime)",
         opts.steps(260)),
        ("large", "Table 15 — d_select sweep, LARGE corpus (underfit regime)",
         opts.steps(260)),
    ] {
        let rows = sweep(rt, regime, steps, opts.seeds[0])?;
        let base = rows.last().unwrap().val_ppl; // ds=64 = full attention
        let mut t = Table::new(title,
            &["d_select", "per head", "val PPL", "dPPL", "QK saved"]);
        for r in &rows {
            t.row(&[
                r.d_select.to_string(),
                (r.d_select / 8).to_string(),
                common::fmt(r.val_ppl, 2),
                common::fmt_pct(100.0 * (r.val_ppl - base) / base),
                format!("{:.0}%", r.qk_saved_pct),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}
