//! Table 19 (paper §11): GSM-mini fine-tuning progression — domain-matched
//! fine-tuning closes the compression gap that out-of-domain data cannot.
//!
//! Grid: {no FT, out-of-domain corpus FT, mixed FT, in-domain CoT FT}
//! × {identically-FT control, factored r/2, factored r/4}, scored by
//! exact-match on held-out gsm-mini problems via greedy generation.

use anyhow::Result;

use crate::bench::Table;
use crate::datagen::{gsm_mini, Batch};
use crate::experiments::common::{self, Opts};
use crate::experiments::exp8_gqa;
use crate::model::surgery;
use crate::runtime::{ParamStore, Runtime};
use crate::substrate::rng::Rng;
use crate::train::eval::{self, greedy_generate};

/// Exact-match accuracy by greedy decoding after the `<A>` marker.
pub fn gsm_exact_match(rt: &Runtime, cfg_name: &str, params: &ParamStore,
                       n_problems: usize, seed: u64) -> Result<f64> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let mut rng = Rng::new(seed);
    let problems: Vec<gsm_mini::Problem> =
        (0..n_problems).map(|_| gsm_mini::Problem::sample(&mut rng)).collect();
    let prompts: Vec<Vec<i32>> =
        problems.iter().map(gsm_mini::encode_prompt).collect();
    let outs = greedy_generate(rt, &cfg, params, &prompts, 12,
                               gsm_mini::T_END)?;
    let mut correct = 0usize;
    for (p, gen) in problems.iter().zip(&outs) {
        if gsm_mini::parse_answer(gen) == Some(p.answer()) {
            correct += 1;
        }
    }
    Ok(correct as f64 / n_problems as f64)
}

fn ft_batches(kind: &str, corpus: &crate::datagen::corpus::Corpus,
              b: usize, s: usize, n: usize, seed: u64) -> Vec<Batch> {
    let mut rng = Rng::new(seed);
    let ood = corpus.batches(&corpus.train, b, s, seed);
    (0..n)
        .map(|i| match kind {
            "ood" => ood[i % ood.len()].clone(),
            "gsm" => gsm_mini::batch(b, s, &mut rng),
            // alternate sources (the paper's "C4 + Math" mix)
            _ => {
                if i % 2 == 0 {
                    ood[i % ood.len()].clone()
                } else {
                    gsm_mini::batch(b, s, &mut rng)
                }
            }
        })
        .collect()
}

/// Pretrain the exp19 base with gsm sequences mixed into the corpus (the
/// Mistral analog: web pretraining contains *some* math, so the model has
/// non-degenerate digit/operator embeddings before QK-only fine-tuning).
fn mixed_base(rt: &Runtime, opts: &Opts)
    -> Result<(ParamStore, crate::datagen::corpus::Corpus)> {
    use crate::train::{Schedule, Trainer, TrainState};
    let corpus = common::corpus_for(rt, "tinygqa_ds64",
                                    crate::experiments::common::LARGE_CORPUS);
    let steps = opts.steps(exp8_gqa::PRETRAIN_STEPS);
    let tag = crate::artifacts_dir().join("ckpt")
        .join(format!("tinygqa_ds64_gsmmix_st{steps}_s{}.tkw", opts.seeds[0]));
    let cfg = rt.manifest().config("tinygqa_ds64")?.clone();
    if tag.exists() {
        if let Ok(p) = ParamStore::load(&tag) {
            if p.check_matches(&cfg).is_ok() {
                return Ok((p, corpus));
            }
        }
    }
    let trainer = Trainer::new(rt, "tinygqa_ds64", false)?;
    let mut st = TrainState::new(&cfg, opts.seeds[0]);
    let sched = Schedule::warmup_cosine(3e-3, steps / 10, steps);
    let (b, s) = (cfg.train_batch, cfg.train_seq);
    let corpus_batches = corpus.batches(&corpus.train, b, s, 11);
    let mut rng = Rng::new(4040);
    trainer.run(&mut st, steps, &sched, |i| {
        if i % 4 == 3 {
            gsm_mini::batch(b, s, &mut rng)
        } else {
            corpus_batches[i % corpus_batches.len()].clone()
        }
    })?;
    st.params.save(&tag)?;
    Ok((st.params, corpus))
}

pub fn run(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let (base, corpus) = mixed_base(rt, opts)?;
    let full_cfg = rt.manifest().config("tinygqa_ds64")?.clone();
    let (b, s) = (full_cfg.train_batch, full_cfg.train_seq);
    let ft_steps = opts.steps(160);
    let n_eval = (64.0 * opts.scale).max(16.0) as usize;

    // factored variants (fresh from the base each time)
    let variants: Vec<(&str, String)> = vec![
        ("control", "tinygqa_ds64".to_string()),
        ("r/2", "tinygqa_ds32".to_string()),
        ("r/4", "tinygqa_ds16".to_string()),
    ];

    // Metric note (DESIGN.md §2): generation exact-match (implemented
    // above in gsm_exact_match) floors at 0 for a 0.2M-param model; the
    // scale-appropriate metric is teacher-forced answer-token accuracy on
    // held-out problems, which exposes the same FT-data gradient.
    let mut eval_rng = Rng::new(9090);
    let eval_batches: Vec<Batch> = (0..4)
        .map(|_| gsm_mini::batch(b, s, &mut eval_rng))
        .collect();
    let mut t = Table::new(
        "Table 19 — gsm-mini answer-token accuracy across FT data regimes",
        &["FT data", "control", "r/2", "r/4", "d(r/2)", "d(r/4)"],
    );
    for (ft_label, kind) in [
        ("None (baseline)", "none"),
        ("OOD corpus", "ood"),
        ("Mixed corpus+math", "mix"),
        ("In-domain gsm CoT", "gsm"),
    ] {
        let mut accs = Vec::new();
        for (_, cfg_name) in &variants {
            let thin_cfg = rt.manifest().config(cfg_name)?.clone();
            let start = if cfg_name == "tinygqa_ds64" {
                base.clone()
            } else {
                surgery::factor_to_thin(&base, &full_cfg, &thin_cfg)?
            };
            let tuned = if kind == "none" {
                start
            } else {
                let batches = ft_batches(kind, &corpus, b, s, ft_steps, 77);
                common::qk_finetune(rt, cfg_name, start, ft_steps,
                                    |i| batches[i % batches.len()].clone())?
            };
            let thin_cfg2 = rt.manifest().config(cfg_name)?.clone();
            let _ = n_eval;
            accs.push(100.0
                * eval::eval_accuracy(rt, &thin_cfg2, &tuned,
                                      &eval_batches)?);
        }
        t.row(&[
            ft_label.to_string(),
            format!("{:.1}", accs[0]),
            format!("{:.1}", accs[1]),
            format!("{:.1}", accs[2]),
            format!("{:+.1}", accs[1] - accs[0]),
            format!("{:+.1}", accs[2] - accs[0]),
        ]);
    }
    Ok(t)
}
