//! Experiment 2 (paper §8.2, Table 13): content-based selection via
//! key-value retrieval. Expectation: a sharp transition — 1 dim/head
//! cannot separate keys by dot product (chance-ish accuracy), ≥2 dims/head
//! reach (near-)perfect accuracy.

use anyhow::Result;

use crate::bench::Table;
use crate::datagen::kvretrieval;
use crate::experiments::common::Opts;
use crate::runtime::Runtime;
use crate::substrate::rng::Rng;
use crate::train::{eval, Schedule, Trainer, TrainState};

pub fn run(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let steps = opts.steps(1600);
    let eval_every = (steps / 8).max(1);
    let mut table = Table::new(
        "Table 13 — key-value retrieval (content selection) by d_select",
        &["d_select", "per head", "best acc", "converge step"],
    );
    for ds in [4usize, 8, 16, 32, 64] {
        let cfg_name = format!("kvret_ds{ds}");
        let trainer = Trainer::new(rt, &cfg_name, false)?;
        let cfg = trainer.cfg.clone();
        let mut st = TrainState::new(&cfg, opts.seeds[0]);
        let mut rng = Rng::new(opts.seeds[0] ^ 0x2222);
        let sched = Schedule::warmup_cosine(2e-3, steps / 20, steps);
        let mut eval_rng = Rng::new(54321);
        let eval_batches: Vec<_> = (0..3)
            .map(|_| kvretrieval::batch(cfg.train_batch, cfg.train_seq,
                                        &mut eval_rng))
            .collect();
        let mut best = 0.0f64;
        let mut converge = None;
        let mut done = 0usize;
        while done < steps {
            let chunk = eval_every.min(steps - done);
            trainer.run(&mut st, chunk, &sched, |_| {
                kvretrieval::batch(cfg.train_batch, cfg.train_seq, &mut rng)
            })?;
            done += chunk;
            let acc =
                eval::eval_accuracy(rt, &cfg, &st.params, &eval_batches)?;
            if acc > best {
                best = acc;
            }
            if acc >= 0.999 && converge.is_none() {
                converge = Some(done);
                break;
            }
        }
        table.row(&[
            ds.to_string(),
            (ds / 4).to_string(),
            format!("{:.1}%", 100.0 * best),
            converge.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
        ]);
    }
    Ok(table)
}
