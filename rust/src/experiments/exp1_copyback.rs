//! Experiment 1 (paper §8.1, Table 12): positional selection via the
//! copy-back task. Expectation: every d_select — down to 1 dim/head —
//! reaches (near-)perfect accuracy; smaller d_select converges later.

use anyhow::Result;

use crate::bench::Table;
use crate::datagen::copyback;
use crate::experiments::common::Opts;
use crate::runtime::Runtime;
use crate::substrate::rng::Rng;
use crate::train::{eval, Schedule, Trainer, TrainState};

pub struct TaskRow {
    pub d_select: usize,
    pub best_acc: f64,
    pub converge_step: Option<usize>,
}

pub fn run_config(rt: &Runtime, cfg_name: &str, steps: usize, eval_every: usize,
                  lr: f64, seed: u64) -> Result<TaskRow> {
    let trainer = Trainer::new(rt, cfg_name, false)?;
    let cfg = trainer.cfg.clone();
    let mut st = TrainState::new(&cfg, seed);
    let mut rng = Rng::new(seed ^ 0x9999);
    let sched = Schedule::warmup_cosine(lr, steps / 20, steps);
    let mut eval_rng = Rng::new(12345);
    let eval_batches: Vec<_> = (0..3)
        .map(|_| copyback::batch(cfg.train_batch, cfg.train_seq, &mut eval_rng))
        .collect();
    let mut best = 0.0f64;
    let mut converge = None;
    let mut done = 0usize;
    while done < steps {
        let chunk = eval_every.min(steps - done);
        trainer.run(&mut st, chunk, &sched, |_| {
            copyback::batch(cfg.train_batch, cfg.train_seq, &mut rng)
        })?;
        done += chunk;
        let acc = eval::eval_accuracy(rt, &cfg, &st.params, &eval_batches)?;
        if acc > best {
            best = acc;
        }
        if acc >= 0.999 && converge.is_none() {
            converge = Some(done);
            break; // early stop at convergence
        }
    }
    Ok(TaskRow { d_select: cfg.d_select, best_acc: best, converge_step: converge })
}

pub fn run(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let steps = opts.steps(900);
    let mut table = Table::new(
        "Table 12 — copy-back (positional selection) by d_select",
        &["d_select", "per head", "best acc", "converge step"],
    );
    for ds in [4usize, 8, 16, 32, 64] {
        let row = run_config(rt, &format!("copyback_ds{ds}"), steps,
                             steps / 6, 2e-3, opts.seeds[0])?;
        table.row(&[
            ds.to_string(),
            (ds / 4).to_string(),
            format!("{:.1}%", 100.0 * row.best_acc),
            row.converge_step
                .map(|s| s.to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    Ok(table)
}
