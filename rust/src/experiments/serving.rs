//! Table 11 (paper §4.2) + the headline capacity claim, measured on this
//! stack: decode throughput at batch 1..32 for the full vs factored
//! serving configs, alongside the paper's Eq. 10 prediction evaluated both
//! at the paper's Mistral-7B constants (exact reproduction) and at our own
//! measured byte counts.

use anyhow::Result;

use crate::bench::Table;
use crate::coordinator::engine::Engine;
use crate::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use crate::coordinator::metrics::ServeReport;
use crate::coordinator::roofline::{self, eq10_speedup, GB};
use crate::coordinator::router::{collect_into, synth_prompt, Router};
use crate::coordinator::sampling::Sampler;
use crate::coordinator::scheduler::{SchedConfig, Scheduler};
use crate::coordinator::sequence::{Priority, Sequence};
use crate::datagen::arrival::{mixed_chat_doc_trace, RequestSpec};
use crate::experiments::common::Opts;
use crate::runtime::{KvQuant, ParamStore, Runtime};
use crate::substrate::rng::Rng;

/// Steady-state decode throughput (tokens/s) at a fixed batch size and
/// prompt length. `pin_tier` forces a fixed arena tier (`Some(max_seq)`
/// reproduces the pre-tiering engine — the benchmark baseline); `None`
/// auto-selects the smallest covering tier.
pub fn decode_throughput_opts(rt: &Runtime, cfg_name: &str, batch: usize,
                              steps: usize, pallas: bool, prompt_len: usize,
                              pin_tier: Option<usize>) -> Result<f64> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let mut eng = Engine::new(rt, cfg_name, params, pallas,
                              Sampler::Greedy, 0)?;
    eng.pin_tier = pin_tier;
    let mut rng = Rng::new(1);
    let mut seqs: Vec<Sequence> = (0..batch)
        .map(|i| {
            Sequence::new(i as u64 + 1,
                          synth_prompt(prompt_len, cfg.vocab, &mut rng),
                          steps + 8, None)
        })
        .collect();
    for s in seqs.iter_mut() {
        eng.prefill(s)?;
    }
    // warmup (compile + first regroup)
    for _ in 0..3 {
        let mut refs: Vec<&mut Sequence> = seqs.iter_mut().collect();
        eng.decode_step(&mut refs)?;
    }
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let mut refs: Vec<&mut Sequence> = seqs.iter_mut().collect();
        eng.decode_step(&mut refs)?;
    }
    let secs = t0.elapsed().as_secs_f64();
    Ok((batch * steps) as f64 / secs)
}

/// Steady-state decode throughput (tokens/s) at a fixed batch size.
pub fn decode_throughput(rt: &Runtime, cfg_name: &str, batch: usize,
                         steps: usize, pallas: bool) -> Result<f64> {
    decode_throughput_opts(rt, cfg_name, batch, steps, pallas, 32, None)
}

/// Before/after the context-tiered arena grid, at short contexts: the
/// pre-tiering engine sizes every decode arena at `max_seq` (pinned
/// tier), the tiered engine at the smallest tier covering the live
/// context. This is where Eq. 10's bytes-per-step argument bites — the
/// `servethin` config only shows its bandwidth win once the coordinator
/// stops moving max_seq-sized arenas.
pub fn tiered_decode_table(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let steps = opts.steps(30);
    let mut t = Table::new(
        "Decode throughput at short context (prompt 16, B=4): \
         max_seq arenas (before) vs context-tiered arenas (after)",
        &["config", "pinned max_seq tok/s", "tiered tok/s", "speedup"],
    );
    for cfg_name in ["servefull", "servethin"] {
        let max_seq = rt.manifest().config(cfg_name)?.max_seq;
        let before = decode_throughput_opts(
            rt, cfg_name, 4, steps, false, 16, Some(max_seq))?;
        let after = decode_throughput_opts(
            rt, cfg_name, 4, steps, false, 16, None)?;
        t.row(&[
            cfg_name.to_string(),
            format!("{before:.1}"),
            format!("{after:.1}"),
            format!("{:.2}x", after / before),
        ]);
    }
    Ok(t)
}

/// Mixed-length serving scenario: a short-chat + long-document arrival
/// mix — the workload where context tiering pays off. Reports per-tier
/// occupancy of the (bucket × tier) artifact grid and the host-transfer
/// byte counters (uploads only on membership changes, zero full-arena
/// downloads, O(L·B) delta rows per step).
pub fn mixed_length_table(rt: &Runtime, cfg_name: &str) -> Result<Table> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let eng = Engine::new(rt, cfg_name, params, false, Sampler::Greedy, 0)?;
    let kv = KvCacheManager::new(KvCacheConfig {
        n_layers: cfg.n_layers,
        k_dims: cfg.k_cache_dims,
        v_dims: cfg.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: 4e6,
    });
    let sched = Scheduler::new(eng, kv, 16);
    let mut router = Router::new(sched);
    // 12 short chats interleaved with 4 long documents
    let trace: Vec<RequestSpec> = (0..16)
        .map(|i| {
            let doc = i % 4 == 3;
            RequestSpec {
                arrive_s: 0.0,
                prompt_len: if doc { 96 } else { 12 },
                gen_len: if doc { 24 } else { 8 },
                priority: if doc { Priority::Batch }
                          else { Priority::Interactive },
            }
        })
        .collect();
    let report = router.run_closed_loop(&trace, 0)?;
    let m = &router.sched.engine.metrics;
    let mut t = Table::new(
        &format!(
            "Mixed-length serving ({cfg_name}): 12 chats (12+8) + 4 docs \
             (96+24), max_seq {}",
            cfg.max_seq
        ),
        &["metric", "value"],
    );
    for (tier, steps) in &m.tier_steps {
        t.row(&[
            format!("decode steps @ tier n={tier}"),
            format!("{steps} ({:.0}%)",
                    100.0 * *steps as f64 / m.decode_steps as f64),
        ]);
    }
    t.row(&["tier switches".into(), m.tier_switches.to_string()]);
    t.row(&["arena bytes (final)".into(), m.arena_bytes.to_string()]);
    t.row(&["host→device upload B".into(), m.sync_upload_bytes.to_string()]);
    t.row(&["device→host full-arena B".into(),
            m.sync_download_bytes.to_string()]);
    t.row(&["delta-sync B/step".into(),
            format!("{:.0}", m.row_sync_bytes_per_step())]);
    t.row(&["gen tok/s".into(),
            format!("{:.1}", report.gen_tokens_per_sec())]);
    Ok(t)
}

/// One mixed chat+doc run at a given prefill mode. Returns the serve
/// report plus (prefill_chunks, chunk_stall_steps) from the engine.
fn mixed_run(rt: &Runtime, cfg_name: &str, chunk: Option<usize>,
             round_budget: usize) -> Result<(ServeReport, u64, u64)> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let eng = Engine::new(rt, cfg_name, params, false, Sampler::Greedy, 0)?;
    let kv = KvCacheManager::new(KvCacheConfig {
        n_layers: cfg.n_layers,
        k_dims: cfg.k_cache_dims,
        v_dims: cfg.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: 4e6,
    });
    let sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 16,
        round_budget,
        chunk_tokens: chunk,
        interactive_weight: 4,
        ..SchedConfig::default()
    });
    let mut router = Router::new(sched);
    // warmup: compile the prefill path (monolithic or chunked) and the
    // small decode buckets outside the measured trace
    let warmup = vec![
        RequestSpec { arrive_s: 0.0, prompt_len: 120, gen_len: 2,
                      priority: Priority::Batch },
        RequestSpec { arrive_s: 0.0, prompt_len: 8, gen_len: 2,
                      priority: Priority::Interactive },
    ];
    router.run_closed_loop(&warmup, 7)?;
    router.sched.finished.clear();
    let (chunks0, stalls0) = {
        let m = &router.sched.engine.metrics;
        (m.prefill_chunks, m.chunk_stall_steps)
    };
    // the measured mixed trace: 2 docs at t=0, 12 chats arriving while
    // the documents are still being prefilled
    let trace = mixed_chat_doc_trace(12, 2, 0.002, 0.0005);
    let report = router.run_trace(&trace, 0)?;
    let m = &router.sched.engine.metrics;
    Ok((report, m.prefill_chunks - chunks0, m.chunk_stall_steps - stalls0))
}

/// The chunked-prefill acceptance table (ISSUE 3): the mixed chat+doc
/// trace served with monolithic prefill vs chunked prefill at every
/// exported chunk size. The headline column is interactive decode-TTFT
/// p99 — chats arriving mid-document wait out the whole document prompt
/// monolithically, but at most one chunk boundary with chunking (plus
/// their own prefill, which is itself a single small chunk instead of a
/// full prefill_seq pass). Returns the table and the per-mode
/// `(chunk_tokens, interactive p99 us)` pairs so bench_serving can assert
/// the strict improvement.
pub fn chunked_prefill_table(rt: &Runtime, cfg_name: &str)
    -> Result<(Table, Vec<(Option<usize>, f64)>)> {
    let chunks = rt.manifest().chunks_for(cfg_name);
    let mut t = Table::new(
        &format!(
            "Chunked prefill ({cfg_name}): mixed trace, 2 docs (120+8, \
             batch) + 12 chats (8+8, interactive), round budget 64"
        ),
        &["prefill mode", "interactive TTFT p50/p99 (ms)",
          "batch TTFT p99 (ms)", "gen tok/s", "chunks", "stalled rounds"],
    );
    let mut p99s = Vec::new();
    let mut modes: Vec<Option<usize>> = vec![None];
    modes.extend(chunks.iter().map(|&c| Some(c)));
    for mode in modes {
        let (report, n_chunks, n_stalls) =
            mixed_run(rt, cfg_name, mode, 64)?;
        let p99 = report.ttft_interactive.quantile_us(0.99);
        p99s.push((mode, p99));
        t.row(&[
            match mode {
                None => "monolithic".to_string(),
                Some(c) => format!("chunked c={c}"),
            },
            format!("{:.1} / {:.1}",
                    report.ttft_interactive.quantile_us(0.50) / 1e3,
                    p99 / 1e3),
            format!("{:.1}", report.ttft_batch.quantile_us(0.99) / 1e3),
            format!("{:.1}", report.gen_tokens_per_sec()),
            n_chunks.to_string(),
            n_stalls.to_string(),
        ]);
    }
    Ok((t, p99s))
}

/// One fp32-vs-q8 comparison point, returned alongside the table so
/// bench_serving can assert the acceptance criteria (ISSUE 4).
#[derive(Clone, Copy, Debug)]
pub struct QuantCompare {
    pub fp32_tok_s: f64,
    pub q8_tok_s: f64,
    /// K+V arena payload gauge after the run (the 4x headline).
    pub fp32_arena_bytes: u64,
    pub q8_arena_bytes: u64,
    /// q8 scale-plane gauge (0 for fp32) — the honest overhead line.
    pub q8_scale_bytes: u64,
    pub fp32_row_sync_per_step: f64,
    pub q8_row_sync_per_step: f64,
    /// Teacher-forced max-abs-logit error of the q8 engine vs fp32.
    pub max_abs_logit_err: f64,
}

/// Teacher-forced twin decode: run the fp32 and q8 engines over the SAME
/// prompts and force the q8 engine to follow the fp32 engine's sampled
/// tokens, so both attend identical contexts every step; the max abs
/// difference of their per-step logits is then pure quantization error
/// (arena codes + fused dequant), not divergence drift.
pub fn q8_decode_logit_error(rt: &Runtime, cfg_name: &str, batch: usize,
                             steps: usize) -> Result<f64> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let mut e32 = Engine::new(rt, cfg_name, params.clone(), false,
                              Sampler::Greedy, 0)?;
    let mut e8 = Engine::with_kv_quant(rt, cfg_name, params, false,
                                       Sampler::Greedy, 0, KvQuant::Q8)?;
    let mut rng = Rng::new(11);
    let prompts: Vec<Vec<i32>> = (0..batch)
        .map(|_| synth_prompt(12, cfg.vocab, &mut rng))
        .collect();
    let mk = |prompts: &[Vec<i32>]| -> Vec<Sequence> {
        prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                Sequence::new(i as u64 + 1, p.clone(), steps + 8, None)
            })
            .collect()
    };
    let mut s32 = mk(&prompts);
    let mut s8 = mk(&prompts);
    for s in s32.iter_mut() {
        e32.prefill(s)?;
    }
    for s in s8.iter_mut() {
        e8.prefill(s)?;
    }
    // align the first generated token (prefill sampling is greedy off
    // fp32 logits in e32 and fp32-prefill logits in e8 — identical, but
    // force anyway so a flip cannot desynchronize the contexts)
    for (a, b) in s32.iter().zip(s8.iter_mut()) {
        *b.generated.last_mut().unwrap() = *a.generated.last().unwrap();
    }
    let mut worst = 0f64;
    for _ in 0..steps {
        let mut r32: Vec<&mut Sequence> = s32.iter_mut().collect();
        e32.decode_step(&mut r32)?;
        drop(r32);
        let mut r8: Vec<&mut Sequence> = s8.iter_mut().collect();
        e8.decode_step(&mut r8)?;
        drop(r8);
        let l32 = e32.last_decode_logits().expect("fp32 logits");
        let l8 = e8.last_decode_logits().expect("q8 logits");
        worst = worst.max(l32.max_abs_diff(l8) as f64);
        // teacher-force: the q8 engine continues from the fp32 tokens
        for (a, b) in s32.iter().zip(s8.iter_mut()) {
            *b.generated.last_mut().unwrap() = *a.generated.last().unwrap();
        }
    }
    Ok(worst)
}

/// The ISSUE 4 acceptance table: the mixed chat+doc trace served by the
/// fp32 engine vs the q8 engine — decode throughput, arena payload and
/// scale gauges, per-step delta-sync traffic, and the teacher-forced
/// max-abs-logit error. The K+V payload shrinks exactly 4x at identical
/// (bucket, tier) trajectories; the scale planes are reported separately
/// so the ~3.6x *total* (payload+scales at toy KD) stays visible next to
/// the 4x payload headline that holds at production widths.
pub fn quantized_decode_table(rt: &Runtime, cfg_name: &str)
    -> Result<(Table, QuantCompare)> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let mut per_mode = Vec::new();
    for quant in [KvQuant::Fp32, KvQuant::Q8] {
        let params = ParamStore::init(&cfg, 42);
        let eng = Engine::with_kv_quant(rt, cfg_name, params, false,
                                        Sampler::Greedy, 0, quant)?;
        // model the admission budget at the true per-element widths (the
        // split-pool manager already supports fractional widths): q8
        // amortizes its per-row scale over the row's elements
        let scale_amort_k = quant.scale_bytes_per_row() as f64
            / cfg.k_cache_dims as f64;
        let scale_amort_v = quant.scale_bytes_per_row() as f64
            / cfg.v_cache_dims as f64;
        let kv = KvCacheManager::new(KvCacheConfig {
            n_layers: cfg.n_layers,
            k_dims: cfg.k_cache_dims,
            v_dims: cfg.v_cache_dims,
            block_tokens: 16,
            bytes_per_el_k: quant.elem_bytes() as f64 + scale_amort_k,
            bytes_per_el_v: quant.elem_bytes() as f64 + scale_amort_v,
            budget_bytes: 4e6,
        });
        let sched = Scheduler::new(eng, kv, 16);
        let mut router = Router::new(sched);
        let trace: Vec<RequestSpec> = (0..16)
            .map(|i| {
                let doc = i % 4 == 3;
                RequestSpec {
                    arrive_s: 0.0,
                    prompt_len: if doc { 96 } else { 12 },
                    gen_len: if doc { 24 } else { 8 },
                    priority: if doc { Priority::Batch }
                              else { Priority::Interactive },
                }
            })
            .collect();
        let report = router.run_closed_loop(&trace, 0)?;
        let m = router.sched.engine.metrics.clone();
        per_mode.push((quant, report, m));
    }
    let err = q8_decode_logit_error(rt, cfg_name, 4, 16)?;
    let mut t = Table::new(
        &format!(
            "Quantized decode ({cfg_name}): mixed 12-chat + 4-doc trace, \
             fp32 vs q8 engine (teacher-forced max-abs-logit err \
             {err:.2e})"
        ),
        &["kv quant", "gen tok/s", "arena payload B", "scale B",
          "delta B/step", "sync up B", "down B"],
    );
    for (quant, report, m) in &per_mode {
        t.row(&[
            quant.name().to_string(),
            format!("{:.1}", report.gen_tokens_per_sec()),
            m.arena_bytes.to_string(),
            m.arena_scale_bytes.to_string(),
            format!("{:.0}", m.row_sync_bytes_per_step()),
            m.sync_upload_bytes.to_string(),
            m.sync_download_bytes.to_string(),
        ]);
    }
    let (_, r32, m32) = &per_mode[0];
    let (_, r8, m8) = &per_mode[1];
    let cmp = QuantCompare {
        fp32_tok_s: r32.gen_tokens_per_sec(),
        q8_tok_s: r8.gen_tokens_per_sec(),
        fp32_arena_bytes: m32.arena_bytes,
        q8_arena_bytes: m8.arena_bytes,
        q8_scale_bytes: m8.arena_scale_bytes,
        fp32_row_sync_per_step: m32.row_sync_bytes_per_step(),
        q8_row_sync_per_step: m8.row_sync_bytes_per_step(),
        max_abs_logit_err: err,
    };
    Ok((t, cmp))
}

/// The measured composed-compression summary (ISSUE 5), returned next to
/// the table so the benches can assert the acceptance criteria off the
/// engine gauges rather than the analytic formulas.
#[derive(Clone, Copy, Debug)]
pub struct GqaCompare {
    /// servefull-fp32 K-arena payload gauge / servegqathin-q8 K-arena
    /// payload gauge, at identical (bucket, tier) — the measured
    /// group × rank × element-width composition (64x at this geometry).
    pub composed_key_compression: f64,
    /// Same ratio with the q8 per-row K scale plane charged to the
    /// denominator — the honest number at toy widths (still ≥ 15x).
    pub composed_key_compression_with_scales: f64,
    /// servefull-fp32 vs servegqa-fp32 K gauges: the pure group factor.
    pub group_key_compression: f64,
    /// Teacher-forced max-abs-logit error of the servegqathin q8 engine
    /// vs its fp32 twin (grouped arenas + fused dequant).
    pub gqa_thin_q8_logit_err: f64,
}

/// Run a fixed decode workload and return the engine metrics + tok/s.
/// Every config/quant mode is driven through the SAME (batch, prompt,
/// steps) trajectory, so bucket and tier match across runs and the arena
/// gauges are directly comparable.
fn measured_arena_run(rt: &Runtime, cfg_name: &str, quant: KvQuant,
                      batch: usize, prompt_len: usize, steps: usize)
    -> Result<(crate::coordinator::metrics::EngineMetrics, f64)> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let mut eng = Engine::with_kv_quant(rt, cfg_name, params, false,
                                        Sampler::Greedy, 0, quant)?;
    let mut rng = Rng::new(2);
    let mut seqs: Vec<Sequence> = (0..batch)
        .map(|i| {
            Sequence::new(i as u64 + 1,
                          synth_prompt(prompt_len, cfg.vocab, &mut rng),
                          steps + 8, None)
        })
        .collect();
    for s in seqs.iter_mut() {
        eng.prefill(s)?;
    }
    for _ in 0..2 {
        let mut refs: Vec<&mut Sequence> = seqs.iter_mut().collect();
        eng.decode_step(&mut refs)?;
    }
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let mut refs: Vec<&mut Sequence> = seqs.iter_mut().collect();
        eng.decode_step(&mut refs)?;
    }
    let secs = t0.elapsed().as_secs_f64();
    Ok((eng.metrics.clone(), (batch * steps) as f64 / secs))
}

/// THE measured composition table (ISSUE 5): the serve grid's four
/// configs × kv-quant modes driven through an identical decode workload,
/// with the composed key-cache compression read off the engine's
/// `arena_k_bytes` gauge — the runtime twin of the analytic §6 table in
/// roofline.rs. servegqathin-q8 holds a K arena 64x (payload; 32x with
/// its scale plane) below servefull-fp32 at the same (bucket, tier),
/// with grouped decode logits staying teacher-forced-bounded vs fp32.
pub fn gqa_composition_table(rt: &Runtime)
    -> Result<(Table, GqaCompare)> {
    let (batch, prompt, steps) = (4usize, 16usize, 10usize);
    let modes: [(&str, KvQuant); 6] = [
        ("servefull", KvQuant::Fp32),
        ("servethin", KvQuant::Fp32),
        ("servethin", KvQuant::Q8),
        ("servegqa", KvQuant::Fp32),
        ("servegqathin", KvQuant::Fp32),
        ("servegqathin", KvQuant::Q8),
    ];
    let mut rows = Vec::new();
    for &(cfg_name, quant) in &modes {
        let cfg = rt.manifest().config(cfg_name)?.clone();
        let (m, tok_s) =
            measured_arena_run(rt, cfg_name, quant, batch, prompt, steps)?;
        rows.push((cfg_name, quant, cfg, m, tok_s));
    }
    // all runs follow the same length trajectory over the same tier
    // table, so bucket and tier match across rows and the gauges are
    // directly comparable
    anyhow::ensure!(
        rows.iter().all(|(_, _, _, m, _)| m.arena_k_bytes > 0),
        "arena gauges empty — no regroup happened"
    );
    let err = q8_decode_logit_error(rt, "servegqathin", batch, steps)?;
    let base_k = rows[0].3.arena_k_bytes as f64;
    let mut t = Table::new(
        &format!(
            "Composed key-cache compression, MEASURED off the engine \
             arena gauges (B={batch}, prompt {prompt}, {steps} steps — \
             identical bucket/tier across rows; servegqathin q8-vs-fp32 \
             teacher-forced logit err {err:.2e})"
        ),
        &["config", "kv quant", "KD", "K arena B", "K scale B",
          "K+V arena B", "tok/s", "K compression"],
    );
    for (cfg_name, quant, cfg, m, tok_s) in &rows {
        t.row(&[
            cfg_name.to_string(),
            quant.name().to_string(),
            cfg.k_cache_dims.to_string(),
            m.arena_k_bytes.to_string(),
            m.arena_k_scale_bytes.to_string(),
            m.arena_bytes.to_string(),
            format!("{tok_s:.1}"),
            format!("{:.1}x", base_k / m.arena_k_bytes as f64),
        ]);
    }
    let by = |name: &str, q: KvQuant| {
        rows.iter()
            .find(|(n, rq, ..)| *n == name && *rq == q)
            .map(|(_, _, _, m, _)| m)
            .expect("mode row")
    };
    let gqa8 = by("servegqathin", KvQuant::Q8);
    let cmp = GqaCompare {
        composed_key_compression: base_k / gqa8.arena_k_bytes as f64,
        composed_key_compression_with_scales: base_k
            / (gqa8.arena_k_bytes + gqa8.arena_k_scale_bytes) as f64,
        group_key_compression: base_k
            / by("servegqa", KvQuant::Fp32).arena_k_bytes as f64,
        gqa_thin_q8_logit_err: err,
    };
    Ok((t, cmp))
}

/// Measured decode throughput table (our stack) + measured speedups.
pub fn table11_measured(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let steps = opts.steps(40);
    let batches = [1usize, 2, 4, 8, 16, 32];
    let mut full = Vec::new();
    let mut thin = Vec::new();
    for &b in &batches {
        full.push(decode_throughput(rt, "servefull", b, steps, false)?);
        thin.push(decode_throughput(rt, "servethin", b, steps, false)?);
    }
    let mut t = Table::new(
        "Table 11 (measured, this stack) — decode throughput tok/s",
        &["batch", "full d_k=8", "factored d_k=2", "speedup"],
    );
    for (i, &b) in batches.iter().enumerate() {
        t.row(&[
            b.to_string(),
            format!("{:.1}", full[i]),
            format!("{:.1}", thin[i]),
            format!("{:.2}x", thin[i] / full[i]),
        ]);
    }
    Ok(t)
}

/// The paper's predicted rows, reproduced exactly from Eq. 10 at the
/// published Mistral-7B constants.
pub fn table11_predicted() -> Table {
    let mut t = Table::new(
        "Table 11 (predicted, Eq. 10 @ Mistral-7B constants)",
        &["variant", "b=1", "b=4", "b=8", "b=16", "b=32", "asymptote"],
    );
    let w = roofline::MISTRAL.w_gb * GB;
    let ck = roofline::MISTRAL.ckv_mb * 1e6;
    for (label, w_thin, ck_thin) in roofline::mistral_thin_variants() {
        let (wt, ckt) = (w_thin * GB, ck_thin * 1e6);
        let cells: Vec<String> = [1.0, 4.0, 8.0, 16.0, 32.0]
            .iter()
            .map(|&b| format!("{:.2}x", eq10_speedup(w, wt, ck, ckt, b)))
            .collect();
        t.row(&[
            label.to_string(),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
            cells[3].clone(),
            cells[4].clone(),
            format!("{:.2}x", roofline::eq10_asymptote(ck, ckt)),
        ]);
    }
    t
}

/// Copy-back cost of a steady-state membership change: group 8 sequences
/// (B=8), retire one, keep decoding. Reports the host bytes the
/// incremental lane-stable repack moved against what the full
/// park/unpark baseline would have moved — the serving-side companion to
/// the paper's Table 12 copy-back experiment.
pub fn regroup_copyback_table(rt: &Runtime, cfg_name: &str) -> Result<Table> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let mut eng = Engine::new(rt, cfg_name, params, false,
                              Sampler::Greedy, 0)?;
    let mut rng = Rng::new(4);
    let mut seqs: Vec<Sequence> = (0..8)
        .map(|i| {
            let max_new = if i == 0 { 2 } else { 12 };
            Sequence::new(i as u64 + 1,
                          synth_prompt(16, cfg.vocab, &mut rng),
                          max_new, None)
        })
        .collect();
    for s in seqs.iter_mut() {
        eng.prefill(s)?;
    }
    // decode at B=8 until the short sequence retires
    while !seqs[0].is_finished() {
        let mut refs: Vec<&mut Sequence> =
            seqs.iter_mut().filter(|s| !s.is_finished()).collect();
        eng.decode_step(&mut refs)?;
    }
    let group_actual = eng.metrics.copyback_bytes;
    let group_full = eng.metrics.copyback_bytes_full;
    eng.drop_seq(seqs[0].id);
    // steady state with the vacated lane
    for _ in 0..4 {
        let mut refs: Vec<&mut Sequence> =
            seqs.iter_mut().filter(|s| !s.is_finished()).collect();
        eng.decode_step(&mut refs)?;
    }
    let retire_actual = eng.metrics.copyback_bytes - group_actual;
    let retire_full = eng.metrics.copyback_bytes_full - group_full;
    let savings = |a: u64, f: u64| {
        if a == 0 {
            "all".to_string()
        } else {
            format!("{:.1}x", f as f64 / a as f64)
        }
    };
    let mut t = Table::new(
        "Regroup copy-back, incremental vs full park/unpark (B=8)",
        &["membership change", "incremental B", "full-repack B", "saved"],
    );
    t.row(&[
        "initial group (8 joins)".into(),
        group_actual.to_string(),
        group_full.to_string(),
        savings(group_actual, group_full),
    ]);
    t.row(&[
        "one retirement, steady state".into(),
        retire_actual.to_string(),
        retire_full.to_string(),
        savings(retire_actual, retire_full),
    ]);
    Ok(t)
}

/// What one shared-prefix cohort run measured (ISSUE 8). Outputs are the
/// per-user generated token streams in submission order, so the caller
/// can assert bit-exactness across sharing modes.
#[derive(Clone, Debug)]
pub struct PrefixRunStats {
    pub report: ServeReport,
    /// Prompt tokens the engine actually computed (prefix hits skip
    /// their adopted rows — with sharing this approaches the UNIQUE
    /// token count of the cohort).
    pub prefill_tokens: u64,
    pub prefix_hits: u64,
    pub prefix_hit_tokens: u64,
    pub cow_splits: u64,
    /// Peak of the dedup-bytes gauge over the run (the end-state gauge
    /// is 0 — a drained pool shares nothing).
    pub peak_dedup_bytes: f64,
    pub peak_shared_blocks: u64,
    /// Most sequences concurrently holding reservations (running +
    /// in-flight prefills) — the capacity headline on a fixed pool.
    pub peak_concurrent: usize,
    pub audit_checks: u64,
    pub sync_download_bytes: u64,
    pub outputs: Vec<Vec<i32>>,
}

/// Serve one chat cohort to completion: `users` sequences over ONE
/// system prompt (`system_tokens` tokens) plus a distinct per-user
/// suffix, on a pool of exactly `pool_blocks` KV blocks. Drives the
/// scheduler directly — router traces synthesize content-free prompts,
/// and prefix sharing is precisely about prompt CONTENT. The same seed
/// generates identical prompts for both sharing modes.
pub fn shared_prefix_run(rt: &Runtime, cfg_name: &str, users: usize,
                         system_tokens: usize, user_tokens: usize,
                         gen_tokens: usize, pool_blocks: usize,
                         sharing: bool) -> Result<PrefixRunStats> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let eng = Engine::new(rt, cfg_name, params, false, Sampler::Greedy, 0)?;
    let mut kc = KvCacheConfig {
        n_layers: cfg.n_layers,
        k_dims: cfg.k_cache_dims,
        v_dims: cfg.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: 0.0,
    };
    // size the budget to EXACTLY pool_blocks blocks (plus half a token of
    // float headroom), so both sharing modes compete on the same pool
    kc.budget_bytes = kc.bytes_per_token()
        * (pool_blocks * kc.block_tokens) as f64
        + 0.5 * kc.bytes_per_token();
    let kv = KvCacheManager::new(kc);
    let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 16,
        prefix_sharing: sharing,
        ..SchedConfig::default()
    });
    let vocab = cfg.vocab;
    let mut rng = Rng::new(23);
    let system = synth_prompt(system_tokens, vocab, &mut rng);
    let t0 = std::time::Instant::now();
    for _ in 0..users {
        let mut prompt = system.clone();
        prompt.extend(synth_prompt(user_tokens, vocab, &mut rng));
        sched.submit(prompt, gen_tokens, None);
    }
    let mut peak_concurrent = 0usize;
    let mut peak_dedup = 0f64;
    let mut peak_shared = 0u64;
    while sched.has_work() {
        sched.step()?;
        peak_concurrent =
            peak_concurrent.max(sched.n_running() + sched.n_prefilling());
        peak_dedup = peak_dedup.max(sched.engine.metrics.dedup_bytes);
        peak_shared = peak_shared.max(sched.engine.metrics.shared_blocks);
    }
    let mut report = ServeReport {
        total_s: t0.elapsed().as_secs_f64(),
        ..ServeReport::default()
    };
    collect_into(&sched.finished, &mut report);
    let mut done = sched.finished;
    done.sort_by_key(|s| s.id);
    let m = &sched.engine.metrics;
    Ok(PrefixRunStats {
        report,
        prefill_tokens: m.prefill_tokens,
        prefix_hits: m.prefix_hits,
        prefix_hit_tokens: m.prefix_hit_tokens,
        cow_splits: m.cow_splits,
        peak_dedup_bytes: peak_dedup,
        peak_shared_blocks: peak_shared,
        peak_concurrent,
        audit_checks: m.audit_checks,
        sync_download_bytes: m.sync_download_bytes,
        outputs: done.into_iter().map(|s| s.generated).collect(),
    })
}

/// A sharing-on vs sharing-off pair at one cohort size, for the
/// acceptance asserts in bench_serving and the e2e suite.
#[derive(Clone, Debug)]
pub struct PrefixCompare {
    pub users: usize,
    pub unique_tokens: u64,
    pub shared: PrefixRunStats,
    pub unshared: PrefixRunStats,
}

impl PrefixCompare {
    pub fn outputs_match(&self) -> bool {
        self.shared.outputs == self.unshared.outputs
    }
}

/// The ISSUE 8 acceptance table: N chat users over one 48-token system
/// prompt, sharing on vs off, on an identical 20-block pool. With
/// sharing, the shared prefix prefills exactly once (prefill tokens ==
/// unique tokens, `prefix_hits == N-1`), the pool holds strictly more
/// concurrent users, and interactive TTFT p50 drops — with outputs
/// bit-exact vs the unshared run.
pub fn shared_prefix_table(rt: &Runtime, cfg_name: &str)
    -> Result<(Table, Vec<PrefixCompare>)> {
    let (system, user, gen, blocks) = (48usize, 8usize, 8usize, 20usize);
    let mut t = Table::new(
        &format!(
            "Shared-prefix serving ({cfg_name}): N users on one \
             {system}-token system prompt, {blocks}-block pool, \
             sharing on vs off"
        ),
        &["users", "mode", "prefill tokens", "prefix hits",
          "peak concurrent", "peak dedup B", "TTFT p50 (ms)", "bit-exact"],
    );
    let mut out = Vec::new();
    for users in [1usize, 8, 64] {
        let shared = shared_prefix_run(
            rt, cfg_name, users, system, user, gen, blocks, true)?;
        let unshared = shared_prefix_run(
            rt, cfg_name, users, system, user, gen, blocks, false)?;
        let cmp = PrefixCompare {
            users,
            unique_tokens: (system + users * user) as u64,
            shared,
            unshared,
        };
        let exact = if cmp.outputs_match() { "yes" } else { "NO" };
        for (mode, r) in [("shared", &cmp.shared),
                          ("unshared", &cmp.unshared)] {
            t.row(&[
                users.to_string(),
                mode.to_string(),
                r.prefill_tokens.to_string(),
                r.prefix_hits.to_string(),
                r.peak_concurrent.to_string(),
                format!("{:.0}", r.peak_dedup_bytes),
                format!("{:.1}",
                        r.report.ttft.quantile_us(0.50) / 1e3),
                exact.to_string(),
            ]);
        }
        out.push(cmp);
    }
    Ok((t, out))
}

/// Headline capacity comparison (paper §1 / Table 10).
pub fn capacity_table() -> Table {
    let c = crate::coordinator::capacity::headline_comparison(
        crate::coordinator::capacity::H100_NODE_7B);
    let mut t = Table::new(
        "Concurrent-user capacity @ 7B / 128K context (H100 node)",
        &["metric", "value"],
    );
    t.row(&["users (standard KV)".into(), c.users_standard.to_string()]);
    t.row(&["users (thin keys d/4)".into(), c.users_thin.to_string()]);
    t.row(&["admission gain".into(), format!("{:.1}%", c.gain_pct)]);
    t.row(&["KV saved per user".into(),
            format!("{:.1} GB", c.saved_gb_per_user)]);
    t
}

pub fn run(rt: &Runtime, opts: &Opts) -> Result<Vec<Table>> {
    let (chunked, _) = chunked_prefill_table(rt, "servethin")?;
    let (quantized, _) = quantized_decode_table(rt, "servethin")?;
    let (gqa, _) = gqa_composition_table(rt)?;
    let (prefix, _) = shared_prefix_table(rt, "servethin")?;
    Ok(vec![
        table11_predicted(),
        table11_measured(rt, opts)?,
        tiered_decode_table(rt, opts)?,
        chunked,
        quantized,
        gqa,
        prefix,
        capacity_table(),
    ])
}
